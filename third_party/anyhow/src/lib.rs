//! Offline drop-in subset of the `anyhow` crate.
//!
//! Implements exactly the surface this workspace uses:
//!
//! - [`Error`]: an owned error with a context chain (outermost first);
//! - [`Result<T>`] with the `Error` default;
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! - [`anyhow!`], [`bail!`], [`ensure!`] macros.
//!
//! Like the real crate, `Display` prints the outermost message,
//! `{:#}` prints the whole chain joined by `": "`, and `Debug` prints
//! the outermost message followed by a `Caused by:` list. `Error`
//! deliberately does **not** implement `std::error::Error`, so the
//! blanket `From<E: std::error::Error>` conversion can coexist with the
//! reflexive `From<Error>` (same trick as upstream).

use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and to `None`).
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Into::<Error>::into(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Into::<Error>::into(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/real/path/7f3a").map(|_| ()).context("reading config")
    }

    #[test]
    fn context_chains_and_formats() {
        let err = io_fail().unwrap_err();
        assert_eq!(format!("{err}"), "reading config");
        assert!(format!("{err:#}").starts_with("reading config: "));
        assert!(format!("{err:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let err = none.context("missing id").unwrap_err();
        assert_eq!(err.to_string(), "missing id");

        fn bails(flag: bool) -> Result<u32> {
            ensure!(!flag, "flag was {flag}");
            bail!("always fails with {}", 42)
        }
        assert_eq!(bails(true).unwrap_err().to_string(), "flag was true");
        assert_eq!(bails(false).unwrap_err().to_string(), "always fails with 42");
        let e = anyhow!("plain");
        assert_eq!(e.root_cause(), "plain");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(parse().is_err());
    }
}
