//! Serialization substrates: a minimal JSON parser/writer (serde is not
//! available offline) and raw little-endian f32 tensor I/O used for
//! initial model weights produced by the AOT pipeline.

pub mod bin;
pub mod json;
