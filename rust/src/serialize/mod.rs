//! Serialization substrates: a minimal JSON parser/writer (serde is not
//! available offline), raw little-endian f32 tensor I/O used for
//! initial model weights produced by the AOT pipeline, and the shared
//! LE slice↔bytes helpers ([`le`]) that both the tensor files and the
//! wire codecs (`crate::wire`) build on.

pub mod bin;
pub mod json;
pub mod le;
