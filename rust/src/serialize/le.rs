//! Shared little-endian f32/u32 slice↔bytes helpers.
//!
//! One home for the chunked-buffer loops that used to be duplicated
//! between the weight-file I/O (`serialize::bin`) and that the wire
//! codecs (`crate::wire::codec`) now share: encoding appends to a byte
//! buffer, decoding either materializes a `Vec<f32>` or streams values
//! through a callback so hot paths (e.g.
//! `compression::aggregate::RoundAccum::absorb_bytes`) can fold encoded
//! frames without an intermediate allocation.

use anyhow::{bail, Result};
use std::io::Write;

/// Append `vals` to `out` as little-endian f32 bytes.
pub fn extend_f32_le(out: &mut Vec<u8>, vals: &[f32]) {
    out.reserve(vals.len() * 4);
    for &x in vals {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append `vals` to `out` as little-endian u32 bytes.
pub fn extend_u32_le(out: &mut Vec<u8>, vals: &[u32]) {
    out.reserve(vals.len() * 4);
    for &x in vals {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Stream `vals` to a writer as little-endian f32 bytes via a bounded
/// scratch buffer (no `unsafe`, no full-size copy).
pub fn write_f32_le<W: Write>(w: &mut W, vals: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(vals.len().min(1 << 14) * 4);
    for chunk in vals.chunks(1 << 14) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Decode a little-endian f32 byte slice. Errors unless `bytes` is an
/// exact multiple of 4.
pub fn f32s_from_le(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("f32 byte payload of {} bytes is not a multiple of 4", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 4);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(out)
}

/// Walk a little-endian f32 byte slice in place, handing each value to
/// `f` in order — the zero-copy decode path (no `Vec<f32>` is built).
/// The caller must have validated that `bytes.len() % 4 == 0`.
pub fn for_each_f32_le(bytes: &[u8], f: &mut dyn FnMut(f32)) {
    debug_assert_eq!(bytes.len() % 4, 0);
    for chunk in bytes.chunks_exact(4) {
        f(f32::from_le_bytes(chunk.try_into().unwrap()));
    }
}

/// `dst[i] += weight * decode_f32_le(bytes)[i]` for every `i`, in index
/// order — the fold the wire absorb path uses. Forwards to
/// [`crate::util::simd::axpy_f32_le`] (SSE2 under `--features simd`,
/// scalar reference otherwise); both perform the same per-cell op in
/// the same order as streaming `for_each_f32_le` through an axpy
/// closure, so the result is bitwise identical. The caller must have
/// validated `bytes.len() == 4 * dst.len()`.
pub fn axpy_f32_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
    debug_assert_eq!(bytes.len(), 4 * dst.len());
    crate::util::simd::axpy_f32_le(bytes, weight, dst);
}

/// Walk a little-endian u32 byte slice in place (sparse index arrays).
pub fn for_each_u32_le(bytes: &[u8], f: &mut dyn FnMut(u32)) {
    debug_assert_eq!(bytes.len() % 4, 0);
    for chunk in bytes.chunks_exact(4) {
        f(u32::from_le_bytes(chunk.try_into().unwrap()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let vals: Vec<f32> = (0..1000).map(|i| (i as f32).sin() * 1e3).collect();
        let mut bytes = Vec::new();
        extend_f32_le(&mut bytes, &vals);
        assert_eq!(bytes.len(), 4000);
        assert_eq!(f32s_from_le(&bytes).unwrap(), vals);
        let mut streamed = Vec::new();
        for_each_f32_le(&bytes, &mut |v| streamed.push(v));
        assert_eq!(streamed, vals);
    }

    #[test]
    fn writer_matches_extend() {
        let vals: Vec<f32> = (0..40_000).map(|i| i as f32 * 0.25).collect();
        let mut via_extend = Vec::new();
        extend_f32_le(&mut via_extend, &vals);
        let mut via_writer = Vec::new();
        write_f32_le(&mut via_writer, &vals).unwrap();
        assert_eq!(via_extend, via_writer);
    }

    #[test]
    fn rejects_ragged_payload() {
        assert!(f32s_from_le(&[0u8; 7]).is_err());
        assert!(f32s_from_le(&[]).unwrap().is_empty());
    }

    #[test]
    fn axpy_matches_streamed_fold_bitwise() {
        for n in [0usize, 1, 7, 8, 9, 31, 500] {
            let vals: Vec<f32> = (0..n).map(|i| (i as f32 * 0.31).sin() * 50.0).collect();
            let mut bytes = Vec::new();
            extend_f32_le(&mut bytes, &vals);
            let mut blocked: Vec<f32> = (0..n).map(|i| i as f32 * 0.125).collect();
            let mut streamed = blocked.clone();
            axpy_f32_le(&bytes, -1.75, &mut blocked);
            let mut i = 0;
            for_each_f32_le(&bytes, &mut |v| {
                streamed[i] += -1.75 * v;
                i += 1;
            });
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&blocked), bits(&streamed), "n={n}");
        }
    }

    #[test]
    fn u32_roundtrip() {
        let vals = vec![0u32, 1, 0xFFFF_FFFF, 42];
        let mut bytes = Vec::new();
        extend_u32_le(&mut bytes, &vals);
        let mut back = Vec::new();
        for_each_u32_le(&bytes, &mut |v| back.push(v));
        assert_eq!(back, vals);
    }
}
