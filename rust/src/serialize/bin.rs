//! Raw little-endian f32 tensor I/O.
//!
//! The AOT pipeline (`python/compile/aot.py`) dumps initial model weights
//! as flat little-endian f32 files next to the HLO artifacts; the
//! coordinator loads them at startup. A tiny 16-byte header carries a
//! magic and the element count so truncated/wrong files fail loudly.

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

use crate::serialize::le::{f32s_from_le, write_f32_le};

const MAGIC: &[u8; 8] = b"FSGDF32\0";

/// Write a flat f32 tensor.
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(data.len() as u64).to_le_bytes())?;
    write_f32_le(&mut f, data)?;
    Ok(())
}

/// Read a flat f32 tensor written by `write_f32` (or by the Python side,
/// which uses the same header).
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut header = [0u8; 16];
    f.read_exact(&mut header).context("reading f32 file header")?;
    if &header[..8] != MAGIC {
        bail!("{}: bad magic (not a FetchSGD f32 file)", path.display());
    }
    let n = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() != n * 4 {
        bail!("{}: expected {} bytes of payload, found {}", path.display(), n * 4, raw.len());
    }
    f32s_from_le(&raw)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("fsgd_bin_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        let data: Vec<f32> = (0..10_000).map(|i| (i as f32).sin()).collect();
        write_f32(&p, &data).unwrap();
        let back = read_f32(&p).unwrap();
        assert_eq!(data, back);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir().join(format!("fsgd_bin_trunc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("w.bin");
        write_f32(&p, &[1.0, 2.0, 3.0]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 2]).unwrap();
        assert!(read_f32(&p).is_err());
        std::fs::write(&p, b"NOTMAGIC********").unwrap();
        assert!(read_f32(&p).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
