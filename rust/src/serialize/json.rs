//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as f64, which is exact
//! for every integer the manifest/config/results files contain (< 2^53).
//! Object key order is preserved for stable round-trips.

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(Vec<(String, Value)>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_object(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(o) => o.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Required typed accessors with contextful errors — used by config
    /// and manifest loading.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing required field '{key}'"))
    }
    pub fn req_str(&self, key: &str) -> Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow!("field '{key}' must be a string"))
    }
    pub fn req_f64(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow!("field '{key}' must be a number"))
    }
    pub fn req_usize(&self, key: &str) -> Result<usize> {
        Ok(self.req_f64(key)? as usize)
    }
    pub fn req_u64(&self, key: &str) -> Result<u64> {
        Ok(self.req_f64(key)? as u64)
    }
    pub fn req_array(&self, key: &str) -> Result<&[Value]> {
        self.req(key)?.as_array().ok_or_else(|| anyhow!("field '{key}' must be an array"))
    }
    /// Optional typed accessors.
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }
    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt_f64(key, default as f64) as usize
    }
    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }
    pub fn opt_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s);
        s
    }
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Value> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}, found {:?}", c as char, self.pos, self.peek().map(|b| b as char))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(fields));
                }
                other => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, other.map(|b| b as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                other => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, other.map(|b| b as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            // Surrogate pairs not handled (never emitted by
                            // our own writer/manifests).
                            s.push(char::from_u32(code).ok_or_else(|| anyhow!("bad codepoint"))?);
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = std::str::from_utf8(&self.b[start..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

fn write_value(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 9e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => write_string(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(item, out);
            }
            out.push(']');
        }
        Value::Object(o) => {
            out.push('{');
            for (i, (k, val)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(val, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders for writing results/configs.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap(), Value::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x", "c": false}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").unwrap().as_array().unwrap()[2].req_str("b").unwrap(), "x");
        assert_eq!(v.get("d"), Some(&Value::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"fig3","rows":[1,2.5,-3],"ok":true,"nested":{"k":"v \"q\""}}"#;
        let v = parse(src).unwrap();
        let out = v.to_json();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v, Value::Str("café ☕".into()));
        let out = v.to_json();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(num(5.0).to_json(), "5");
        assert_eq!(num(5.25).to_json(), "5.25");
    }

    #[test]
    fn typed_accessors_report_missing_fields() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.req_str("missing").is_err());
        assert!(v.req_f64("a").is_ok());
        assert_eq!(v.opt_usize("zz", 9), 9);
    }
}
