//! Pareto-frontier extraction: the paper plots, per method, only the
//! frontier over hyperparameters in (compression, accuracy) space
//! (§5: "we plot only the Pareto frontier over hyperparameters").

/// A point in (compression, quality) space. `higher_quality_better`
/// selects accuracy-style (max) vs perplexity-style (min) metrics.
#[derive(Clone, Debug, PartialEq)]
pub struct RunPoint {
    pub compression: f64,
    pub quality: f64,
    pub label: String,
}

/// Extract the Pareto frontier: points not dominated by any other point
/// (another point with >= compression and strictly better quality, or
/// > compression and >= quality). Returned sorted by compression.
pub fn pareto_frontier(points: &[RunPoint], higher_quality_better: bool) -> Vec<RunPoint> {
    let better = |a: f64, b: f64| {
        if higher_quality_better {
            a > b
        } else {
            a < b
        }
    };
    let better_eq = |a: f64, b: f64| a == b || better(a, b);
    let mut frontier: Vec<RunPoint> = points
        .iter()
        .filter(|p| {
            !points.iter().any(|q| {
                (q.compression >= p.compression && better(q.quality, p.quality))
                    || (q.compression > p.compression && better_eq(q.quality, p.quality))
            })
        })
        .cloned()
        .collect();
    frontier.sort_by(|a, b| a.compression.partial_cmp(&b.compression).unwrap());
    frontier.dedup_by(|a, b| a.compression == b.compression && a.quality == b.quality);
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(c: f64, q: f64) -> RunPoint {
        RunPoint { compression: c, quality: q, label: String::new() }
    }

    #[test]
    fn dominated_points_removed_accuracy() {
        let pts = vec![pt(1.0, 0.9), pt(2.0, 0.85), pt(2.0, 0.7), pt(4.0, 0.8), pt(3.0, 0.6)];
        let f = pareto_frontier(&pts, true);
        let cs: Vec<f64> = f.iter().map(|p| p.compression).collect();
        assert_eq!(cs, vec![1.0, 2.0, 4.0]);
        assert_eq!(f[1].quality, 0.85);
    }

    #[test]
    fn perplexity_lower_is_better() {
        let pts = vec![pt(1.0, 14.9), pt(2.0, 16.3), pt(2.0, 15.1), pt(7.3, 15.8), pt(5.0, 20.0)];
        let f = pareto_frontier(&pts, false);
        let cs: Vec<f64> = f.iter().map(|p| p.compression).collect();
        assert_eq!(cs, vec![1.0, 2.0, 7.3]);
    }

    #[test]
    fn empty_and_single() {
        assert!(pareto_frontier(&[], true).is_empty());
        let f = pareto_frontier(&[pt(1.0, 1.0)], true);
        assert_eq!(f.len(), 1);
    }
}
