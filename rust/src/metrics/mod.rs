//! Metrics: JSONL/CSV run logging and Pareto-frontier extraction for the
//! accuracy-vs-compression figures.

pub mod logger;
pub mod pareto;

pub use logger::{EvalRecord, MetricsLogger, RoundRecord, SummaryRecord};
pub use pareto::pareto_frontier;
