//! Run logging: per-round training records and eval records, written as
//! JSONL (one JSON object per line) so experiment drivers and external
//! tooling can consume them without a parser dependency.

use anyhow::Result;
use std::io::Write;
use std::path::Path;

use crate::serialize::json::{num, obj, s, Value};

/// One training round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub loss: f64,
    pub lr: f64,
    /// Idealized upload bytes (paper footnote-5 convention).
    pub upload_bytes: u64,
    /// Idealized download bytes.
    pub download_bytes: u64,
    /// Measured wire-frame upload bytes (0 when wire mode is off) —
    /// logged next to the estimate so figures can show both
    /// conventions.
    pub wire_upload_bytes: u64,
    /// Measured wire-frame download bytes.
    pub wire_download_bytes: u64,
    /// Total measured on-the-wire bytes for the round when training is
    /// served over a real transport (`fetchsgd serve`): every
    /// round-start, upload, and round-end message including length
    /// prefixes and control headers. 0 for in-process runs.
    pub transport_bytes: u64,
    /// Times an absorb had to block on a shard lock held by another
    /// worker this round (see
    /// `compression::aggregate::AbsorbStats::lock_stalls`). 0 when the
    /// round was absorb-uncontended.
    pub absorb_stalls: u64,
    /// Frame bytes parked because an upload arrived ahead of an earlier
    /// slot on its shard (out-of-order arrivals that could not take the
    /// zero-copy path). 0 when every arrival folded in order.
    pub parked_bytes: u64,
    /// Shard count the absorb pipeline actually ran with this round.
    /// Interesting when the adaptive controller is on (the count moves
    /// with observed lock contention); 0 for in-process runs that never
    /// report it.
    pub chosen_shards: usize,
    /// Slots whose upload was actually absorbed this round — the
    /// cohort's arrived subset (equal to the planned cohort size unless
    /// quorum rounds dropped stragglers or faulted peers).
    pub participants: usize,
    /// Planned slots excluded from the round (fault / disconnect /
    /// deadline, after retries).
    pub dropped_slots: usize,
    /// Slots that needed at least one retry or reassignment.
    pub retried_slots: usize,
    pub update_nnz: usize,
    /// Which aggregation tier produced this record when the run is part
    /// of a relay tree: `"root"` for the tree's round server, `"relay"`
    /// for a mid-tier aggregator. `None` (flat and in-process runs)
    /// omits the key, so non-tree logs are unchanged.
    pub tier: Option<&'static str>,
}

/// One evaluation record.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub round: usize,
    pub eval_loss: f64,
    pub accuracy: f64,
    pub perplexity: f64,
}

/// JSONL writer; silently no-ops when no path is configured (keeps the
/// trainer's hot loop branch-free of IO concerns).
pub struct MetricsLogger {
    file: Option<std::fs::File>,
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
}

impl MetricsLogger {
    pub fn new(path: Option<&Path>) -> Result<Self> {
        let file = match path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                Some(std::fs::File::create(p)?)
            }
            None => None,
        };
        Ok(MetricsLogger { file, rounds: Vec::new(), evals: Vec::new() })
    }

    fn write_line(&mut self, v: Value) {
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{}", v.to_json());
        }
    }

    pub fn log_round(&mut self, r: RoundRecord) {
        let mut fields = vec![
            ("type", s("round")),
            ("round", num(r.round as f64)),
            ("loss", num(r.loss)),
            ("lr", num(r.lr)),
            ("upload_bytes", num(r.upload_bytes as f64)),
            ("download_bytes", num(r.download_bytes as f64)),
        ];
        // Measured wire bytes only exist in wire mode; omit the keys
        // otherwise so estimate-only logs stay unchanged.
        if r.wire_upload_bytes > 0 || r.wire_download_bytes > 0 {
            fields.push(("wire_upload_bytes", num(r.wire_upload_bytes as f64)));
            fields.push(("wire_download_bytes", num(r.wire_download_bytes as f64)));
        }
        // On-the-wire transport bytes only exist for served runs.
        if r.transport_bytes > 0 {
            fields.push(("transport_bytes", num(r.transport_bytes as f64)));
        }
        // Absorb-contention counters: emitted only when the round saw
        // any contention or parking, so quiet logs stay compact.
        if r.absorb_stalls > 0 || r.parked_bytes > 0 {
            fields.push(("absorb_stalls", num(r.absorb_stalls as f64)));
            fields.push(("parked_bytes", num(r.parked_bytes as f64)));
        }
        // Absorb-shard layout: emitted whenever the round reported one,
        // so adaptive runs show the controller's sizing trace inline.
        if r.chosen_shards > 0 {
            fields.push(("chosen_shards", num(r.chosen_shards as f64)));
        }
        // Cohort membership: always reported, so participation sweeps
        // (paper-style 0.1% cohorts) can be read straight off the log.
        fields.push(("participants", num(r.participants as f64)));
        fields.push(("dropped_slots", num(r.dropped_slots as f64)));
        fields.push(("retried_slots", num(r.retried_slots as f64)));
        fields.push(("update_nnz", num(r.update_nnz as f64)));
        // Tree runs tag each record with its aggregation tier so one
        // merged log can be split back into root vs relay rows.
        if let Some(tier) = r.tier {
            fields.push(("tier", s(tier)));
        }
        self.write_line(obj(fields));
        self.rounds.push(r);
    }

    pub fn log_eval(&mut self, e: EvalRecord) {
        self.write_line(obj(vec![
            ("type", s("eval")),
            ("round", num(e.round as f64)),
            ("eval_loss", num(e.eval_loss)),
            ("accuracy", num(e.accuracy)),
            ("perplexity", num(e.perplexity)),
        ]));
        self.evals.push(e);
    }

    /// Mean training loss over the last `n` rounds (smoother signal than
    /// a single round on tiny-batch federated tasks).
    pub fn recent_loss(&self, n: usize) -> f64 {
        if self.rounds.is_empty() {
            return f64::NAN;
        }
        let start = self.rounds.len().saturating_sub(n);
        let tail = &self.rounds[start..];
        tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logs_to_file_as_jsonl() {
        let dir = std::env::temp_dir().join(format!("fsgd_log_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.jsonl");
        {
            let mut m = MetricsLogger::new(Some(&p)).unwrap();
            m.log_round(RoundRecord {
                round: 0,
                loss: 2.5,
                lr: 0.1,
                upload_bytes: 100,
                download_bytes: 50,
                wire_upload_bytes: 132,
                wire_download_bytes: 70,
                transport_bytes: 180,
                absorb_stalls: 4,
                parked_bytes: 264,
                chosen_shards: 8,
                participants: 3,
                dropped_slots: 1,
                retried_slots: 2,
                update_nnz: 5,
                tier: Some("root"),
            });
            m.log_eval(EvalRecord { round: 0, eval_loss: 2.0, accuracy: 0.5, perplexity: 7.4 });
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = crate::serialize::json::parse(lines[0]).unwrap();
        assert_eq!(v.req_str("type").unwrap(), "round");
        // measured wire bytes land next to the idealized estimates
        assert!((v.req_f64("upload_bytes").unwrap() - 100.0).abs() < 1e-9);
        assert!((v.req_f64("wire_upload_bytes").unwrap() - 132.0).abs() < 1e-9);
        assert!((v.req_f64("wire_download_bytes").unwrap() - 70.0).abs() < 1e-9);
        assert!((v.req_f64("transport_bytes").unwrap() - 180.0).abs() < 1e-9);
        // absorb-contention counters land next to the transport bytes
        assert!((v.req_f64("absorb_stalls").unwrap() - 4.0).abs() < 1e-9);
        assert!((v.req_f64("parked_bytes").unwrap() - 264.0).abs() < 1e-9);
        assert!((v.req_f64("chosen_shards").unwrap() - 8.0).abs() < 1e-9);
        // cohort membership lands next to the byte accounting
        assert!((v.req_f64("participants").unwrap() - 3.0).abs() < 1e-9);
        assert!((v.req_f64("dropped_slots").unwrap() - 1.0).abs() < 1e-9);
        assert!((v.req_f64("retried_slots").unwrap() - 2.0).abs() < 1e-9);
        // tree runs tag their tier; flat runs omit the key entirely
        assert_eq!(v.req_str("tier").unwrap(), "root");
        let v = crate::serialize::json::parse(lines[1]).unwrap();
        assert!((v.req_f64("perplexity").unwrap() - 7.4).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recent_loss_window() {
        let mut m = MetricsLogger::new(None).unwrap();
        for (i, l) in [10.0, 2.0, 4.0].into_iter().enumerate() {
            m.log_round(RoundRecord {
                round: i,
                loss: l,
                lr: 0.0,
                upload_bytes: 0,
                download_bytes: 0,
                wire_upload_bytes: 0,
                wire_download_bytes: 0,
                transport_bytes: 0,
                absorb_stalls: 0,
                parked_bytes: 0,
                chosen_shards: 0,
                participants: 1,
                dropped_slots: 0,
                retried_slots: 0,
                update_nnz: 0,
                tier: None,
            });
        }
        assert!((m.recent_loss(2) - 3.0).abs() < 1e-9);
        assert!((m.recent_loss(10) - 16.0 / 3.0).abs() < 1e-9);
    }
}
