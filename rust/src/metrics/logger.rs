//! Run logging: per-round training records, eval records, and an
//! end-of-run summary record, written as JSONL (one JSON object per
//! line) so experiment drivers and external tooling can consume them
//! without a parser dependency. The full schema — including which keys
//! are omitted when zero — is documented in `docs/OBSERVABILITY.md`.

use anyhow::Result;
use std::io::Write;
use std::path::Path;

use crate::serialize::json::{num, obj, s, Value};

/// One training round's record.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    pub loss: f64,
    pub lr: f64,
    /// Idealized upload bytes (paper footnote-5 convention).
    pub upload_bytes: u64,
    /// Idealized download bytes.
    pub download_bytes: u64,
    /// Measured wire-frame upload bytes (0 when wire mode is off) —
    /// logged next to the estimate so figures can show both
    /// conventions.
    pub wire_upload_bytes: u64,
    /// Measured wire-frame download bytes.
    pub wire_download_bytes: u64,
    /// Total measured on-the-wire bytes for the round when training is
    /// served over a real transport (`fetchsgd serve`): every
    /// round-start, upload, and round-end message including length
    /// prefixes and control headers. 0 for in-process runs.
    pub transport_bytes: u64,
    /// Times an absorb had to block on a shard lock held by another
    /// worker this round (see
    /// `compression::aggregate::AbsorbStats::lock_stalls`). 0 when the
    /// round was absorb-uncontended.
    pub absorb_stalls: u64,
    /// Frame bytes parked because an upload arrived ahead of an earlier
    /// slot on its shard (out-of-order arrivals that could not take the
    /// zero-copy path). 0 when every arrival folded in order.
    pub parked_bytes: u64,
    /// Shard count the absorb pipeline actually ran with this round.
    /// Interesting when the adaptive controller is on (the count moves
    /// with observed lock contention); 0 for in-process runs that never
    /// report it.
    pub chosen_shards: usize,
    /// Slots whose upload was actually absorbed this round — the
    /// cohort's arrived subset (equal to the planned cohort size unless
    /// quorum rounds dropped stragglers or faulted peers).
    pub participants: usize,
    /// Planned slots excluded from the round (fault / disconnect /
    /// deadline, after retries).
    pub dropped_slots: usize,
    /// Slots that needed at least one retry or reassignment.
    pub retried_slots: usize,
    pub update_nnz: usize,
    /// Wall-clock duration of the round in milliseconds. Always
    /// measured and always logged — the minimal timing fact every
    /// record carries, independent of the trace file.
    pub round_ms: f64,
    /// Client-compute phase duration (engine worker-pool span). 0 — and
    /// key omitted — for drivers whose compute is remote (serve/relay).
    pub compute_ms: f64,
    /// Cumulative time folding uploads into shard accumulators (traced
    /// engine rounds) or the server's upload-wait span. 0 when not
    /// measured.
    pub absorb_ms: f64,
    /// Shard reduce + finalize duration. 0 when not measured.
    pub reduce_ms: f64,
    /// Which aggregation tier produced this record when the run is part
    /// of a relay tree: `"root"` for the tree's round server, `"relay"`
    /// for a mid-tier aggregator. `None` (flat and in-process runs)
    /// omits the key, so non-tree logs are unchanged.
    pub tier: Option<&'static str>,
}

/// One evaluation record.
#[derive(Clone, Debug)]
pub struct EvalRecord {
    pub round: usize,
    pub eval_loss: f64,
    pub accuracy: f64,
    pub perplexity: f64,
}

/// The end-of-run record (`"type": "summary"`, one per log): the
/// run-level aggregates a consumer would otherwise recompute from every
/// round row. Timing aggregates are totals across rounds; the arrival
/// percentiles come from the run-level slot-arrival histogram and are
/// only nonzero (and only logged) when tracing measured them.
#[derive(Clone, Debug, Default)]
pub struct SummaryRecord {
    pub strategy: String,
    pub task: String,
    pub rounds: usize,
    pub final_loss: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub dropped_slots: u64,
    pub retried_slots: u64,
    /// Total wall-clock across rounds, ms. Always measured.
    pub round_ms: f64,
    /// Phase totals across rounds, ms; 0 (key omitted) when the driver
    /// never measured that phase.
    pub compute_ms: f64,
    pub absorb_ms: f64,
    pub reduce_ms: f64,
    /// Slot-arrival latency percentiles over the whole run, ms
    /// (log-bucket upper bounds; 0 and omitted when tracing was off).
    pub arrival_p50_ms: f64,
    pub arrival_p90_ms: f64,
    pub arrival_p99_ms: f64,
}

/// JSONL writer; no-ops when no path is configured (keeps the trainer's
/// hot loop branch-free of IO concerns). Write failures are *not*
/// silent: the first IO error is held and surfaced by
/// [`MetricsLogger::flush`] — and shouted to stderr on drop if nobody
/// called flush — so a full disk produces a loud truncation, not a
/// quietly shortened JSONL.
pub struct MetricsLogger {
    file: Option<std::fs::File>,
    /// First write error; once set, further writes are skipped.
    write_error: Option<std::io::Error>,
    /// Whether `write_error` was already surfaced through `flush`, so
    /// drop doesn't report it twice.
    error_reported: bool,
    pub rounds: Vec<RoundRecord>,
    pub evals: Vec<EvalRecord>,
}

impl MetricsLogger {
    pub fn new(path: Option<&Path>) -> Result<Self> {
        let file = match path {
            Some(p) => {
                if let Some(parent) = p.parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent)?;
                    }
                }
                Some(std::fs::File::create(p)?)
            }
            None => None,
        };
        Ok(MetricsLogger {
            file,
            write_error: None,
            error_reported: false,
            rounds: Vec::new(),
            evals: Vec::new(),
        })
    }

    fn write_line(&mut self, v: Value) {
        if self.write_error.is_some() {
            return;
        }
        if let Some(f) = &mut self.file {
            if let Err(e) = writeln!(f, "{}", v.to_json()) {
                self.write_error = Some(e);
            }
        }
    }

    /// Surface the first write error, if any. Call once at end of run;
    /// drop also reports (on stderr) if this was never called.
    pub fn flush(&mut self) -> Result<()> {
        if let Some(f) = &mut self.file {
            if self.write_error.is_none() {
                if let Err(e) = f.flush() {
                    self.write_error = Some(e);
                }
            }
        }
        if let Some(e) = &self.write_error {
            self.error_reported = true;
            return Err(anyhow::anyhow!("metrics log write failed; log is truncated: {e}"));
        }
        Ok(())
    }

    pub fn log_round(&mut self, r: RoundRecord) {
        let mut fields = vec![
            ("type", s("round")),
            ("round", num(r.round as f64)),
            ("loss", num(r.loss)),
            ("lr", num(r.lr)),
            ("upload_bytes", num(r.upload_bytes as f64)),
            ("download_bytes", num(r.download_bytes as f64)),
        ];
        // Measured wire bytes only exist in wire mode; omit the keys
        // otherwise so estimate-only logs stay unchanged.
        if r.wire_upload_bytes > 0 || r.wire_download_bytes > 0 {
            fields.push(("wire_upload_bytes", num(r.wire_upload_bytes as f64)));
            fields.push(("wire_download_bytes", num(r.wire_download_bytes as f64)));
        }
        // On-the-wire transport bytes only exist for served runs.
        if r.transport_bytes > 0 {
            fields.push(("transport_bytes", num(r.transport_bytes as f64)));
        }
        // Absorb-contention counters: emitted only when the round saw
        // any contention or parking, so quiet logs stay compact.
        if r.absorb_stalls > 0 || r.parked_bytes > 0 {
            fields.push(("absorb_stalls", num(r.absorb_stalls as f64)));
            fields.push(("parked_bytes", num(r.parked_bytes as f64)));
        }
        // Absorb-shard layout: emitted whenever the round reported one,
        // so adaptive runs show the controller's sizing trace inline.
        if r.chosen_shards > 0 {
            fields.push(("chosen_shards", num(r.chosen_shards as f64)));
        }
        // Cohort membership: always reported, so participation sweeps
        // (paper-style 0.1% cohorts) can be read straight off the log.
        fields.push(("participants", num(r.participants as f64)));
        fields.push(("dropped_slots", num(r.dropped_slots as f64)));
        fields.push(("retried_slots", num(r.retried_slots as f64)));
        fields.push(("update_nnz", num(r.update_nnz as f64)));
        // Round wall-clock is always present; the finer phase timings
        // appear only when the driver measured them.
        fields.push(("round_ms", num(r.round_ms)));
        if r.compute_ms > 0.0 {
            fields.push(("compute_ms", num(r.compute_ms)));
        }
        if r.absorb_ms > 0.0 {
            fields.push(("absorb_ms", num(r.absorb_ms)));
        }
        if r.reduce_ms > 0.0 {
            fields.push(("reduce_ms", num(r.reduce_ms)));
        }
        // Tree runs tag each record with its aggregation tier so one
        // merged log can be split back into root vs relay rows.
        if let Some(tier) = r.tier {
            fields.push(("tier", s(tier)));
        }
        self.write_line(obj(fields));
        self.rounds.push(r);
    }

    pub fn log_eval(&mut self, e: EvalRecord) {
        self.write_line(obj(vec![
            ("type", s("eval")),
            ("round", num(e.round as f64)),
            ("eval_loss", num(e.eval_loss)),
            ("accuracy", num(e.accuracy)),
            ("perplexity", num(e.perplexity)),
        ]));
        self.evals.push(e);
    }

    pub fn log_summary(&mut self, r: &SummaryRecord) {
        let mut fields = vec![
            ("type", s("summary")),
            ("strategy", s(&r.strategy)),
            ("task", s(&r.task)),
            ("rounds", num(r.rounds as f64)),
            ("final_loss", num(r.final_loss)),
            ("upload_bytes", num(r.upload_bytes as f64)),
            ("download_bytes", num(r.download_bytes as f64)),
            ("dropped_slots", num(r.dropped_slots as f64)),
            ("retried_slots", num(r.retried_slots as f64)),
            ("round_ms", num(r.round_ms)),
        ];
        for (key, v) in [
            ("compute_ms", r.compute_ms),
            ("absorb_ms", r.absorb_ms),
            ("reduce_ms", r.reduce_ms),
            ("arrival_p50_ms", r.arrival_p50_ms),
            ("arrival_p90_ms", r.arrival_p90_ms),
            ("arrival_p99_ms", r.arrival_p99_ms),
        ] {
            if v > 0.0 {
                fields.push((key, num(v)));
            }
        }
        self.write_line(obj(fields));
    }

    /// Training-loss signal over the last `n` rounds, weighted by each
    /// round's participants: a quorum-closed partial round contributes
    /// in proportion to the uploads that actually reached it, and a
    /// zero-participant round contributes nothing instead of dragging
    /// the mean. Falls back to the unweighted mean if the whole window
    /// had zero participants (degenerate, but defined).
    pub fn recent_loss(&self, n: usize) -> f64 {
        if self.rounds.is_empty() {
            return f64::NAN;
        }
        let start = self.rounds.len().saturating_sub(n);
        let tail = &self.rounds[start..];
        let weight: f64 = tail.iter().map(|r| r.participants as f64).sum();
        if weight == 0.0 {
            return tail.iter().map(|r| r.loss).sum::<f64>() / tail.len() as f64;
        }
        tail.iter().map(|r| r.loss * r.participants as f64).sum::<f64>() / weight
    }
}

impl Drop for MetricsLogger {
    fn drop(&mut self) {
        if let Some(f) = &mut self.file {
            if self.write_error.is_none() {
                if let Err(e) = f.flush() {
                    self.write_error = Some(e);
                }
            }
        }
        if let (Some(e), false) = (&self.write_error, self.error_reported) {
            eprintln!("warning: metrics log write failed; log is truncated: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, loss: f64, participants: usize) -> RoundRecord {
        RoundRecord {
            round,
            loss,
            lr: 0.0,
            upload_bytes: 0,
            download_bytes: 0,
            wire_upload_bytes: 0,
            wire_download_bytes: 0,
            transport_bytes: 0,
            absorb_stalls: 0,
            parked_bytes: 0,
            chosen_shards: 0,
            participants,
            dropped_slots: 0,
            retried_slots: 0,
            update_nnz: 0,
            round_ms: 1.0,
            compute_ms: 0.0,
            absorb_ms: 0.0,
            reduce_ms: 0.0,
            tier: None,
        }
    }

    #[test]
    fn logs_to_file_as_jsonl() {
        let dir = std::env::temp_dir().join(format!("fsgd_log_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.jsonl");
        {
            let mut m = MetricsLogger::new(Some(&p)).unwrap();
            m.log_round(RoundRecord {
                round: 0,
                loss: 2.5,
                lr: 0.1,
                upload_bytes: 100,
                download_bytes: 50,
                wire_upload_bytes: 132,
                wire_download_bytes: 70,
                transport_bytes: 180,
                absorb_stalls: 4,
                parked_bytes: 264,
                chosen_shards: 8,
                participants: 3,
                dropped_slots: 1,
                retried_slots: 2,
                update_nnz: 5,
                round_ms: 12.5,
                compute_ms: 8.25,
                absorb_ms: 1.5,
                reduce_ms: 0.75,
                tier: Some("root"),
            });
            m.log_eval(EvalRecord { round: 0, eval_loss: 2.0, accuracy: 0.5, perplexity: 7.4 });
            m.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        let v = crate::serialize::json::parse(lines[0]).unwrap();
        assert_eq!(v.req_str("type").unwrap(), "round");
        // measured wire bytes land next to the idealized estimates
        assert!((v.req_f64("upload_bytes").unwrap() - 100.0).abs() < 1e-9);
        assert!((v.req_f64("wire_upload_bytes").unwrap() - 132.0).abs() < 1e-9);
        assert!((v.req_f64("wire_download_bytes").unwrap() - 70.0).abs() < 1e-9);
        assert!((v.req_f64("transport_bytes").unwrap() - 180.0).abs() < 1e-9);
        // absorb-contention counters land next to the transport bytes
        assert!((v.req_f64("absorb_stalls").unwrap() - 4.0).abs() < 1e-9);
        assert!((v.req_f64("parked_bytes").unwrap() - 264.0).abs() < 1e-9);
        assert!((v.req_f64("chosen_shards").unwrap() - 8.0).abs() < 1e-9);
        // cohort membership lands next to the byte accounting
        assert!((v.req_f64("participants").unwrap() - 3.0).abs() < 1e-9);
        assert!((v.req_f64("dropped_slots").unwrap() - 1.0).abs() < 1e-9);
        assert!((v.req_f64("retried_slots").unwrap() - 2.0).abs() < 1e-9);
        // round timing: wall clock always, phases when measured
        assert!((v.req_f64("round_ms").unwrap() - 12.5).abs() < 1e-9);
        assert!((v.req_f64("compute_ms").unwrap() - 8.25).abs() < 1e-9);
        assert!((v.req_f64("absorb_ms").unwrap() - 1.5).abs() < 1e-9);
        assert!((v.req_f64("reduce_ms").unwrap() - 0.75).abs() < 1e-9);
        // tree runs tag their tier; flat runs omit the key entirely
        assert_eq!(v.req_str("tier").unwrap(), "root");
        let v = crate::serialize::json::parse(lines[1]).unwrap();
        assert!((v.req_f64("perplexity").unwrap() - 7.4).abs() < 1e-9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recent_loss_weights_by_participants() {
        let mut m = MetricsLogger::new(None).unwrap();
        // Equal participation: identical to the old unweighted mean.
        for (i, l) in [10.0, 2.0, 4.0].into_iter().enumerate() {
            m.log_round(record(i, l, 1));
        }
        assert!((m.recent_loss(2) - 3.0).abs() < 1e-9);
        assert!((m.recent_loss(10) - 16.0 / 3.0).abs() < 1e-9);

        // A quorum-closed partial round (1 of 4 participants) must not
        // pull the window as hard as a full round.
        let mut m = MetricsLogger::new(None).unwrap();
        m.log_round(record(0, 2.0, 4));
        m.log_round(record(1, 10.0, 1));
        assert!((m.recent_loss(2) - (2.0 * 4.0 + 10.0) / 5.0).abs() < 1e-9);

        // Zero-participant rounds (e.g. a relay's empty chain) vanish
        // from the signal entirely.
        let mut m = MetricsLogger::new(None).unwrap();
        m.log_round(record(0, 3.0, 2));
        m.log_round(record(1, 0.0, 0));
        assert!((m.recent_loss(2) - 3.0).abs() < 1e-9);

        // Degenerate all-zero window: fall back to the plain mean
        // rather than dividing by zero.
        let mut m = MetricsLogger::new(None).unwrap();
        m.log_round(record(0, 5.0, 0));
        assert!((m.recent_loss(1) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn summary_record_omits_unmeasured_timing_keys() {
        let dir = std::env::temp_dir().join(format!("fsgd_sum_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.jsonl");
        {
            let mut m = MetricsLogger::new(Some(&p)).unwrap();
            m.log_summary(&SummaryRecord {
                strategy: "fetchsgd".into(),
                task: "smoke".into(),
                rounds: 3,
                final_loss: 1.25,
                round_ms: 30.0,
                compute_ms: 20.0,
                ..SummaryRecord::default()
            });
            m.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let v = crate::serialize::json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(v.req_str("type").unwrap(), "summary");
        assert_eq!(v.req_str("strategy").unwrap(), "fetchsgd");
        assert!((v.req_f64("round_ms").unwrap() - 30.0).abs() < 1e-9);
        assert!((v.req_f64("compute_ms").unwrap() - 20.0).abs() < 1e-9);
        assert!(v.get("absorb_ms").is_none(), "unmeasured phases are omitted");
        assert!(v.get("arrival_p50_ms").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A full "disk" surfaces as a flush error instead of a silently
    /// truncated log (Linux-only: needs /dev/full).
    #[test]
    #[cfg(target_os = "linux")]
    fn write_errors_surface_on_flush() {
        let mut m = MetricsLogger::new(Some(Path::new("/dev/full"))).unwrap();
        m.log_round(record(0, 1.0, 1));
        let err = m.flush().unwrap_err().to_string();
        assert!(err.contains("metrics log write failed"), "{err}");
        // The record is still retained in memory for summaries.
        assert_eq!(m.rounds.len(), 1);
    }
}
