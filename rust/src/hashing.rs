//! Count-Sketch hash spec, shared bit-for-bit with the Python/Pallas
//! kernels (`python/compile/kernels/hashing.py`).
//!
//! The sketch's bucket and sign hashes must agree *exactly* between the
//! Rust coordinator (which merges sketches, applies momentum/error
//! feedback, and unsketches) and the JAX/Pallas kernel (which sketches
//! gradients inside the AOT-compiled HLO graph). We therefore fix a
//! deliberately simple spec using only u32 wrapping arithmetic, which is
//! native in both `u32` Rust and `uint32` jax.numpy:
//!
//! - columns `C` is a power of two, rows `R` is small and odd;
//! - per row `r`, four u32 constants `(a_b, b_b, a_s, b_s)` are derived
//!   from a master u64 seed via splitmix64 (multipliers forced odd);
//! - `bucket_r(i) = ((a_b * i + b_b) mod 2^32) >> (32 - log2(C))`
//!   (a multiply-shift hash — 2-universal for power-of-two ranges);
//! - `sign_r(i)   = +1 if top bit of (a_s * i + b_s) is 0 else -1`.
//!
//! Changing anything here is a breaking change to every serialized
//! artifact; bump `SPEC_VERSION` and re-run `make artifacts` if you do.

use anyhow::{bail, Result};

use crate::util::rng::splitmix64;

/// Version tag recorded in the artifact manifest; checked at load time.
pub const SPEC_VERSION: u32 = 1;

/// Maximum sketch depth. The unsketch hot path keeps one value per row
/// in a fixed stack buffer (`[f32; MAX_ROWS]`), and production
/// geometries use R in {3, 5}; rejecting deeper tables at construction
/// is what lets every downstream loop iterate `0..rows` unchecked.
pub const MAX_ROWS: usize = 16;

/// Per-row hash constants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowHash {
    pub a_bucket: u32,
    pub b_bucket: u32,
    pub a_sign: u32,
    pub b_sign: u32,
}

/// Hash parameterization for an `R x C` Count Sketch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SketchHasher {
    pub rows: usize,
    pub cols: usize,
    pub seed: u64,
    shift: u32,
    row_hashes: Vec<RowHash>,
}

impl SketchHasher {
    /// Build the hasher. Fails unless `1 <= rows <= MAX_ROWS` and `cols`
    /// is a power of two in `[2, 2^31]` — the bucket hash computes
    /// `(a·i + b) >> (32 - log2(C))`, which silently produces garbage
    /// indices for any non-power-of-two width, so the geometry is
    /// validated here once instead of trusted everywhere.
    pub fn new(rows: usize, cols: usize, seed: u64) -> Result<Self> {
        if rows < 1 || rows > MAX_ROWS {
            bail!("sketch rows must be in [1, {MAX_ROWS}], got {rows}");
        }
        if cols < 2 || !cols.is_power_of_two() {
            bail!("sketch cols must be a power of two >= 2, got {cols}");
        }
        if cols > 1 << 31 {
            bail!("sketch cols {cols} too large for u32 multiply-shift hashing (max 2^31)");
        }
        let shift = 32 - cols.trailing_zeros();
        let mut row_hashes = Vec::with_capacity(rows);
        // Mirror python: state = seed; 4 splitmix64 draws per row, taking
        // the low 32 bits of each; multipliers forced odd.
        let mut state = seed;
        for _ in 0..rows {
            let a_bucket = (splitmix64(&mut state) as u32) | 1;
            let b_bucket = splitmix64(&mut state) as u32;
            let a_sign = (splitmix64(&mut state) as u32) | 1;
            let b_sign = splitmix64(&mut state) as u32;
            row_hashes.push(RowHash { a_bucket, b_bucket, a_sign, b_sign });
        }
        Ok(SketchHasher { rows, cols, seed, shift, row_hashes })
    }

    #[inline]
    pub fn row(&self, r: usize) -> RowHash {
        self.row_hashes[r]
    }

    /// Bucket for coordinate `i` in row `r`.
    #[inline]
    pub fn bucket(&self, r: usize, i: u32) -> usize {
        let h = self.row_hashes[r];
        (h.a_bucket.wrapping_mul(i).wrapping_add(h.b_bucket) >> self.shift) as usize
    }

    /// Sign (+1.0 / -1.0) for coordinate `i` in row `r`.
    #[inline]
    pub fn sign(&self, r: usize, i: u32) -> f32 {
        let h = self.row_hashes[r];
        if h.a_sign.wrapping_mul(i).wrapping_add(h.b_sign) >> 31 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// (bucket, sign) pair — the common access pattern on the hot path.
    #[inline]
    pub fn bucket_sign(&self, r: usize, i: u32) -> (usize, f32) {
        (self.bucket(r, i), self.sign(r, i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let h1 = SketchHasher::new(3, 256, 99).unwrap();
        let h2 = SketchHasher::new(3, 256, 99).unwrap();
        let h3 = SketchHasher::new(3, 256, 100).unwrap();
        for i in 0..1000u32 {
            for r in 0..3 {
                assert_eq!(h1.bucket(r, i), h2.bucket(r, i));
                assert_eq!(h1.sign(r, i), h2.sign(r, i));
            }
        }
        let diffs = (0..1000u32).filter(|&i| h1.bucket(0, i) != h3.bucket(0, i)).count();
        assert!(diffs > 900, "different seeds should disagree, diffs={diffs}");
    }

    #[test]
    fn buckets_in_range_and_roughly_uniform() {
        let cols = 128;
        let h = SketchHasher::new(1, cols, 7).unwrap();
        let mut counts = vec![0usize; cols];
        let n = 128 * 200;
        for i in 0..n as u32 {
            let b = h.bucket(0, i);
            assert!(b < cols);
            counts[b] += 1;
        }
        let expected = n / cols;
        for (b, &c) in counts.iter().enumerate() {
            assert!(
                c > expected / 2 && c < expected * 2,
                "bucket {b} count {c} vs expected {expected}"
            );
        }
    }

    #[test]
    fn signs_balanced_per_row() {
        let h = SketchHasher::new(5, 64, 21).unwrap();
        for r in 0..5 {
            let pos = (0..10_000u32).filter(|&i| h.sign(r, i) > 0.0).count();
            assert!((4000..6000).contains(&pos), "row {r} pos {pos}");
        }
    }

    #[test]
    fn rows_are_independent_ish() {
        let h = SketchHasher::new(2, 64, 5).unwrap();
        let coll = (0..10_000u32).filter(|&i| h.bucket(0, i) == h.bucket(1, i)).count();
        // expect ~1/64 collisions = ~156
        assert!(coll < 500, "rows look correlated: {coll}");
    }

    #[test]
    fn rejects_bad_geometries() {
        // Non-power-of-two widths used to silently hash into garbage
        // buckets (`32 - cols.trailing_zeros()` is meaningless there).
        let err = SketchHasher::new(3, 100, 1).unwrap_err();
        assert!(format!("{err}").contains("power of two"), "{err}");
        assert!(SketchHasher::new(3, 0, 1).is_err());
        assert!(SketchHasher::new(3, 1, 1).is_err());
        // Depth 0 and depth > MAX_ROWS are both rejected up front.
        assert!(SketchHasher::new(0, 64, 1).is_err());
        let err = SketchHasher::new(MAX_ROWS + 1, 64, 1).unwrap_err();
        assert!(format!("{err}").contains("rows"), "{err}");
        assert!(SketchHasher::new(MAX_ROWS, 64, 1).is_ok());
    }

    /// Golden vectors pinning the cross-language spec. The same values
    /// are asserted in python/tests/test_hashing.py — if either test is
    /// changed, both must be.
    #[test]
    fn golden_cross_language_vectors() {
        let h = SketchHasher::new(3, 1 << 12, 0xFE7C_5D11).unwrap();
        let idx = [0u32, 1, 2, 1000, 65_537, 4_000_000_000];
        let buckets: Vec<Vec<usize>> =
            (0..3).map(|r| idx.iter().map(|&i| h.bucket(r, i)).collect()).collect();
        let signs: Vec<Vec<f32>> =
            (0..3).map(|r| idx.iter().map(|&i| h.sign(r, i)).collect()).collect();
        // Print-once values generated from this implementation and
        // independently reproduced by the Python implementation.
        let expected_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("python/tests/golden_hash_vectors.json");
        let text = std::fs::read_to_string(&expected_path)
            .expect("golden_hash_vectors.json missing — run python/tests/gen_golden.py");
        let v = crate::serialize::json::parse(&text).unwrap();
        let gb = v.get("buckets").unwrap().as_array().unwrap();
        let gs = v.get("signs").unwrap().as_array().unwrap();
        for r in 0..3 {
            let row_b: Vec<usize> = gb[r]
                .as_array()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as usize)
                .collect();
            let row_s: Vec<f32> = gs[r]
                .as_array()
                .unwrap()
                .iter()
                .map(|x| x.as_f64().unwrap() as f32)
                .collect();
            assert_eq!(buckets[r], row_b, "bucket row {r}");
            assert_eq!(signs[r], row_s, "sign row {r}");
        }
    }
}
