//! # FetchSGD — communication-efficient federated learning with sketching
//!
//! Production-style reproduction of *FetchSGD: Communication-Efficient
//! Federated Learning with Sketching* (ICML 2020) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the federated coordinator: round loop,
//!   client sampling, Count-Sketch aggregation, momentum and error
//!   accumulation *in sketch space*, top-k extraction, sparse broadcast,
//!   byte accounting, and all baselines (uncompressed SGD, local top-k,
//!   FedAvg, true top-k).
//! - **Layer 2** — JAX model fwd/bwd (`python/compile/model.py`), lowered
//!   once to HLO text and executed here via PJRT (`runtime`).
//! - **Layer 1** — Pallas Count-Sketch kernels
//!   (`python/compile/kernels/`), fused into the same HLO graph.
//!
//! Python never runs on the training path: `make artifacts` lowers the
//! compute graphs ahead of time, and the coordinator is a self-contained
//! binary afterwards.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fetchsgd::config::TrainConfig;
//! use fetchsgd::coordinator::Trainer;
//!
//! let cfg = TrainConfig::default_smoke();
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let summary = trainer.run().unwrap();
//! println!("final loss {:.4}", summary.final_loss);
//! ```

pub mod bench_util;
pub mod cohort;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod hashing;
pub mod metrics;
pub mod model;
pub mod relay;
pub mod runtime;
pub mod serialize;
pub mod sketch;
pub mod trace;
pub mod transport;
pub mod util;
pub mod wire;
