//! Sliding-window error accumulation (paper §4.2, Appendix B.2/D).
//!
//! Theorem 2 requires that signal spread over at most `I` consecutive
//! gradients be recoverable. Vanilla error accumulation sums *all* prior
//! gradients, so noise grows as O(t) and eventually buries window-limited
//! signal. Two schemes fix this:
//!
//! - [`RingWindowSketch`] — the exact construction of Figure 11a: `I`
//!   staggered sketches; sketch `j` is zeroed every `I` steps at offset
//!   `j`, so at any time some sketch holds exactly the last `I'` updates
//!   for every `I' <= I`.
//! - [`LogWindowSketch`] — the Appendix-D-style economy version: one
//!   sketch per power-of-two window (log2(I)+1 total), each zeroed every
//!   `2^j` steps at a staggered phase. This approximates the smooth
//!   histogram of Braverman–Ostrovsky with O(log I) memory: any suffix
//!   window of length `<= I` is covered by a sketch whose span is within
//!   2x of it.
//!
//! Both expose the same surface the server needs: `insert` a sketched
//! update, `top_k` over the union of windows, and `zero_out`/`subtract`
//! applied to all live sketches. The paper's experiments use a single
//! vanilla sketch (§5); ablation abl3 compares all three.

use anyhow::{bail, Result};

use crate::sketch::count_sketch::CountSketch;
use crate::sketch::topk::{top_k_indices, SparseVec};

/// Common interface over error-accumulation backends, so the FetchSGD
/// server can swap vanilla / ring / log window schemes (ablation abl3).
pub trait ErrorAccumulator: Send {
    /// `S_e += scale * update` on every live sketch.
    fn add_scaled(&mut self, update: &CountSketch, scale: f32);
    /// Extract the top-k over the (union of) accumulated signal.
    fn top_k(&mut self, k: usize) -> SparseVec;
    /// Apply the paper's zero-out rule for an extracted Δ.
    fn zero_out(&mut self, delta: &SparseVec);
    /// Apply the subtract rule (Algorithm 1 line 14 exact form).
    fn subtract(&mut self, delta: &SparseVec);
    /// Advance the window clock one round (expire/rotate sketches).
    fn advance(&mut self);
    /// Memory footprint in f32 cells (for reporting).
    fn cells(&self) -> usize;
}

/// Vanilla single-sketch error accumulation — what the paper actually
/// runs in §5.
pub struct VanillaAccumulator {
    pub sketch: CountSketch,
}

impl VanillaAccumulator {
    pub fn new(rows: usize, cols: usize, dim: usize, seed: u64) -> Result<Self> {
        Ok(VanillaAccumulator { sketch: CountSketch::zeros(rows, cols, dim, seed)? })
    }
}

impl ErrorAccumulator for VanillaAccumulator {
    fn add_scaled(&mut self, update: &CountSketch, scale: f32) {
        self.sketch.add_scaled(update, scale);
    }
    fn top_k(&mut self, k: usize) -> SparseVec {
        self.sketch.top_k(k)
    }
    fn zero_out(&mut self, delta: &SparseVec) {
        self.sketch.zero_out_sparse(delta);
    }
    fn subtract(&mut self, delta: &SparseVec) {
        self.sketch.subtract_sparse(delta);
    }
    fn advance(&mut self) {}
    fn cells(&self) -> usize {
        self.sketch.cells()
    }
}

/// Exact ring of `I` staggered sketches (Figure 11a).
pub struct RingWindowSketch {
    sketches: Vec<CountSketch>,
    window: usize,
    t: usize,
}

impl RingWindowSketch {
    pub fn new(rows: usize, cols: usize, dim: usize, seed: u64, window: usize) -> Result<Self> {
        if window < 1 {
            bail!("ring window must be >= 1");
        }
        let sketches = (0..window)
            .map(|_| CountSketch::zeros(rows, cols, dim, seed))
            .collect::<Result<Vec<_>>>()?;
        Ok(RingWindowSketch { sketches, window, t: 0 })
    }

    /// Estimates from the sketch holding the *longest* complete window
    /// (the freshest full view of the last <= I updates).
    fn union_estimates(&self) -> Vec<f32> {
        // Sketch j was last zeroed at the most recent time step s with
        // s % window == j; its content is the sum of updates since then.
        // The longest span is the sketch zeroed furthest in the past:
        // j = (t) % window is freshest (just zeroed), j = (t+1) % window
        // holds the longest history. Coordinate-wise we take the
        // max-|.| estimate across sketches: signal present in any suffix
        // window must be surfaced (FindHeavy queries every sketch and
        // unions the results — Appendix B.2 Implementation).
        let dim = self.sketches[0].dim();
        let mut best = vec![0f32; dim];
        let mut buf = vec![0f32; dim];
        for s in &self.sketches {
            s.estimate_all_into(&mut buf);
            for (b, &e) in best.iter_mut().zip(&buf) {
                if e.abs() > b.abs() {
                    *b = e;
                }
            }
        }
        best
    }
}

impl ErrorAccumulator for RingWindowSketch {
    fn add_scaled(&mut self, update: &CountSketch, scale: f32) {
        for s in self.sketches.iter_mut() {
            s.add_scaled(update, scale);
        }
    }

    fn top_k(&mut self, k: usize) -> SparseVec {
        let est = self.union_estimates();
        let idx = top_k_indices(&est, k);
        SparseVec::from_pairs(est.len(), idx.into_iter().map(|i| (i, est[i as usize])).collect())
    }

    fn zero_out(&mut self, delta: &SparseVec) {
        for s in self.sketches.iter_mut() {
            s.zero_out_sparse(delta);
        }
    }

    fn subtract(&mut self, delta: &SparseVec) {
        for s in self.sketches.iter_mut() {
            s.subtract_sparse(delta);
        }
    }

    fn advance(&mut self) {
        self.t += 1;
        let j = self.t % self.window;
        self.sketches[j].clear();
    }

    fn cells(&self) -> usize {
        self.sketches.iter().map(|s| s.cells()).sum()
    }
}

/// O(log I) sketches: sketch `j` covers a window of `2^j` rounds
/// (zeroed every `2^j` advances, phase-staggered by construction of the
/// counter). Any suffix window of length `L <= I` is covered by the
/// sketch with `2^j >= L` whose last reset is at most `2^j` old — a
/// 2-approximation of the exact ring in window span, following the
/// smooth-histogram idea (Braverman–Ostrovsky 2007) specialized to our
/// reset-based accumulation.
pub struct LogWindowSketch {
    sketches: Vec<CountSketch>,
    periods: Vec<usize>,
    t: usize,
}

impl LogWindowSketch {
    pub fn new(rows: usize, cols: usize, dim: usize, seed: u64, window: usize) -> Result<Self> {
        if window < 1 {
            bail!("log window must be >= 1");
        }
        let levels = (usize::BITS - window.next_power_of_two().leading_zeros()) as usize;
        let mut sketches = Vec::new();
        let mut periods = Vec::new();
        for j in 0..levels.max(1) {
            sketches.push(CountSketch::zeros(rows, cols, dim, seed)?);
            periods.push(1usize << j);
        }
        Ok(LogWindowSketch { sketches, periods, t: 0 })
    }

    pub fn num_sketches(&self) -> usize {
        self.sketches.len()
    }
}

impl ErrorAccumulator for LogWindowSketch {
    fn add_scaled(&mut self, update: &CountSketch, scale: f32) {
        for s in self.sketches.iter_mut() {
            s.add_scaled(update, scale);
        }
    }

    fn top_k(&mut self, k: usize) -> SparseVec {
        let dim = self.sketches[0].dim();
        let mut best = vec![0f32; dim];
        let mut buf = vec![0f32; dim];
        for s in &self.sketches {
            s.estimate_all_into(&mut buf);
            for (b, &e) in best.iter_mut().zip(&buf) {
                if e.abs() > b.abs() {
                    *b = e;
                }
            }
        }
        let idx = top_k_indices(&best, k);
        SparseVec::from_pairs(dim, idx.into_iter().map(|i| (i, best[i as usize])).collect())
    }

    fn zero_out(&mut self, delta: &SparseVec) {
        for s in self.sketches.iter_mut() {
            s.zero_out_sparse(delta);
        }
    }

    fn subtract(&mut self, delta: &SparseVec) {
        for s in self.sketches.iter_mut() {
            s.subtract_sparse(delta);
        }
    }

    fn advance(&mut self) {
        self.t += 1;
        for (s, &p) in self.sketches.iter_mut().zip(&self.periods) {
            if self.t % p == 0 {
                s.clear();
            }
        }
    }

    fn cells(&self) -> usize {
        self.sketches.iter().map(|s| s.cells()).sum()
    }
}

/// Factory used by config (`error_window = "vanilla" | "ring:I" | "log:I"`).
pub fn make_accumulator(
    kind: &str,
    rows: usize,
    cols: usize,
    dim: usize,
    seed: u64,
) -> Result<Box<dyn ErrorAccumulator>> {
    if kind == "vanilla" {
        return Ok(Box::new(VanillaAccumulator::new(rows, cols, dim, seed)?));
    }
    if let Some(rest) = kind.strip_prefix("ring:") {
        let i: usize = rest.parse()?;
        return Ok(Box::new(RingWindowSketch::new(rows, cols, dim, seed, i)?));
    }
    if let Some(rest) = kind.strip_prefix("log:") {
        let i: usize = rest.parse()?;
        return Ok(Box::new(LogWindowSketch::new(rows, cols, dim, seed, i)?));
    }
    bail!("unknown error accumulator kind '{kind}' (vanilla | ring:I | log:I)")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sketch_of(d: usize, pairs: &[(u32, f32)]) -> CountSketch {
        let sv = SparseVec::from_pairs(d, pairs.to_vec());
        let mut s = CountSketch::zeros(5, 512, d, 13).unwrap();
        s.accumulate_sparse(&sv, 1.0);
        s
    }

    #[test]
    fn ring_window_forgets_old_noise_but_keeps_window_signal() {
        let d = 2000;
        let window = 4;
        let mut ring = RingWindowSketch::new(5, 512, d, 13, window).unwrap();
        // Inject weak signal at coord 100 for `window` consecutive steps:
        // individually small, heavy in the window sum.
        for _ in 0..window {
            let up = sketch_of(d, &[(100, 2.0)]);
            ring.add_scaled(&up, 1.0);
            ring.advance();
        }
        let top = ring.top_k(1);
        assert_eq!(top.idx, vec![100]);
        assert!(top.val[0] > 4.0, "window-summed signal visible: {}", top.val[0]);
    }

    #[test]
    fn ring_window_expires_signal_older_than_window() {
        let d = 2000;
        let window = 3;
        let mut ring = RingWindowSketch::new(5, 512, d, 13, window).unwrap();
        let up = sketch_of(d, &[(55, 10.0)]);
        ring.add_scaled(&up, 1.0);
        // Advance far past the window with zero updates.
        for _ in 0..(3 * window) {
            ring.advance();
        }
        let est = ring.union_estimates();
        assert!(est[55].abs() < 1e-6, "signal should have expired: {}", est[55]);
    }

    #[test]
    fn log_window_uses_log_many_sketches() {
        let lw = LogWindowSketch::new(3, 128, 100, 1, 16).unwrap();
        assert_eq!(lw.num_sketches(), 5); // windows 1,2,4,8,16
        let lw1 = LogWindowSketch::new(3, 128, 100, 1, 1).unwrap();
        assert_eq!(lw1.num_sketches(), 1);
    }

    #[test]
    fn log_window_covers_window_signal() {
        let d = 2000;
        let mut lw = LogWindowSketch::new(5, 512, d, 13, 8).unwrap();
        for _ in 0..6 {
            let up = sketch_of(d, &[(70, 1.5)]);
            lw.add_scaled(&up, 1.0);
            lw.advance();
        }
        let top = lw.top_k(1);
        assert_eq!(top.idx, vec![70]);
    }

    #[test]
    fn vanilla_never_forgets() {
        let d = 500;
        let mut v = VanillaAccumulator::new(5, 512, d, 13).unwrap();
        let up = sketch_of(d, &[(9, 3.0)]);
        v.add_scaled(&up, 1.0);
        for _ in 0..20 {
            v.advance();
        }
        let top = v.top_k(1);
        assert_eq!(top.idx, vec![9]);
    }

    #[test]
    fn zero_out_applies_to_all_window_sketches() {
        let d = 500;
        let mut ring = RingWindowSketch::new(5, 512, d, 13, 4).unwrap();
        let up = sketch_of(d, &[(9, 30.0)]);
        ring.add_scaled(&up, 1.0);
        let delta = ring.top_k(1);
        ring.zero_out(&delta);
        let est = ring.union_estimates();
        assert!(est[9].abs() < 1e-6);
    }

    #[test]
    fn factory_parses_kinds() {
        assert!(make_accumulator("vanilla", 3, 64, 10, 1).is_ok());
        assert!(make_accumulator("ring:4", 3, 64, 10, 1).is_ok());
        assert!(make_accumulator("log:16", 3, 64, 10, 1).is_ok());
        assert!(make_accumulator("bogus", 3, 64, 10, 1).is_err());
    }

    #[test]
    fn memory_footprints_ordered() {
        let v = VanillaAccumulator::new(3, 64, 10, 1).unwrap();
        let ring = RingWindowSketch::new(3, 64, 10, 1, 16).unwrap();
        let log = LogWindowSketch::new(3, 64, 10, 1, 16).unwrap();
        assert!(v.cells() < log.cells());
        assert!(log.cells() < ring.cells());
    }
}
