//! Count-Sketch data structures (the paper's compression operator).
//!
//! - [`count_sketch::CountSketch`] — the linear sketch: encode, merge,
//!   scale, unsketch (coordinate estimation), top-k extraction, and the
//!   two error-feedback update rules from the paper (subtract vs
//!   zero-out).
//! - [`sliding`] — sliding-window error accumulation (Theorem 2): the
//!   exact ring-of-`I` scheme from Appendix B.2/Figure 11a and the
//!   `log(I)`-sketch variant sketched in Appendix D.
//! - [`topk`] — top-k selection utilities shared by the sketch and the
//!   (local/true) top-k baselines.

pub mod count_sketch;
pub mod sliding;
pub mod topk;

pub use count_sketch::CountSketch;
pub use topk::{top_k_indices, SparseVec};
