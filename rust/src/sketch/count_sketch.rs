//! The Count Sketch (Charikar–Chen–Farach-Colton 2002) as used by
//! FetchSGD: a linear `R x C` table of f32 counters with per-row bucket
//! and sign hashes.
//!
//! Linearity — `S(a·x + b·y) = a·S(x) + b·S(y)` — is what lets the
//! FetchSGD server merge client sketches and carry momentum and error
//! accumulation entirely in sketch space (paper §3.2). This struct is
//! used on the server hot path every round: merge W client sketches,
//! momentum/error updates, `Top-k(U(S_e))`, and the zero-out update.
//!
//! Construction is fallible: the sketch geometry (power-of-two width,
//! depth <= [`crate::hashing::MAX_ROWS`]) is validated once by
//! [`crate::hashing::SketchHasher`], so the hot-path loops can trust it.
//!
//! The linear ops (`add_scaled` / `scale` / `clear`) also come in
//! row-strip variants so callers can chunk work over rows, and
//! [`CountSketch::merge_shards`] is the fan-in primitive the parallel
//! round engine uses to reduce per-worker scratch sketches in a fixed
//! deterministic order.
//!
//! The hash spec (`crate::hashing`) is shared bit-for-bit with the Pallas
//! kernel so sketches produced inside the AOT HLO graph and sketches
//! produced here are interchangeable.

use std::ops::Range;

use anyhow::{bail, Result};

use crate::hashing::SketchHasher;
use crate::sketch::topk::{top_k_indices, SparseVec};

/// An `R x C` Count Sketch over vectors of dimension `dim`.
#[derive(Clone, Debug)]
pub struct CountSketch {
    hasher: SketchHasher,
    /// Row-major `rows x cols` table.
    table: Vec<f32>,
    /// Dimension of the vectors this sketch compresses.
    dim: usize,
}

impl CountSketch {
    /// Fresh zero sketch. Errors on invalid geometry (non-power-of-two
    /// `cols`, or `rows` outside `[1, MAX_ROWS]`).
    pub fn zeros(rows: usize, cols: usize, dim: usize, seed: u64) -> Result<Self> {
        let hasher = SketchHasher::new(rows, cols, seed)?;
        Ok(CountSketch { hasher, table: vec![0f32; rows * cols], dim })
    }

    /// Sketch a dense vector: `S(g)`.
    pub fn encode(rows: usize, cols: usize, seed: u64, g: &[f32]) -> Result<Self> {
        let mut s = Self::zeros(rows, cols, g.len(), seed)?;
        s.accumulate_dense(g, 1.0);
        Ok(s)
    }

    /// Construct from an existing table (e.g. the sketch output of the
    /// AOT client-step executable). `table` is row-major `rows x cols`.
    pub fn from_table(
        rows: usize,
        cols: usize,
        dim: usize,
        seed: u64,
        table: Vec<f32>,
    ) -> Result<Self> {
        let hasher = SketchHasher::new(rows, cols, seed)?;
        if table.len() != rows * cols {
            bail!("sketch table has {} cells, expected {rows}x{cols}", table.len());
        }
        Ok(CountSketch { hasher, table, dim })
    }

    pub fn rows(&self) -> usize {
        self.hasher.rows
    }
    pub fn cols(&self) -> usize {
        self.hasher.cols
    }
    pub fn dim(&self) -> usize {
        self.dim
    }
    pub fn seed(&self) -> u64 {
        self.hasher.seed
    }
    pub fn table(&self) -> &[f32] {
        &self.table
    }

    /// Mutable view of the raw table — the wire absorb path
    /// (`compression::aggregate::RoundAccum::absorb_bytes`) folds
    /// decoded frame values straight into the cells. Crate-internal:
    /// external callers go through the linear ops, which preserve the
    /// geometry invariants.
    pub(crate) fn table_mut(&mut self) -> &mut [f32] {
        &mut self.table
    }
    pub fn hasher(&self) -> &SketchHasher {
        &self.hasher
    }

    /// Number of f32 cells (the upload payload size of one client sketch).
    pub fn cells(&self) -> usize {
        self.table.len()
    }

    /// Bytes on the wire for one sketch upload.
    pub fn payload_bytes(&self) -> u64 {
        4 * self.table.len() as u64
    }

    fn assert_compatible(&self, other: &CountSketch) {
        assert_eq!(self.hasher, other.hasher, "sketch hash spec mismatch");
        assert_eq!(self.dim, other.dim, "sketch dim mismatch");
    }

    /// `self += scale * g` for a dense vector `g` (linearity lets callers
    /// accumulate many vectors into one sketch).
    ///
    /// Row-major sweep: per sketch row, one pass over `g` scattering
    /// into that row's `C·4`-byte strip. §Perf iteration 2 tried the
    /// single-pass element-major variant (read `g` once, update all R
    /// rows); it measured 2.2x *slower* (scattered writes across R row
    /// strips defeat the write-combining the per-row sweep gets), so the
    /// row-major form stays.
    pub fn accumulate_dense(&mut self, g: &[f32], scale: f32) {
        assert_eq!(g.len(), self.dim, "vector dim mismatch");
        let cols = self.cols();
        let shift = 32 - cols.trailing_zeros();
        for r in 0..self.rows() {
            let row = &mut self.table[r * cols..(r + 1) * cols];
            let h = self.hasher.row(r);
            // Vectorized multiply-shift hashing with a scalar in-order
            // scatter (see `util::simd` for the bitwise contract).
            crate::util::simd::accumulate_row(row, h, shift, g, scale);
        }
    }

    /// `self += scale * sv` for a sparse vector.
    ///
    /// Same hoisted per-row hash form as [`accumulate_dense`]
    /// (`RowHash` fetched once per row, zero entries skipped), instead
    /// of the historical per-(row, element) `bucket_sign` calls. The
    /// hoist is bitwise-neutral: `(±v) * scale` computes the same bits
    /// as the old `sgn * v * scale` for every non-NaN `v` (sign flips
    /// are exact), and a skipped `±0.0` entry contributed exactly
    /// nothing before (`±0.0 * scale` adds as zero).
    pub fn accumulate_sparse(&mut self, sv: &SparseVec, scale: f32) {
        assert_eq!(sv.dim, self.dim);
        let cols = self.cols();
        let shift = 32 - cols.trailing_zeros();
        for r in 0..self.rows() {
            let row = &mut self.table[r * cols..(r + 1) * cols];
            let h = self.hasher.row(r);
            crate::util::simd::accumulate_row_sparse(row, h, shift, &sv.idx, &sv.val, scale);
        }
    }

    /// `self += scale * other` (sketch-space linear combination).
    pub fn add_scaled(&mut self, other: &CountSketch, scale: f32) {
        self.assert_compatible(other);
        self.add_scaled_rows(other, scale, 0..self.rows());
    }

    /// `self[rows] += scale * other[rows]` over a strip of rows only —
    /// the chunked form, letting callers split one merge across workers
    /// by row strip while keeping per-cell op order identical to the
    /// full-table call.
    pub fn add_scaled_rows(&mut self, other: &CountSketch, scale: f32, rows: Range<usize>) {
        self.assert_compatible(other);
        debug_assert!(rows.end <= self.rows());
        let cols = self.cols();
        let span = rows.start * cols..rows.end * cols;
        // Blocked kernel: same per-cell `+= scale * b` in the same
        // order as the scalar zip it replaced (§Perf, PR 6), so bits
        // don't move.
        crate::util::kernels::axpy(&mut self.table[span.clone()], &other.table[span], scale);
    }

    /// `dst_strip += self[rows]` where `dst_strip` is another table's
    /// slice for exactly the row range `rows` — the split-borrow form of
    /// [`CountSketch::add_scaled_rows`] (at scale 1) that the row-strip-
    /// parallel fan-in needs: workers hold disjoint `&mut` strips of one
    /// destination table and each folds its strip from every shard in
    /// shard order. Per cell this is the same `+=` as the whole-table
    /// merge, so any strip partition produces identical bits.
    pub fn add_rows_to(&self, dst_strip: &mut [f32], rows: Range<usize>) {
        debug_assert!(rows.end <= self.rows());
        let cols = self.cols();
        let span = rows.start * cols..rows.end * cols;
        debug_assert_eq!(dst_strip.len(), span.len(), "strip/span length mismatch");
        crate::util::kernels::add(dst_strip, &self.table[span]);
    }

    /// `self *= scale` (e.g. momentum decay `rho * S_u`).
    pub fn scale(&mut self, scale: f32) {
        self.scale_rows(scale, 0..self.rows());
    }

    /// `self[rows] *= scale` over a strip of rows only. Cells are
    /// independent, so the kernelized form cannot reorder anything.
    pub fn scale_rows(&mut self, scale: f32, rows: Range<usize>) {
        debug_assert!(rows.end <= self.rows());
        let cols = self.cols();
        crate::util::kernels::scale(&mut self.table[rows.start * cols..rows.end * cols], scale);
    }

    /// Reset to the zero sketch (reuses the allocation).
    pub fn clear(&mut self) {
        self.clear_rows(0..self.rows());
    }

    /// Zero a strip of rows only.
    pub fn clear_rows(&mut self, rows: Range<usize>) {
        debug_assert!(rows.end <= self.rows());
        let cols = self.cols();
        self.table[rows.start * cols..rows.end * cols].iter_mut().for_each(|x| *x = 0.0);
    }

    /// Fan-in primitive for the parallel round engine: `self += Σ shards`,
    /// reduced strictly in slice order so the result is bitwise
    /// reproducible for a fixed shard layout regardless of how many
    /// worker threads produced the shards.
    ///
    /// The sweep is row-strip-major (for each row, add that row from
    /// every shard) so the destination strip stays hot in cache across
    /// the whole fan-in; per cell this performs the same
    /// `(((self + s0) + s1) + ...)` additions as calling
    /// [`CountSketch::add_scaled`] once per shard in order.
    pub fn merge_shards(&mut self, shards: &[CountSketch]) {
        let refs: Vec<&CountSketch> = shards.iter().collect();
        self.merge_shard_refs(&refs);
    }

    /// [`CountSketch::merge_shards`] over borrowed shards — the form the
    /// round engine's reusable scratch accumulators need (the shards
    /// stay alive, and allocated, for the next round).
    pub fn merge_shard_refs(&mut self, shards: &[&CountSketch]) {
        for sh in shards {
            self.assert_compatible(sh);
        }
        let cols = self.cols();
        for r in 0..self.rows() {
            let dst = &mut self.table[r * cols..(r + 1) * cols];
            for sh in shards {
                sh.add_rows_to(dst, r..r + 1);
            }
        }
    }

    /// Unbiased point estimate of coordinate `i`: median over rows of
    /// `sign_r(i) * table[r][bucket_r(i)]`.
    pub fn estimate(&self, i: u32) -> f32 {
        debug_assert!((i as usize) < self.dim);
        let cols = self.cols();
        // Construction guarantees rows <= MAX_ROWS, so the stack buffer
        // covers every row (no silent truncation).
        let mut vals = [0f32; crate::hashing::MAX_ROWS];
        let rows = self.rows();
        for r in 0..rows {
            let (b, sgn) = self.hasher.bucket_sign(r, i);
            vals[r] = sgn * self.table[r * cols + b];
        }
        median_in_place(&mut vals[..rows])
    }

    /// Estimate every coordinate: `U(S)` from the paper. This is the
    /// server's unsketch hot path (O(d·R)); see benches/bench_sketch.rs.
    pub fn estimate_all(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.dim];
        self.estimate_all_into(&mut out);
        out
    }

    /// `estimate_all` into a caller-provided buffer (hot-path variant
    /// that avoids per-round allocation).
    pub fn estimate_all_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        let rows = self.rows();
        let cols = self.cols();
        let shift = 32 - cols.trailing_zeros();
        // Row-major sweep per row keeps the table row hot in cache; the
        // per-coordinate medians are computed from a transposed scratch
        // strip to avoid d*R random accesses. Strips of 4096 coords.
        const STRIP: usize = 4096;
        let mut scratch = vec![0f32; rows * STRIP];
        let mut vals = [0f32; crate::hashing::MAX_ROWS];
        let mut start = 0;
        while start < self.dim {
            let len = STRIP.min(self.dim - start);
            for r in 0..rows {
                let h = self.hasher.row(r);
                let row = &self.table[r * cols..(r + 1) * cols];
                let dst = &mut scratch[r * STRIP..r * STRIP + len];
                for (j, d) in dst.iter_mut().enumerate() {
                    let iu = (start + j) as u32;
                    let b =
                        (h.a_bucket.wrapping_mul(iu).wrapping_add(h.b_bucket) >> shift) as usize;
                    let neg = h.a_sign.wrapping_mul(iu).wrapping_add(h.b_sign) >> 31;
                    let v = row[b];
                    *d = if neg == 0 { v } else { -v };
                }
            }
            // Median reduction. rows==5 and rows==3 (the production
            // geometries) use branchless median networks — measured ~3x
            // faster than the generic per-coordinate sort (§Perf).
            match rows {
                5 => {
                    let (s0, rest) = scratch.split_at(STRIP);
                    let (s1, rest) = rest.split_at(STRIP);
                    let (s2, rest) = rest.split_at(STRIP);
                    let (s3, rest) = rest.split_at(STRIP);
                    let s4 = rest;
                    for j in 0..len {
                        out[start + j] = median5(s0[j], s1[j], s2[j], s3[j], s4[j]);
                    }
                }
                3 => {
                    let (s0, rest) = scratch.split_at(STRIP);
                    let (s1, s2) = rest.split_at(STRIP);
                    for j in 0..len {
                        out[start + j] = median3(s0[j], s1[j], s2[j]);
                    }
                }
                _ => {
                    for j in 0..len {
                        for r in 0..rows {
                            vals[r] = scratch[r * STRIP + j];
                        }
                        out[start + j] = median_in_place(&mut vals[..rows]);
                    }
                }
            }
            start += len;
        }
    }

    /// Pre-optimization `estimate_all` (generic per-coordinate median
    /// sort, no median network). Kept for the §Perf before/after bench
    /// and as the fallback for unusual row counts.
    #[doc(hidden)]
    pub fn estimate_all_into_generic(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        let rows = self.rows();
        let cols = self.cols();
        let shift = 32 - cols.trailing_zeros();
        let mut vals = [0f32; crate::hashing::MAX_ROWS];
        for (i, o) in out.iter_mut().enumerate() {
            let iu = i as u32;
            for r in 0..rows {
                let h = self.hasher.row(r);
                let b = (h.a_bucket.wrapping_mul(iu).wrapping_add(h.b_bucket) >> shift) as usize;
                let neg = h.a_sign.wrapping_mul(iu).wrapping_add(h.b_sign) >> 31;
                let v = self.table[r * cols + b];
                vals[r] = if neg == 0 { v } else { -v };
            }
            *o = median_in_place(&mut vals[..rows]);
        }
    }

    /// `Top-k(U(S))`: the k highest-magnitude coordinate estimates as a
    /// sparse vector (FetchSGD's model update Δ).
    pub fn top_k(&self, k: usize) -> SparseVec {
        let est = self.estimate_all();
        let idx = top_k_indices(&est, k);
        SparseVec::from_pairs(self.dim, idx.into_iter().map(|i| (i, est[i as usize])).collect())
    }

    /// Error-feedback update, paper Algorithm 1 line 14 (exact form):
    /// `S_e -= S(Δ)`.
    pub fn subtract_sparse(&mut self, delta: &SparseVec) {
        self.accumulate_sparse(delta, -1.0);
    }

    /// Error-feedback update as actually run in the paper's experiments
    /// (§5): *zero out* every cell that `S(Δ)` touches, instead of
    /// subtracting. Empirically stabilizes optimization.
    pub fn zero_out_sparse(&mut self, delta: &SparseVec) {
        let cols = self.cols();
        for r in 0..self.rows() {
            for &i in &delta.idx {
                let b = self.hasher.bucket(r, i);
                self.table[r * cols + b] = 0.0;
            }
        }
    }

    /// Median-of-rows estimate of ||g||^2 (AMS-style): used by tests and
    /// diagnostics.
    pub fn l2_estimate(&self) -> f64 {
        let cols = self.cols();
        let mut norms: Vec<f64> = (0..self.rows())
            .map(|r| {
                self.table[r * cols..(r + 1) * cols]
                    .iter()
                    .map(|&x| x as f64 * x as f64)
                    .sum::<f64>()
            })
            .collect();
        norms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        norms[norms.len() / 2].sqrt()
    }
}

/// Branchless median of 3.
#[inline(always)]
fn median3(a: f32, b: f32, c: f32) -> f32 {
    a.max(b).min(a.min(b).max(c))
}

/// Median of 5 via the classic 6-comparison network.
///
/// Sort (a,b) and (c,d); make `a` the smaller pair-minimum (so `a` is
/// at most second-smallest overall and can never be the median);
/// discarding `a`, the answer is the 2nd smallest of {b, e, c, d} with
/// the sorted-pair identity `min(max(lo1, lo2), min(hi1, hi2))`.
#[inline(always)]
fn median5(mut a: f32, mut b: f32, mut c: f32, mut d: f32, mut e: f32) -> f32 {
    #[inline(always)]
    fn cswap(x: &mut f32, y: &mut f32) {
        let lo = x.min(*y);
        let hi = x.max(*y);
        *x = lo;
        *y = hi;
    }
    cswap(&mut a, &mut b); // a <= b
    cswap(&mut c, &mut d); // c <= d
    if a > c {
        std::mem::swap(&mut a, &mut c);
        std::mem::swap(&mut b, &mut d);
    }
    // a = min of {a,b,c,d}: discard; need 2nd smallest of {b,e} ∪ {c,d}
    cswap(&mut b, &mut e); // b <= e
    b.max(c).min(e.min(d))
}

/// Median of a small slice, in place. For even n returns the lower-middle
/// average (matching `jnp.median` for the R=2 edge case is unnecessary —
/// production sketches use odd R; we still average to be safe).
fn median_in_place(v: &mut [f32]) -> f32 {
    debug_assert!(!v.is_empty());
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::stats::l2_norm;

    const R: usize = 5;
    const C: usize = 512;
    const SEED: u64 = 0xABCD;

    #[test]
    fn single_heavy_coordinate_recovered_exactly() {
        let d = 10_000;
        let mut g = vec![0f32; d];
        g[1234] = 7.5;
        let s = CountSketch::encode(R, C, SEED, &g).unwrap();
        assert!((s.estimate(1234) - 7.5).abs() < 1e-6);
        // all other estimates should be 0 or +-7.5 only on colliding rows;
        // median kills them since collisions across >=3 of 5 rows are
        // vanishingly unlikely.
        let est = s.estimate_all();
        let big = est.iter().enumerate().filter(|(_, v)| v.abs() > 1.0).count();
        assert_eq!(big, 1, "only the planted coordinate is heavy");
    }

    #[test]
    fn rejects_invalid_geometry_at_construction() {
        // Regression: depth used to be silently capped at 16 inside
        // `estimate` (rows beyond the stack buffer were dropped from the
        // median); now any un-representable depth is a construction error.
        let err = CountSketch::zeros(17, 64, 100, 1).unwrap_err();
        assert!(format!("{err}").contains("rows"), "{err}");
        assert!(CountSketch::zeros(16, 64, 100, 1).is_ok());
        // Non-power-of-two width is an error, not garbage buckets.
        let err = CountSketch::zeros(5, 100, 100, 1).unwrap_err();
        assert!(format!("{err}").contains("power of two"), "{err}");
        assert!(CountSketch::encode(5, 96, 1, &[1.0; 8]).is_err());
        assert!(CountSketch::from_table(3, 24, 8, 1, vec![0.0; 72]).is_err());
        // from_table additionally validates the cell count.
        let err = CountSketch::from_table(3, 64, 8, 1, vec![0.0; 10]).unwrap_err();
        assert!(format!("{err}").contains("cells"), "{err}");
    }

    #[test]
    fn deep_sketch_estimates_use_every_row() {
        // With the old 16-row cap this sketch would estimate from a
        // truncated median; at exactly MAX_ROWS all rows participate.
        let d = 500;
        let mut g = vec![0f32; d];
        g[7] = 3.0;
        let s = CountSketch::encode(crate::hashing::MAX_ROWS, 256, 3, &g).unwrap();
        assert!((s.estimate(7) - 3.0).abs() < 1e-6);
        let all = s.estimate_all();
        assert_eq!(all[7], s.estimate(7));
    }

    #[test]
    fn linearity_encode_of_sum_equals_sum_of_encodes() {
        check("sketch linearity", 30, |g| {
            let d = g.usize_in(10, 2000);
            let a = g.vec_f32(d, d + 1, -5.0, 5.0);
            let b = g.vec_f32(d, d + 1, -5.0, 5.0);
            let sum: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
            let mut sa = CountSketch::encode(3, 256, 7, &a).unwrap();
            let sb = CountSketch::encode(3, 256, 7, &b).unwrap();
            let ssum = CountSketch::encode(3, 256, 7, &sum).unwrap();
            sa.add_scaled(&sb, 1.0);
            for (x, y) in sa.table().iter().zip(ssum.table()) {
                assert!((x - y).abs() < 1e-4, "linearity violated: {x} vs {y}");
            }
        });
    }

    #[test]
    fn merge_of_client_sketches_equals_sketch_of_mean() {
        // The aggregation step the server performs every round.
        check("merge = sketch of mean", 20, |g| {
            let d = 500;
            let w = g.usize_in(2, 8);
            let grads: Vec<Vec<f32>> = (0..w).map(|_| g.vec_f32(d, d + 1, -1.0, 1.0)).collect();
            let mut agg = CountSketch::zeros(3, 128, d, 99).unwrap();
            for gr in &grads {
                let s = CountSketch::encode(3, 128, 99, gr).unwrap();
                agg.add_scaled(&s, 1.0 / w as f32);
            }
            let mean: Vec<f32> = (0..d)
                .map(|i| grads.iter().map(|gr| gr[i]).sum::<f32>() / w as f32)
                .collect();
            let direct = CountSketch::encode(3, 128, 99, &mean).unwrap();
            for (x, y) in agg.table().iter().zip(direct.table()) {
                assert!((x - y).abs() < 1e-4);
            }
        });
    }

    #[test]
    fn merge_shards_is_bitwise_sequential_fan_in() {
        let d = 4000;
        let mut rng = crate::util::Rng::new(31);
        let shards: Vec<CountSketch> = (0..6)
            .map(|_| {
                let g: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
                CountSketch::encode(5, 512, 9, &g).unwrap()
            })
            .collect();
        let mut via_merge = CountSketch::zeros(5, 512, d, 9).unwrap();
        via_merge.merge_shards(&shards);
        let mut via_adds = CountSketch::zeros(5, 512, d, 9).unwrap();
        for s in &shards {
            via_adds.add_scaled(s, 1.0);
        }
        for (a, b) in via_merge.table().iter().zip(via_adds.table()) {
            assert_eq!(a.to_bits(), b.to_bits(), "merge_shards must match ordered adds exactly");
        }
    }

    #[test]
    fn row_strip_ops_compose_to_full_table_ops() {
        let d = 2000;
        let mut rng = crate::util::Rng::new(77);
        let g: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let other = CountSketch::encode(5, 256, 4, &g).unwrap();

        let mut whole = CountSketch::encode(5, 256, 4, &g).unwrap();
        let mut strips = whole.clone();
        whole.add_scaled(&other, 0.5);
        strips.add_scaled_rows(&other, 0.5, 0..2);
        strips.add_scaled_rows(&other, 0.5, 2..5);
        assert_eq!(whole.table(), strips.table());

        whole.scale(0.25);
        strips.scale_rows(0.25, 0..1);
        strips.scale_rows(0.25, 1..5);
        assert_eq!(whole.table(), strips.table());

        whole.clear();
        strips.clear_rows(0..3);
        strips.clear_rows(3..5);
        assert_eq!(whole.table(), strips.table());
    }

    #[test]
    fn sparse_and_dense_accumulate_agree() {
        check("sparse == dense accumulate", 20, |g| {
            let d = g.usize_in(50, 500);
            let mut dense = vec![0f32; d];
            let nnz = g.usize_in(1, 20.min(d));
            let mut pairs = Vec::new();
            for _ in 0..nnz {
                let i = g.usize_in(0, d) as u32;
                if pairs.iter().any(|&(j, _)| j == i) {
                    continue;
                }
                let v = g.f32_in(-3.0, 3.0);
                pairs.push((i, v));
                dense[i as usize] = v;
            }
            let sv = SparseVec::from_pairs(d, pairs);
            let s1 = CountSketch::encode(3, 64, 5, &dense).unwrap();
            let mut s2 = CountSketch::zeros(3, 64, d, 5).unwrap();
            s2.accumulate_sparse(&sv, 1.0);
            for (x, y) in s1.table().iter().zip(s2.table()) {
                assert!((x - y).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn estimates_are_accurate_for_heavy_hitters() {
        // Heavy hitters over Gaussian noise: the regime of Definition 1.
        check("heavy hitter recovery", 10, |g| {
            let d = 20_000;
            let v = g.heavy_vec(d, 10, 10.0, 0.05);
            let s = CountSketch::encode(5, 2048, 42, &v).unwrap();
            let norm = l2_norm(&v);
            for (i, &x) in v.iter().enumerate() {
                if x.abs() > 5.0 {
                    let e = s.estimate(i as u32);
                    assert!(
                        (e - x).abs() < 0.15 * norm as f32,
                        "coord {i}: est {e} vs true {x} (norm {norm})"
                    );
                }
            }
        });
    }

    #[test]
    fn top_k_finds_planted_heavy_coordinates() {
        let d = 50_000;
        let mut g = vec![0f32; d];
        let planted: Vec<u32> = vec![3, 777, 12_345, 40_000, 49_999];
        for (j, &i) in planted.iter().enumerate() {
            g[i as usize] = 50.0 * (1.0 + j as f32);
        }
        // small noise
        let mut rng = crate::util::Rng::new(8);
        for x in g.iter_mut() {
            *x += rng.next_gaussian() as f32 * 0.01;
        }
        let s = CountSketch::encode(5, 4096, 17, &g).unwrap();
        let top = s.top_k(5);
        let mut got = top.idx.clone();
        got.sort();
        assert_eq!(got, planted);
    }

    #[test]
    fn zero_out_removes_extracted_signal() {
        let d = 1000;
        let mut g = vec![0f32; d];
        g[10] = 100.0;
        g[20] = -80.0;
        let mut s = CountSketch::encode(5, 512, 3, &g).unwrap();
        let delta = s.top_k(2);
        s.zero_out_sparse(&delta);
        assert!(s.estimate(10).abs() < 1e-3);
        assert!(s.estimate(20).abs() < 1e-3);
    }

    #[test]
    fn subtract_sparse_removes_signal_up_to_estimation_error() {
        let d = 1000;
        let mut g = vec![0f32; d];
        g[10] = 100.0;
        let mut s = CountSketch::encode(5, 512, 3, &g).unwrap();
        let delta = s.top_k(1);
        assert_eq!(delta.idx, vec![10]);
        s.subtract_sparse(&delta);
        assert!(s.estimate(10).abs() < 1.0);
    }

    #[test]
    fn scale_and_clear() {
        let g = vec![1f32; 100];
        let mut s = CountSketch::encode(3, 64, 1, &g).unwrap();
        let before: f32 = s.table().iter().map(|x| x.abs()).sum();
        s.scale(0.5);
        let after: f32 = s.table().iter().map(|x| x.abs()).sum();
        assert!((after - before * 0.5).abs() < 1e-3);
        s.clear();
        assert!(s.table().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn l2_estimate_tracks_true_norm() {
        check("l2 estimate", 10, |g| {
            let v = g.vec_f32(5000, 5001, -1.0, 1.0);
            let s = CountSketch::encode(5, 4096, 23, &v).unwrap();
            let est = s.l2_estimate();
            let truth = l2_norm(&v);
            assert!(
                (est - truth).abs() / truth < 0.25,
                "l2 est {est} vs {truth}"
            );
        });
    }

    #[test]
    fn estimate_all_into_matches_estimate() {
        let mut rng = crate::util::Rng::new(77);
        let d = 3000;
        let v: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let s = CountSketch::encode(5, 1024, 6, &v).unwrap();
        let all = s.estimate_all();
        for i in (0..d).step_by(97) {
            assert_eq!(all[i], s.estimate(i as u32), "coord {i}");
        }
    }

    #[test]
    fn median_even_and_odd() {
        assert_eq!(median_in_place(&mut [3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median_in_place(&mut [4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(median_in_place(&mut [5.0]), 5.0);
    }

    #[test]
    fn median_networks_match_sort_exhaustively() {
        // median3/median5 over all permutations of distinct values and a
        // sample of ties.
        let vals3 = [[1.0f32, 2.0, 3.0]];
        for v in vals3 {
            let mut idx = [0usize, 1, 2];
            // all 6 permutations
            for _ in 0..6 {
                idx.rotate_left(1);
                for swap in [false, true] {
                    let mut p = [v[idx[0]], v[idx[1]], v[idx[2]]];
                    if swap {
                        p.swap(0, 1);
                    }
                    assert_eq!(median3(p[0], p[1], p[2]), 2.0);
                }
            }
        }
        // all 120 permutations of [1..5]
        let mut perm = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let mut count = 0;
        permute(&mut perm, 0, &mut |p: &[f32; 5]| {
            assert_eq!(median5(p[0], p[1], p[2], p[3], p[4]), 3.0, "{p:?}");
            count += 1;
        });
        assert_eq!(count, 120);
        // ties
        assert_eq!(median5(1.0, 1.0, 2.0, 3.0, 3.0), 2.0);
        assert_eq!(median5(2.0, 2.0, 2.0, 0.0, 9.0), 2.0);
        assert_eq!(median5(-1.0, -1.0, -1.0, -1.0, -1.0), -1.0);
    }

    fn permute(v: &mut [f32; 5], k: usize, f: &mut impl FnMut(&[f32; 5])) {
        if k == 5 {
            f(v);
            return;
        }
        for i in k..5 {
            v.swap(k, i);
            permute(v, k + 1, f);
            v.swap(k, i);
        }
    }

    #[test]
    fn estimate_all_matches_per_coordinate_for_all_row_counts() {
        for rows in [1usize, 3, 5, 7] {
            let mut rng = crate::util::Rng::new(rows as u64);
            let d = 2000;
            let v: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
            let s = CountSketch::encode(rows, 256, 9, &v).unwrap();
            let all = s.estimate_all();
            for i in (0..d).step_by(53) {
                assert_eq!(all[i], s.estimate(i as u32), "rows={rows} coord {i}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn incompatible_sketches_refuse_to_merge() {
        let a = CountSketch::zeros(3, 64, 10, 1).unwrap();
        let b = CountSketch::zeros(3, 64, 10, 2).unwrap(); // different seed
        let mut a = a;
        a.add_scaled(&b, 1.0);
    }
}
