//! Top-k selection and sparse-vector utilities.
//!
//! Used by (a) the FetchSGD server to extract `Top-k(U(S_e))`, (b) the
//! local top-k baseline on each client, and (c) the true top-k baseline
//! on the server. Selection is by magnitude, O(d) via quickselect.

/// A k-sparse vector: parallel index/value arrays, indices strictly
/// increasing. This is the wire format of FetchSGD's model update
/// (download direction) and of the local top-k upload.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub dim: usize,
    pub idx: Vec<u32>,
    pub val: Vec<f32>,
}

impl SparseVec {
    pub fn new(dim: usize) -> Self {
        SparseVec { dim, idx: Vec::new(), val: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Build from already-sorted parallel arrays, validating the
    /// invariant (strictly increasing indices, in range, matched
    /// lengths) instead of assuming it — the constructor wire decoding
    /// uses, where the input is untrusted bytes.
    pub fn from_sorted(dim: usize, idx: Vec<u32>, val: Vec<f32>) -> anyhow::Result<Self> {
        if idx.len() != val.len() {
            anyhow::bail!("{} indices but {} values", idx.len(), val.len());
        }
        let mut prev: i64 = -1;
        for &i in &idx {
            if (i as i64) <= prev || (i as usize) >= dim {
                anyhow::bail!("sparse index {i} out of order or exceeds dim {dim}");
            }
            prev = i as i64;
        }
        Ok(SparseVec { dim, idx, val })
    }

    /// Build from (unsorted) pairs; sorts by index and asserts no dups.
    pub fn from_pairs(dim: usize, mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|&(i, _)| i);
        for w in pairs.windows(2) {
            debug_assert_ne!(w[0].0, w[1].0, "duplicate index in SparseVec");
        }
        SparseVec {
            dim,
            idx: pairs.iter().map(|&(i, _)| i).collect(),
            val: pairs.iter().map(|&(_, v)| v).collect(),
        }
    }

    /// Densify (for tests / small vectors).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.dim];
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
        out
    }

    /// out += self * scale, into a dense accumulator.
    pub fn add_into(&self, out: &mut [f32], scale: f32) {
        assert_eq!(out.len(), self.dim);
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += v * scale;
        }
    }

    /// Number of payload bytes under the paper's accounting convention
    /// (footnote 5: non-zero f32 values only, zero-overhead encoding of
    /// the index set).
    pub fn payload_bytes(&self) -> u64 {
        4 * self.nnz() as u64
    }

    /// Dot product with a dense vector.
    pub fn dot(&self, dense: &[f32]) -> f64 {
        self.idx
            .iter()
            .zip(&self.val)
            .map(|(&i, &v)| v as f64 * dense[i as usize] as f64)
            .sum()
    }
}

/// Indices of the `k` largest-magnitude entries of `v` (any order).
/// O(d) expected via `select_nth_unstable`. If `k >= len`, returns all.
pub fn top_k_indices(v: &[f32], k: usize) -> Vec<u32> {
    if k == 0 {
        return Vec::new();
    }
    if k >= v.len() {
        return (0..v.len() as u32).collect();
    }
    let mut order: Vec<u32> = (0..v.len() as u32).collect();
    let kth = k - 1;
    order.select_nth_unstable_by(kth, |&a, &b| {
        let ma = v[a as usize].abs();
        let mb = v[b as usize].abs();
        mb.partial_cmp(&ma).unwrap_or(std::cmp::Ordering::Equal)
    });
    order.truncate(k);
    order
}

/// Extract the top-k of `v` by magnitude as a SparseVec (values taken
/// from `v`).
pub fn top_k_sparse(v: &[f32], k: usize) -> SparseVec {
    let idx = top_k_indices(v, k);
    SparseVec::from_pairs(v.len(), idx.into_iter().map(|i| (i, v[i as usize])).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn top_k_exact_small() {
        let v = [0.1f32, -5.0, 3.0, 0.0, -2.0, 4.0];
        let mut idx = top_k_indices(&v, 3);
        idx.sort();
        assert_eq!(idx, vec![1, 2, 5]);
    }

    #[test]
    fn top_k_edge_cases() {
        assert!(top_k_indices(&[1.0, 2.0], 0).is_empty());
        assert_eq!(top_k_indices(&[1.0, 2.0], 5).len(), 2);
        let sv = top_k_sparse(&[0.0f32; 4], 2);
        assert_eq!(sv.nnz(), 2); // ties are fine, any 2 of the zeros
    }

    #[test]
    fn from_sorted_validates_untrusted_input() {
        assert!(SparseVec::from_sorted(10, vec![1, 4], vec![1.0, 2.0]).is_ok());
        assert!(SparseVec::from_sorted(10, vec![4, 1], vec![1.0, 2.0]).is_err());
        assert!(SparseVec::from_sorted(10, vec![1, 1], vec![1.0, 2.0]).is_err());
        assert!(SparseVec::from_sorted(10, vec![1, 10], vec![1.0, 2.0]).is_err());
        assert!(SparseVec::from_sorted(10, vec![1], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn sparse_roundtrip_and_add() {
        let sv = SparseVec::from_pairs(6, vec![(4, 2.0), (1, -1.0)]);
        assert_eq!(sv.idx, vec![1, 4]);
        assert_eq!(sv.to_dense(), vec![0.0, -1.0, 0.0, 0.0, 2.0, 0.0]);
        let mut acc = vec![1f32; 6];
        sv.add_into(&mut acc, 2.0);
        assert_eq!(acc, vec![1.0, -1.0, 1.0, 1.0, 5.0, 1.0]);
        assert_eq!(sv.payload_bytes(), 8);
    }

    #[test]
    fn prop_top_k_matches_full_sort() {
        check("topk = sort prefix", 60, |g| {
            let v = g.vec_f32(1, 200, -100.0, 100.0);
            let k = g.usize_in(1, v.len() + 1);
            let mut got: Vec<f32> = top_k_indices(&v, k).iter().map(|&i| v[i as usize].abs()).collect();
            got.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let mut all: Vec<f32> = v.iter().map(|x| x.abs()).collect();
            all.sort_by(|a, b| b.partial_cmp(a).unwrap());
            assert_eq!(got, all[..k].to_vec());
        });
    }
}
