//! The quorum policy: how much of a planned cohort must actually arrive
//! for a round to close, how long to wait, and how often a lost slot is
//! re-offered before being dropped.

use anyhow::{bail, Result};
use std::time::Duration;

/// Partial-participation knobs for one training run. Built from
/// `TrainConfig` (`quorum_fraction` / `round_deadline_ms` /
/// `max_slot_retries`) and consulted by both round drivers.
#[derive(Clone, Debug, PartialEq)]
pub struct QuorumPolicy {
    /// Minimum fraction of the planned cohort that must arrive, in
    /// (0, 1]. 1.0 = the full cohort (the pre-cohort behavior).
    min_fraction: f64,
    /// Wall-clock budget for a round. Once it expires with quorum met,
    /// outstanding slots are dropped (`DropReason::Deadline`) instead
    /// of holding the round open. `None` = wait forever (the default).
    round_deadline: Option<Duration>,
    /// How many times a faulted slot is re-offered (in-process: the
    /// client compute re-run; served: the slot reassigned to a healthy
    /// connection) before it is dropped.
    max_slot_retries: usize,
}

impl QuorumPolicy {
    /// Full cohort, no deadline, no retries: one bad slot fails the
    /// round loudly — exactly the behavior before the cohort subsystem.
    pub fn strict() -> QuorumPolicy {
        QuorumPolicy { min_fraction: 1.0, round_deadline: None, max_slot_retries: 0 }
    }

    /// Validating constructor. `round_deadline_ms` of 0 means
    /// wait-forever (preserves the strict default's pacing); a quorum
    /// fraction outside (0, 1] is a config error, caught here rather
    /// than as a never-closing or trivially-empty round later.
    pub fn new(
        min_fraction: f64,
        round_deadline_ms: u64,
        max_slot_retries: usize,
    ) -> Result<QuorumPolicy> {
        if !min_fraction.is_finite() || min_fraction <= 0.0 || min_fraction > 1.0 {
            bail!("quorum_fraction must be in (0, 1], got {min_fraction}");
        }
        let round_deadline =
            (round_deadline_ms > 0).then(|| Duration::from_millis(round_deadline_ms));
        Ok(QuorumPolicy { min_fraction, round_deadline, max_slot_retries })
    }

    pub fn min_fraction(&self) -> f64 {
        self.min_fraction
    }

    pub fn round_deadline(&self) -> Option<Duration> {
        self.round_deadline
    }

    pub fn max_slot_retries(&self) -> usize {
        self.max_slot_retries
    }

    /// Arrived-slot count required to close a round of `slots` slots:
    /// `ceil(min_fraction · slots)`, clamped to [1, slots].
    pub fn quorum_target(&self, slots: usize) -> usize {
        ((self.min_fraction * slots as f64).ceil() as usize).clamp(1, slots.max(1))
    }

    /// Whether a single slot fault is already fatal (full quorum, no
    /// retry budget) — drivers use this to keep the pre-cohort
    /// fail-fast behavior: once one slot is lost the round cannot
    /// close, so there is no point finishing the cohort.
    pub fn is_strict(&self) -> bool {
        self.min_fraction >= 1.0 && self.max_slot_retries == 0
    }
}

impl Default for QuorumPolicy {
    fn default() -> Self {
        QuorumPolicy::strict()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_fraction_bounds() {
        assert!(QuorumPolicy::new(0.0, 0, 0).is_err());
        assert!(QuorumPolicy::new(-0.5, 0, 0).is_err());
        assert!(QuorumPolicy::new(1.5, 0, 0).is_err());
        assert!(QuorumPolicy::new(f64::NAN, 0, 0).is_err());
        assert!(QuorumPolicy::new(f64::INFINITY, 0, 0).is_err());
        assert!(QuorumPolicy::new(0.001, 0, 0).is_ok());
        assert!(QuorumPolicy::new(1.0, 0, 0).is_ok());
    }

    #[test]
    fn deadline_zero_means_wait_forever() {
        let p = QuorumPolicy::new(1.0, 0, 0).unwrap();
        assert_eq!(p.round_deadline(), None);
        assert_eq!(p, QuorumPolicy::strict());
        let p = QuorumPolicy::new(0.5, 250, 2).unwrap();
        assert_eq!(p.round_deadline(), Some(Duration::from_millis(250)));
        assert_eq!(p.max_slot_retries(), 2);
    }

    #[test]
    fn quorum_target_rounds_up_and_clamps() {
        let p = QuorumPolicy::new(0.5, 0, 0).unwrap();
        assert_eq!(p.quorum_target(4), 2);
        assert_eq!(p.quorum_target(5), 3); // ceil, not floor
        assert_eq!(p.quorum_target(1), 1);
        let p = QuorumPolicy::new(0.01, 0, 0).unwrap();
        assert_eq!(p.quorum_target(10), 1, "quorum never drops below one upload");
        let p = QuorumPolicy::strict();
        assert_eq!(p.quorum_target(7), 7);
        assert!(p.is_strict());
        assert!(!QuorumPolicy::new(1.0, 0, 1).unwrap().is_strict());
        assert!(!QuorumPolicy::new(0.9, 0, 0).unwrap().is_strict());
    }
}
