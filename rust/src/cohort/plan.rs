//! The sampled round: which clients the coordinator *intends* to hear
//! from, in slot order.

use anyhow::{bail, Result};

use crate::cohort::membership::RoundMembership;
use crate::cohort::policy::QuorumPolicy;
use crate::coordinator::selection::ClientSelector;
use crate::data::FedDataset;

/// One round's planned cohort: the participant client ids drawn by
/// `coordinator::selection` plus their local dataset sizes (slot order
/// throughout). The plan is what [`RoundMembership`] is measured
/// against: slot `i` of the plan either arrives or is dropped.
#[derive(Clone, Debug)]
pub struct CohortPlan {
    pub round: usize,
    /// Participant client ids, in slot order.
    pub participants: Vec<usize>,
    /// Participants' local dataset sizes, in slot order — the input to
    /// `ServerAggregator::begin_round`, which turns them into per-slot
    /// aggregation weights λ.
    pub sizes: Vec<f32>,
}

impl CohortPlan {
    /// Draw the round's cohort: uniform sampling via the selector, with
    /// dataset sizes resolved per slot. Deterministic given the
    /// selector's seed and the round index.
    pub fn sample(selector: &ClientSelector, dataset: &dyn FedDataset, round: usize) -> CohortPlan {
        let participants = selector.select(round);
        let sizes = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        CohortPlan { round, participants, sizes }
    }

    /// Build a plan from pre-resolved parts (transport drivers and
    /// tests that own selection themselves).
    pub fn from_parts(
        round: usize,
        participants: Vec<usize>,
        sizes: Vec<f32>,
    ) -> Result<CohortPlan> {
        if participants.is_empty() {
            bail!("round {round} has no participants");
        }
        if participants.len() != sizes.len() {
            bail!("{} participants but {} client sizes", participants.len(), sizes.len());
        }
        Ok(CohortPlan { round, participants, sizes })
    }

    pub fn slots(&self) -> usize {
        self.participants.len()
    }

    /// A fresh outcome tracker for this plan under `policy`.
    pub fn membership(&self, policy: QuorumPolicy) -> Result<RoundMembership> {
        RoundMembership::new(self.slots(), policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::sim::SimDataset;

    #[test]
    fn sample_is_deterministic_and_sized() {
        let selector = ClientSelector::new(50, 8, 7);
        let ds = SimDataset { num_clients: 50 };
        let a = CohortPlan::sample(&selector, &ds, 3);
        let b = CohortPlan::sample(&selector, &ds, 3);
        assert_eq!(a.participants, b.participants);
        assert_eq!(a.slots(), 8);
        for (slot, &c) in a.participants.iter().enumerate() {
            assert_eq!(a.sizes[slot], ds.client_size(c) as f32);
        }
        let m = a.membership(QuorumPolicy::strict()).unwrap();
        assert_eq!(m.slots(), 8);
    }

    #[test]
    fn from_parts_validates_shape() {
        assert!(CohortPlan::from_parts(0, vec![], vec![]).is_err());
        assert!(CohortPlan::from_parts(0, vec![1, 2], vec![1.0]).is_err());
        let p = CohortPlan::from_parts(0, vec![1, 2], vec![1.0, 2.0]).unwrap();
        assert_eq!(p.slots(), 2);
    }
}
