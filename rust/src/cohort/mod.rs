//! Round membership as a first-class, typed state machine.
//!
//! FetchSGD's central robustness claim (paper §1, §3) is that a round
//! is valid with *whatever subset of clients actually shows up*:
//! momentum and error accumulation live in the server's sketches, and
//! every strategy's fan-in is a weighted sum, so the arrived subset is
//! all the server needs. This module owns that subset:
//!
//! - [`CohortPlan`] — the *sampled* round: participant client ids (from
//!   `coordinator::selection`) plus their dataset sizes, in slot order.
//!   What the round intends.
//! - [`QuorumPolicy`] — how much of the plan must materialize: a
//!   minimum arrival fraction, an optional round deadline, and a
//!   per-slot retry budget. The default ([`QuorumPolicy::strict`])
//!   requires the full cohort with no deadline and no retries —
//!   exactly the pre-cohort behavior, so existing configs are
//!   untouched.
//! - [`RoundMembership`] — what actually happened: a per-slot outcome
//!   ([`SlotOutcome`]: `Arrived`, `Retried(n)`, `Dropped(reason)`)
//!   recorded by the round drivers (the in-process engine and the
//!   transport server), plus the **finalize-at-quorum** decision:
//!   once every slot is settled, the round closes iff the arrived
//!   count meets [`RoundMembership::quorum_target`].
//!
//! ## Determinism contract
//!
//! *Which* slots drop can depend on wall-clock (deadlines) or on flaky
//! peers — that is inherent to partial participation. Everything
//! downstream of the final membership set is a **pure function of that
//! set**: [`RoundMembership::renormalization_scale`] renormalizes the
//! per-slot aggregation weights over the arrived subset in slot order,
//! and `aggregate::RoundPipeline::finalize_partial` absorbs the arrived
//! slots in the same in-shard order the full-cohort path uses. Two runs
//! — in-process or served, at any parallelism — that end with the same
//! arrived set produce bitwise-identical merged weights (enforced by
//! `rust/tests/cohort_quorum.rs`).

pub mod membership;
pub mod plan;
pub mod policy;

pub use membership::{DropReason, MembershipSummary, RoundMembership, SlotOutcome};
pub use plan::CohortPlan;
pub use policy::QuorumPolicy;
