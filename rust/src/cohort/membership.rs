//! The per-round membership tracker: what happened to each planned
//! slot, and whether the arrived subset clears the quorum.

use anyhow::{bail, Result};

use crate::cohort::policy::QuorumPolicy;

/// Why a slot was dropped from the round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// The client compute or its upload faulted (bad frame, compute
    /// error) and the retry budget is exhausted.
    Faulted,
    /// The peer carrying the slot disconnected and the retry budget is
    /// exhausted.
    Disconnected,
    /// The round deadline fired before the upload arrived.
    Deadline,
}

impl std::fmt::Display for DropReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DropReason::Faulted => write!(f, "faulted"),
            DropReason::Disconnected => write!(f, "disconnected"),
            DropReason::Deadline => write!(f, "deadline"),
        }
    }
}

/// Final state of one participant slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotOutcome {
    /// No outcome recorded yet.
    Pending,
    /// Upload absorbed on the first offer.
    Arrived,
    /// Upload absorbed after `n ≥ 1` retries / reassignments.
    Retried(usize),
    /// Slot excluded from the round.
    Dropped(DropReason),
}

/// The membership counts a round reports into metrics
/// (`RoundRecord.participants` / `dropped_slots` / `retried_slots`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct MembershipSummary {
    /// Slots whose upload was absorbed (`Arrived` or `Retried`).
    pub participants: usize,
    /// Slots excluded from the round.
    pub dropped_slots: usize,
    /// Slots that needed at least one retry (whether or not the upload
    /// eventually arrived).
    pub retried_slots: usize,
}

/// Per-slot outcome tracker for one round, plus the
/// **finalize-at-quorum** decision.
///
/// Drivers record events as they happen (`record_retry` before each
/// re-offer, `record_arrival` when the upload is absorbed,
/// `record_drop` when a slot is given up on); once every slot is
/// settled, [`RoundMembership::quorum_met`] decides whether the round
/// closes with the arrived subset. Recording is intentionally
/// assert-guarded rather than fallible: a double arrival or an
/// arrival-after-drop is a driver bug, not a runtime condition —
/// upstream slot bookkeeping (`RoundInFlight`'s seen-set, the
/// transport's per-connection order check) already rejects hostile
/// duplicates before they reach here.
#[derive(Clone, Debug)]
pub struct RoundMembership {
    policy: QuorumPolicy,
    outcomes: Vec<SlotOutcome>,
    /// Retries recorded per slot (survives into `Retried(n)` on
    /// arrival, and is reported for dropped slots too).
    retries: Vec<usize>,
    arrived: usize,
    dropped: usize,
}

impl RoundMembership {
    pub fn new(slots: usize, policy: QuorumPolicy) -> Result<RoundMembership> {
        if slots == 0 {
            bail!("a round needs at least one participant slot");
        }
        Ok(RoundMembership {
            policy,
            outcomes: vec![SlotOutcome::Pending; slots],
            retries: vec![0; slots],
            arrived: 0,
            dropped: 0,
        })
    }

    pub fn slots(&self) -> usize {
        self.outcomes.len()
    }

    pub fn policy(&self) -> &QuorumPolicy {
        &self.policy
    }

    /// Arrived-slot count required to close this round.
    pub fn quorum_target(&self) -> usize {
        self.policy.quorum_target(self.slots())
    }

    /// Record one retry / reassignment attempt for `slot`; returns the
    /// total retries now charged against it.
    pub fn record_retry(&mut self, slot: usize) -> usize {
        assert!(
            matches!(self.outcomes[slot], SlotOutcome::Pending),
            "retry recorded for settled slot {slot}"
        );
        self.retries[slot] += 1;
        self.retries[slot]
    }

    /// Whether `slot` still has retry budget left.
    pub fn retries_remaining(&self, slot: usize) -> bool {
        self.retries[slot] < self.policy.max_slot_retries()
    }

    /// The slot's upload was absorbed into the round.
    pub fn record_arrival(&mut self, slot: usize) {
        assert!(
            matches!(self.outcomes[slot], SlotOutcome::Pending),
            "arrival recorded for settled slot {slot}"
        );
        self.outcomes[slot] = match self.retries[slot] {
            0 => SlotOutcome::Arrived,
            n => SlotOutcome::Retried(n),
        };
        self.arrived += 1;
    }

    /// The slot is excluded from the round.
    pub fn record_drop(&mut self, slot: usize, reason: DropReason) {
        assert!(
            matches!(self.outcomes[slot], SlotOutcome::Pending),
            "drop recorded for settled slot {slot}"
        );
        self.outcomes[slot] = SlotOutcome::Dropped(reason);
        self.dropped += 1;
    }

    /// Roll up a subtree-reported outcome into this (root-tier)
    /// membership — the relay-tree path, where the slot's events
    /// happened on another tier and arrive as one settled fact. A
    /// `Retried(n)` report charges the downstream retries against the
    /// slot without consulting *this* tier's retry budget: the
    /// downstream policy already spent its own budget, and the root
    /// only accounts. `Pending` is not a reportable outcome.
    pub fn record_report(&mut self, slot: usize, outcome: SlotOutcome) {
        match outcome {
            SlotOutcome::Pending => panic!("a subtree report cannot be pending (slot {slot})"),
            SlotOutcome::Arrived => self.record_arrival(slot),
            SlotOutcome::Retried(n) => {
                assert!(n >= 1, "Retried(0) reported for slot {slot}");
                assert!(
                    matches!(self.outcomes[slot], SlotOutcome::Pending),
                    "report recorded for settled slot {slot}"
                );
                self.retries[slot] += n;
                self.record_arrival(slot);
            }
            SlotOutcome::Dropped(reason) => self.record_drop(slot, reason),
        }
    }

    pub fn outcome(&self, slot: usize) -> SlotOutcome {
        self.outcomes[slot]
    }

    pub fn is_arrived(&self, slot: usize) -> bool {
        matches!(self.outcomes[slot], SlotOutcome::Arrived | SlotOutcome::Retried(_))
    }

    /// Slots whose upload was absorbed.
    pub fn arrived(&self) -> usize {
        self.arrived
    }

    pub fn dropped(&self) -> usize {
        self.dropped
    }

    /// Every slot has an outcome (nothing `Pending`).
    pub fn is_settled(&self) -> bool {
        self.arrived + self.dropped == self.slots()
    }

    /// The full planned cohort arrived.
    pub fn is_full(&self) -> bool {
        self.arrived == self.slots()
    }

    pub fn quorum_met(&self) -> bool {
        self.arrived >= self.quorum_target()
    }

    /// The arrived slots, in increasing slot order — the canonical
    /// representation of the final membership set.
    pub fn arrived_slots(&self) -> Vec<usize> {
        (0..self.slots()).filter(|&s| self.is_arrived(s)).collect()
    }

    /// Mean of the per-slot `losses` over the arrived slots, summed in
    /// slot order — the scheduling-invariant round training loss both
    /// round drivers report. Dropped slots' entries are ignored.
    pub fn mean_loss_over_arrived(&self, losses: &[f32]) -> f64 {
        let mut sum = 0f64;
        for slot in 0..self.slots() {
            if self.is_arrived(slot) {
                sum += losses[slot] as f64;
            }
        }
        sum / self.arrived.max(1) as f64
    }

    /// The factor that renormalizes the round's original per-slot
    /// aggregation weights λ over the actual participants:
    /// `1 / Σ_{i ∈ arrived} λ_i`, the sum taken in slot order. A pure
    /// function of (original weights, final membership set) — never of
    /// arrival order, thread count, or transport — so two runs ending
    /// with the same set scale identically, bit for bit.
    pub fn renormalization_scale(&self, weights: &[f32]) -> Result<f32> {
        if weights.len() != self.slots() {
            bail!("{} weights for a {}-slot membership", weights.len(), self.slots());
        }
        let mut sum = 0f64;
        for slot in 0..self.slots() {
            if self.is_arrived(slot) {
                sum += weights[slot] as f64;
            }
        }
        if !(sum > 0.0) {
            bail!("arrived slots carry no aggregation weight (sum {sum})");
        }
        Ok((1.0 / sum) as f32)
    }

    pub fn summary(&self) -> MembershipSummary {
        MembershipSummary {
            participants: self.arrived,
            dropped_slots: self.dropped,
            retried_slots: (0..self.slots()).filter(|&s| self.retries[s] > 0).count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(frac: f64, retries: usize) -> QuorumPolicy {
        QuorumPolicy::new(frac, 0, retries).unwrap()
    }

    #[test]
    fn tracks_outcomes_and_quorum() {
        let mut m = RoundMembership::new(4, policy(0.5, 1)).unwrap();
        assert_eq!(m.quorum_target(), 2);
        assert!(!m.is_settled());
        m.record_arrival(0);
        assert!(!m.quorum_met());
        m.record_retry(1);
        assert!(!m.retries_remaining(1), "budget of 1 is spent");
        m.record_arrival(1);
        assert_eq!(m.outcome(1), SlotOutcome::Retried(1));
        assert!(m.quorum_met());
        m.record_retry(2);
        m.record_drop(2, DropReason::Disconnected);
        m.record_drop(3, DropReason::Deadline);
        assert!(m.is_settled());
        assert!(!m.is_full());
        assert_eq!(m.arrived_slots(), vec![0, 1]);
        let s = m.summary();
        assert_eq!(
            s,
            MembershipSummary { participants: 2, dropped_slots: 2, retried_slots: 2 }
        );
    }

    #[test]
    fn strict_policy_requires_everyone() {
        let mut m = RoundMembership::new(3, QuorumPolicy::strict()).unwrap();
        m.record_arrival(0);
        m.record_arrival(1);
        m.record_drop(2, DropReason::Faulted);
        assert!(m.is_settled());
        assert!(!m.quorum_met());
        assert!(!m.retries_remaining(0));
    }

    #[test]
    fn renormalization_is_a_pure_function_of_the_set() {
        let weights = [0.25f32, 0.25, 0.25, 0.25];
        let mut a = RoundMembership::new(4, policy(0.5, 2)).unwrap();
        a.record_arrival(0);
        a.record_arrival(2);
        a.record_drop(1, DropReason::Faulted);
        a.record_drop(3, DropReason::Deadline);
        // Same final set, different history (retries, drop reasons,
        // recording order) — identical scale bits.
        let mut b = RoundMembership::new(4, policy(0.9, 2)).unwrap();
        b.record_drop(3, DropReason::Disconnected);
        b.record_retry(2);
        b.record_arrival(2);
        b.record_arrival(0);
        b.record_drop(1, DropReason::Deadline);
        let (sa, sb) = (
            a.renormalization_scale(&weights).unwrap(),
            b.renormalization_scale(&weights).unwrap(),
        );
        assert_eq!(sa.to_bits(), sb.to_bits());
        assert!((sa - 2.0).abs() < 1e-6, "half the uniform cohort doubles the weights");
        // Full arrival scales by exactly the reciprocal of the sum.
        let mut f = RoundMembership::new(2, policy(1.0, 0)).unwrap();
        f.record_arrival(0);
        f.record_arrival(1);
        assert!(f.is_full());
        // Mismatched weight length and zero-weight subsets error.
        assert!(f.renormalization_scale(&[1.0]).is_err());
        let mut z = RoundMembership::new(2, policy(0.5, 0)).unwrap();
        z.record_arrival(0);
        z.record_drop(1, DropReason::Faulted);
        assert!(z.renormalization_scale(&[0.0, 1.0]).is_err());
    }

    #[test]
    #[should_panic(expected = "settled slot")]
    fn double_arrival_is_a_driver_bug() {
        let mut m = RoundMembership::new(2, QuorumPolicy::strict()).unwrap();
        m.record_arrival(0);
        m.record_arrival(0);
    }

    #[test]
    fn empty_rounds_are_rejected() {
        assert!(RoundMembership::new(0, QuorumPolicy::strict()).is_err());
    }

    #[test]
    fn subtree_reports_roll_up_without_local_retry_budget() {
        // max_slot_retries = 0 at this tier: a Retried(2) report must
        // still land (the downstream tier spent its own budget) and be
        // charged to the retried-slots summary.
        let mut m = RoundMembership::new(4, policy(0.5, 0)).unwrap();
        m.record_report(0, SlotOutcome::Arrived);
        m.record_report(1, SlotOutcome::Retried(2));
        m.record_report(2, SlotOutcome::Dropped(DropReason::Disconnected));
        m.record_report(3, SlotOutcome::Dropped(DropReason::Deadline));
        assert!(m.is_settled());
        assert_eq!(m.outcome(1), SlotOutcome::Retried(2));
        assert_eq!(m.outcome(2), SlotOutcome::Dropped(DropReason::Disconnected));
        assert_eq!(
            m.summary(),
            MembershipSummary { participants: 2, dropped_slots: 2, retried_slots: 1 }
        );
    }

    #[test]
    fn quorum_is_global_not_per_subtree() {
        // Slots {0,2,4} form one subtree that lost everything — locally
        // 0% arrival, far under quorum — while {1,3,5} fully arrived.
        // The decision belongs to the root over the whole cohort: 3 of
        // 6 meets the 0.5 target, so the round closes.
        let mut m = RoundMembership::new(6, policy(0.5, 0)).unwrap();
        for slot in [0, 2, 4] {
            m.record_report(slot, SlotOutcome::Dropped(DropReason::Faulted));
        }
        for slot in [1, 3, 5] {
            m.record_report(slot, SlotOutcome::Arrived);
        }
        assert!(m.is_settled());
        assert!(m.quorum_met());
        assert_eq!(m.arrived_slots(), vec![1, 3, 5]);
    }

    #[test]
    fn zero_participant_subtree_still_settles_the_round() {
        // A relay that answers with all-dropped reports (or an empty
        // chain) contributes only drops; the round settles and the
        // renormalization scale is a function of the surviving set.
        let mut m = RoundMembership::new(4, policy(0.25, 0)).unwrap();
        m.record_report(0, SlotOutcome::Dropped(DropReason::Disconnected));
        m.record_report(2, SlotOutcome::Dropped(DropReason::Disconnected));
        m.record_report(1, SlotOutcome::Arrived);
        m.record_report(3, SlotOutcome::Arrived);
        assert!(m.is_settled() && m.quorum_met() && !m.is_full());
        let s = m.renormalization_scale(&[0.25, 0.25, 0.25, 0.25]).unwrap();
        assert!((s - 2.0).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "settled slot")]
    fn duplicate_slot_across_tiers_is_a_driver_bug() {
        // Two subtrees both claiming slot 1 must fail loudly — silent
        // double-counting would corrupt the round.
        let mut m = RoundMembership::new(2, policy(0.5, 0)).unwrap();
        m.record_report(1, SlotOutcome::Arrived);
        m.record_report(1, SlotOutcome::Arrived);
    }

    #[test]
    #[should_panic(expected = "cannot be pending")]
    fn pending_reports_are_rejected() {
        let mut m = RoundMembership::new(1, policy(0.5, 0)).unwrap();
        m.record_report(0, SlotOutcome::Pending);
    }
}
