//! Empirical validation of Assumption 2 (§4.2, Definition 1): gradients
//! along the optimization path are `(I, τ)`-sliding heavy — sums of up
//! to `I` consecutive aggregated gradients contain coordinates holding a
//! τ fraction of the ℓ2² mass.
//!
//! The paper cites observations of heavy gradient coordinates (Shi et
//! al. 2019; Li et al. 2019) but never measures its own assumption; this
//! driver does. We train the smoke/cifar task with uncompressed SGD,
//! record the aggregated gradient each round, and report, for windows
//! I ∈ {1, 2, 4, 8}, the fraction of windowed-sum ℓ2² mass captured by
//! the top 0.1% / 1% of coordinates. Growing mass with I supports both
//! the sliding-window analysis and the practical success of error
//! feedback (signal spread over consecutive rounds).

use anyhow::Result;
use std::path::PathBuf;
use std::sync::Arc;

use crate::config::{LrSchedule, StrategyConfig, TrainConfig};
use crate::coordinator::Trainer;
use crate::experiments::runner::ExperimentScale;
use crate::model::DataScale;
use crate::runtime::Runtime;
use crate::serialize::json::{num, obj, s};
use crate::sketch::topk::top_k_indices;

pub struct AssumptionParams {
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub task: String,
}

/// Fraction of ||v||^2 captured by the top-`k` coordinates.
fn topk_mass_fraction(v: &[f32], k: usize) -> f64 {
    let total: f64 = v.iter().map(|&x| (x as f64) * (x as f64)).sum();
    if total == 0.0 {
        return 0.0;
    }
    let idx = top_k_indices(v, k);
    let mass: f64 = idx.iter().map(|&i| (v[i as usize] as f64).powi(2)).sum();
    mass / total
}

pub fn run(p: AssumptionParams) -> Result<()> {
    let rounds = p.scale.rounds(40);
    let cfg = TrainConfig {
        task: p.task.clone(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.0 },
        rounds,
        clients_per_round: 8,
        lr: LrSchedule::Triangular { peak: 0.02, pivot: 0.2 },
        scale: if p.task == "smoke" {
            DataScale::smoke()
        } else {
            DataScale {
                num_clients: p.scale.clients(200),
                samples_per_client: 5,
                eval_batches: 4,
                partition: "label_skew".into(),
                ..DataScale::default()
            }
        },
        eval_every: 0,
        seed: 13,
        artifacts_dir: p.artifacts_dir.clone(),
        log_path: None,
        baseline_rounds: None,
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    };

    let runtime = Arc::new(Runtime::cpu()?);
    let mut trainer = Trainer::with_runtime(cfg, runtime)?;
    let dim = trainer.dim();

    // Train while recording the aggregated gradient each round.
    // (We re-derive it from the weight delta of the momentum-free
    // uncompressed strategy: w_{t+1} - w_t = -lr * mean_grad.)
    let mut grads: Vec<Vec<f32>> = Vec::with_capacity(rounds);
    let mut prev_w = trainer.weights().to_vec();
    for round in 0..rounds {
        trainer.step(round)?;
        let w = trainer.weights();
        let lr = trainer.logger.rounds[round].lr.max(1e-12);
        let g: Vec<f32> =
            prev_w.iter().zip(w).map(|(&a, &b)| ((a - b) as f64 / lr) as f32).collect();
        grads.push(g);
        prev_w = w.to_vec();
    }

    let windows = [1usize, 2, 4, 8];
    let ks = [(dim / 1000).max(1), (dim / 100).max(1)];
    println!("\n=== Assumption 2 check: sliding-window heavy hitters ({}) ===", p.task);
    println!("model dim d = {dim}; mass fraction of windowed gradient sums\n");
    println!(
        "{:<10} {:>18} {:>18}",
        "window I",
        format!("top 0.1% (k={})", ks[0]),
        format!("top 1% (k={})", ks[1])
    );
    std::fs::create_dir_all(&p.out_dir)?;
    let mut jsonl = String::new();
    for &w in &windows {
        let mut fr_small = Vec::new();
        let mut fr_big = Vec::new();
        for start in (0..grads.len().saturating_sub(w)).step_by(w.max(1)) {
            let mut acc = vec![0f32; dim];
            for g in &grads[start..start + w] {
                for (a, &b) in acc.iter_mut().zip(g) {
                    *a += b;
                }
            }
            fr_small.push(topk_mass_fraction(&acc, ks[0]));
            fr_big.push(topk_mass_fraction(&acc, ks[1]));
        }
        let m_small = crate::util::stats::mean(&fr_small);
        let m_big = crate::util::stats::mean(&fr_big);
        println!("{:<10} {:>17.1}% {:>17.1}%", w, m_small * 100.0, m_big * 100.0);
        jsonl.push_str(
            &obj(vec![
                ("experiment", s("assumption2")),
                ("task", s(&p.task)),
                ("window", num(w as f64)),
                ("mass_top_0p1pct", num(m_small)),
                ("mass_top_1pct", num(m_big)),
            ])
            .to_json(),
        );
        jsonl.push('\n');
    }
    std::fs::write(p.out_dir.join("assumption2.jsonl"), jsonl)?;
    println!(
        "\nInterpretation: if windowed sums concentrate mass in few coordinates\n\
         (τ-heavy hitters), Definition 1 holds along the path and the sketch\n\
         can recover the signal (Theorem 2). Wrote {}",
        p.out_dir.join("assumption2.jsonl").display()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mass_fraction_bounds() {
        let v = vec![10.0, 0.1, 0.1, 0.1];
        let f = topk_mass_fraction(&v, 1);
        assert!(f > 0.99);
        assert_eq!(topk_mass_fraction(&[0.0; 4], 2), 0.0);
        let uniform = vec![1.0f32; 100];
        let f = topk_mass_fraction(&uniform, 10);
        assert!((f - 0.1).abs() < 1e-6);
    }
}
