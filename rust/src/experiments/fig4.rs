//! Figure 4 (+ Figure 8/9 left breakdowns): FEMNIST accuracy vs
//! compression — the regime designed to favor FedAvg (writer split,
//! ~200 images/client, only W=3 clients/round, closer to i.i.d.).
//!
//! Paper setup (§5.2/A.2): 3,500 writers, ResNet101, one global epoch.
//! Substitute: writer-partitioned synthetic images (per-writer style
//! transform), MLP, W=3, one-participation-per-client round budget.

use anyhow::Result;
use std::path::PathBuf;

use crate::config::{LrSchedule, StrategyConfig, TrainConfig};
use crate::experiments::runner::{ExperimentScale, Quality, Sweep, SweepRow};
use crate::model::DataScale;

pub struct Fig4Params {
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
}

fn base_config(p: &Fig4Params, rounds: usize) -> TrainConfig {
    let clients = p.scale.clients(150);
    TrainConfig {
        task: "femnist".into(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
        rounds,
        clients_per_round: 3, // paper: only three clients participate
        // tuned on the uncompressed baseline (paper §5 protocol)
        lr: LrSchedule::Triangular { peak: 0.1, pivot: 0.2 },
        scale: DataScale {
            num_clients: clients,
            writer_mean_size: 40,
            eval_batches: 8,
            partition: "writer".into(),
            ..DataScale::default()
        },
        eval_every: 0,
        seed: 23,
        artifacts_dir: p.artifacts_dir.clone(),
        log_path: None,
        baseline_rounds: None,
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

pub fn run(p: Fig4Params) -> Result<Vec<SweepRow>> {
    // "One epoch": every client participates about once.
    let clients = p.scale.clients(150);
    let rounds = (clients / 3).max(8);
    let mut sweep = Sweep::new("fig4_femnist", Quality::Accuracy);

    for frac in [1.0, 0.5] {
        let mut cfg = base_config(&p, ((rounds as f64 * frac) as usize).max(4));
        cfg.baseline_rounds = Some(rounds);
        sweep.push("uncompressed", &format!("rounds x{frac}"), cfg);
    }

    for &k in &[2000usize, 8000] {
        for &cols in &[4096usize, 8192] {
            let mut cfg = base_config(&p, rounds);
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = StrategyConfig::FetchSgd {
                k,
                cols,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            };
            sweep.push("fetchsgd", &format!("k={k} cols={cols}"), cfg);
        }
    }

    for &k in &[2000usize, 8000, 16000] {
        for &rho_g in &[0.0f32, 0.9] {
            let mut cfg = base_config(&p, rounds);
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy =
                StrategyConfig::LocalTopK { k, rho_g, masking: true, local_error: false };
            sweep.push("local_topk", &format!("k={k} rho_g={rho_g}"), cfg);
        }
    }

    // FedAvg's favored regime: fractions of the epoch with local steps.
    for frac in [0.5, 0.25] {
        for &local in &[1usize, 2, 5] {
            let mut cfg = base_config(&p, ((rounds as f64 * frac) as usize).max(4));
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = StrategyConfig::FedAvg { local_steps: local, rho_g: 0.0 };
            sweep.push("fedavg", &format!("rounds x{frac} local={local}"), cfg);
        }
    }

    sweep.execute(&p.out_dir)
}
