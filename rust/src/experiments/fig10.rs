//! Figure 10 (Appendix A.3): true top-k perplexity as a function of k —
//! the idealized method FetchSGD approximates. For intermediate k, true
//! top-k regularizes and can beat the uncompressed baseline; for large
//! k, momentum factor masking starts to hurt.

use anyhow::Result;
use std::path::PathBuf;

use crate::config::StrategyConfig;
use crate::experiments::fig5::{base_config, Fig5Params};
use crate::experiments::runner::{ExperimentScale, Quality, Sweep, SweepRow};

pub struct Fig10Params {
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
}

pub fn run(p: Fig10Params) -> Result<Vec<SweepRow>> {
    let fig5p = Fig5Params {
        scale: p.scale,
        artifacts_dir: p.artifacts_dir.clone(),
        out_dir: p.out_dir.clone(),
        curves: false,
    };
    let rounds = p.scale.rounds(60);
    let mut sweep = Sweep::new("fig10_true_topk", Quality::Perplexity);

    // Uncompressed reference line.
    let mut cfg = base_config(&fig5p, rounds);
    cfg.baseline_rounds = Some(rounds);
    sweep.push("uncompressed", "baseline", cfg);

    // True top-k over a k sweep (paper sweeps 1e4..1e7 for d=124M; we
    // scale the fractions of d ~ 1e5).
    for &k in &[50usize, 200, 1000, 5000, 20000] {
        let mut cfg = base_config(&fig5p, rounds);
        cfg.baseline_rounds = Some(rounds);
        cfg.strategy = StrategyConfig::TrueTopK { k, rho: 0.9, masking: true };
        sweep.push("true_topk", &format!("k={k}"), cfg);
    }

    sweep.execute(&p.out_dir)
}
