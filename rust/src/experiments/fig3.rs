//! Figure 3 (+ Figures 6/7 breakdowns): CIFAR10/CIFAR100 test accuracy
//! vs compression, per-class non-i.i.d. split.
//!
//! Paper setup (§5.1/A.1): 10,000 (50,000) clients with 5 (1) images of
//! a single class, 1% participation, ResNet9, triangular lr. Methods:
//! FetchSGD (k × sketch-cols grid), local top-k (k grid, ρ_g ∈ {0,.9}),
//! FedAvg (global-epoch × local-epoch grid), uncompressed (fewer
//! epochs). Our scaled-down substitute keeps the split semantics and
//! grids; see DESIGN.md §5.
//!
//! The upload/download breakdown of Figures 6/7 falls out of the same
//! sweep: every row carries up/down/overall ratios.

use anyhow::Result;
use std::path::PathBuf;

use crate::config::{LrSchedule, StrategyConfig, TrainConfig};
use crate::experiments::runner::{ExperimentScale, Quality, Sweep, SweepRow};
use crate::model::DataScale;

pub struct Fig3Params {
    pub dataset: String, // "cifar10" | "cifar100"
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
}

fn base_config(p: &Fig3Params, rounds: usize) -> TrainConfig {
    let cifar100 = p.dataset == "cifar100";
    // Per-class split: CIFAR10 -> 5 images/client, CIFAR100 -> 1.
    let samples = if cifar100 { 1 } else { 5 };
    let clients = p.scale.clients(if cifar100 { 400 } else { 200 });
    TrainConfig {
        task: p.dataset.clone(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
        rounds,
        clients_per_round: (clients / 20).max(2), // ~5% participation
        // Tuned on the uncompressed runs (paper §5 protocol: "the maximum
        // peak learning rate for which the uncompressed runs converge")
        // and shared by every compression method.
        lr: LrSchedule::Triangular { peak: if cifar100 { 0.015 } else { 0.02 }, pivot: 0.2 },
        scale: DataScale {
            num_clients: clients,
            samples_per_client: samples,
            eval_batches: 8,
            partition: "label_skew".into(),
            ..DataScale::default()
        },
        eval_every: 0,
        seed: 17,
        artifacts_dir: p.artifacts_dir.clone(),
        log_path: None,
        baseline_rounds: None,
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

pub fn run(p: Fig3Params) -> Result<Vec<SweepRow>> {
    let rounds = p.scale.rounds(60);
    let mut sweep = Sweep::new(&format!("fig3_{}", p.dataset), Quality::Accuracy);

    // Uncompressed: full rounds (1x) and fewer-epoch "compression".
    for frac in [1.0, 0.5, 0.25] {
        let mut cfg = base_config(&p, ((rounds as f64 * frac) as usize).max(4));
        cfg.baseline_rounds = Some(rounds);
        sweep.push("uncompressed", &format!("rounds x{frac}"), cfg);
    }

    // FetchSGD: k x cols grid. k is sized so that k*rounds covers a
    // multiple of d at this round budget (the paper's k/d ratios assume
    // 2400 iterations; ours are compressed accordingly).
    for &k in &[1000usize, 5000] {
        for &cols in &[8192usize, 16384] {
            let mut cfg = base_config(&p, rounds);
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = StrategyConfig::FetchSgd {
                k,
                cols,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            };
            sweep.push("fetchsgd", &format!("k={k} cols={cols}"), cfg);
        }
    }

    // Local top-k: k grid with and without global momentum.
    for &k in &[1000usize, 5000, 20000] {
        for &rho_g in &[0.0f32, 0.9] {
            let mut cfg = base_config(&p, rounds);
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy =
                StrategyConfig::LocalTopK { k, rho_g, masking: true, local_error: false };
            sweep.push("local_topk", &format!("k={k} rho_g={rho_g}"), cfg);
        }
    }

    // FedAvg: global-epoch fraction x local steps (lr schedule compresses
    // automatically since it is parameterized by progress).
    for frac in [0.5, 0.25] {
        for &local in &[2usize, 5] {
            let mut cfg = base_config(&p, ((rounds as f64 * frac) as usize).max(4));
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = StrategyConfig::FedAvg { local_steps: local, rho_g: 0.0 };
            sweep.push("fedavg", &format!("rounds x{frac} local={local}"), cfg);
        }
    }

    sweep.execute(&p.out_dir)
}
