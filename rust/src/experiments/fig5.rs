//! Figure 5 (+ Figure 8/9 right breakdowns): PersonaChat validation
//! perplexity vs compression, and representative training-loss curves.
//!
//! Paper setup (§5.3/A.3): GPT2-small finetuned one epoch over 17,568
//! persona-partitioned clients, linear lr decay, metric = validation
//! perplexity. Substitute: decoder-only char-transformer over the
//! persona-conditioned synthetic corpus with power-law client sizes.
//!
//! With `curves = true`, representative runs additionally write
//! per-round training-loss JSONL (Figure 5 right).

use anyhow::Result;
use std::path::PathBuf;

use crate::config::{LrSchedule, StrategyConfig, TrainConfig};
use crate::experiments::runner::{ExperimentScale, Quality, Sweep, SweepRow};
use crate::model::DataScale;

pub struct Fig5Params {
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub curves: bool,
}

pub fn base_config(p: &Fig5Params, rounds: usize) -> TrainConfig {
    let clients = p.scale.clients(400);
    TrainConfig {
        task: "persona".into(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
        rounds,
        clients_per_round: 8,
        lr: LrSchedule::LinearDecay { lr: 0.25 },
        scale: DataScale {
            num_clients: clients,
            persona_max_size: 200,
            persona_alpha: 1.1,
            eval_batches: 8,
            ..DataScale::default()
        },
        eval_every: 0,
        seed: 31,
        artifacts_dir: p.artifacts_dir.clone(),
        log_path: None,
        baseline_rounds: None,
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

pub fn run(p: Fig5Params) -> Result<Vec<SweepRow>> {
    let rounds = p.scale.rounds(60);
    let mut sweep = Sweep::new("fig5_persona", Quality::Perplexity);
    let curve_dir = p.out_dir.join("curves");

    let maybe_log = |cfg: &mut TrainConfig, name: &str| {
        if p.curves {
            cfg.log_path = Some(curve_dir.join(format!("{name}.jsonl")));
        }
    };

    for frac in [1.0, 0.5] {
        let mut cfg = base_config(&p, ((rounds as f64 * frac) as usize).max(4));
        cfg.baseline_rounds = Some(rounds);
        maybe_log(&mut cfg, &format!("uncompressed_x{frac}"));
        sweep.push("uncompressed", &format!("rounds x{frac}"), cfg);
    }

    // FetchSGD grid (paper: k in [10k..200k], cols in {1.24M, 12.4M} for
    // d=124M; scaled to our d).
    for &k in &[1000usize, 5000] {
        for &cols in &[4096usize, 16384] {
            let mut cfg = base_config(&p, rounds);
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = StrategyConfig::FetchSgd {
                k,
                cols,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            };
            maybe_log(&mut cfg, &format!("fetchsgd_k{k}_c{cols}"));
            sweep.push("fetchsgd", &format!("k={k} cols={cols}"), cfg);
        }
    }

    // Local top-k without global momentum (paper: ρ_g hurts on this
    // task, Figure 5 caption) — we run both to reproduce that finding.
    for &k in &[1000usize, 5000, 20000] {
        for &rho_g in &[0.0f32, 0.9] {
            let mut cfg = base_config(&p, rounds);
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy =
                StrategyConfig::LocalTopK { k, rho_g, masking: true, local_error: false };
            maybe_log(&mut cfg, &format!("local_topk_k{k}_rho{rho_g}"));
            sweep.push("local_topk", &format!("k={k} rho_g={rho_g}"), cfg);
        }
    }

    // FedAvg: 2 and 5 local iterations (Table 1's configs).
    for frac in [0.5, 0.2] {
        for &local in &[2usize, 5] {
            let mut cfg = base_config(&p, ((rounds as f64 * frac) as usize).max(4));
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = StrategyConfig::FedAvg { local_steps: local, rho_g: 0.0 };
            maybe_log(&mut cfg, &format!("fedavg_x{frac}_l{local}"));
            sweep.push("fedavg", &format!("rounds x{frac} local={local}"), cfg);
        }
    }

    sweep.execute(&p.out_dir)
}
