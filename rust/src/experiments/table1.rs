//! Table 1: representative PersonaChat runs with standard deviations
//! over three random seeds — perplexity plus upload / download / total
//! compression for each named configuration.

use anyhow::Result;
use std::path::PathBuf;

use crate::config::{StrategyConfig, TrainConfig};
use crate::coordinator::Trainer;
use crate::experiments::fig5::{base_config, Fig5Params};
use crate::experiments::runner::ExperimentScale;
use crate::runtime::Runtime;
use crate::serialize::json::{num, obj, s};
use crate::util::stats::{mean, stddev};
use std::sync::Arc;

pub struct Table1Params {
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    pub seeds: usize,
}

struct NamedConfig {
    name: &'static str,
    strategy: StrategyConfig,
    round_frac: f64,
}

pub fn run(p: Table1Params) -> Result<()> {
    let fig5p = Fig5Params {
        scale: p.scale,
        artifacts_dir: p.artifacts_dir.clone(),
        out_dir: p.out_dir.clone(),
        curves: false,
    };
    let rounds = p.scale.rounds(60);
    // The table's seven representative configurations, scaled.
    let configs = vec![
        NamedConfig {
            name: "Uncompressed",
            strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
            round_frac: 1.0,
        },
        NamedConfig {
            name: "Local Top-k (small k)",
            strategy: StrategyConfig::LocalTopK {
                k: 1000,
                rho_g: 0.0,
                masking: true,
                local_error: false,
            },
            round_frac: 1.0,
        },
        NamedConfig {
            name: "Local Top-k (large k)",
            strategy: StrategyConfig::LocalTopK {
                k: 10000,
                rho_g: 0.0,
                masking: true,
                local_error: false,
            },
            round_frac: 1.0,
        },
        NamedConfig {
            name: "FedAvg (2 local iters)",
            strategy: StrategyConfig::FedAvg { local_steps: 2, rho_g: 0.0 },
            round_frac: 0.5,
        },
        NamedConfig {
            name: "FedAvg (5 local iters)",
            strategy: StrategyConfig::FedAvg { local_steps: 5, rho_g: 0.0 },
            round_frac: 0.2,
        },
        NamedConfig {
            name: "Sketch (narrow)",
            strategy: StrategyConfig::FetchSgd {
                k: 1000,
                cols: 4096,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            round_frac: 1.0,
        },
        NamedConfig {
            name: "Sketch (wide)",
            strategy: StrategyConfig::FetchSgd {
                k: 5000,
                cols: 16384,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            round_frac: 1.0,
        },
    ];

    std::fs::create_dir_all(&p.out_dir)?;
    let runtime = Arc::new(Runtime::cpu()?);
    println!("\n=== Table 1 (persona task, {} seeds) ===", p.seeds);
    println!(
        "{:<26} {:>16} {:>8} {:>8} {:>8}",
        "method", "ppl (mean±std)", "down", "up", "total"
    );
    let mut jsonl = String::new();
    for nc in configs {
        let mut ppls = Vec::new();
        let (mut up, mut down, mut overall) = (0.0, 0.0, 0.0);
        for seed in 0..p.seeds {
            let mut cfg: TrainConfig =
                base_config(&fig5p, ((rounds as f64 * nc.round_frac) as usize).max(4));
            cfg.baseline_rounds = Some(rounds);
            cfg.strategy = nc.strategy.clone();
            cfg.seed = 100 + seed as u64;
            let mut trainer = Trainer::with_runtime(cfg, runtime.clone())?;
            let summary = trainer.run()?;
            ppls.push(summary.perplexity);
            up = summary.ratios.upload;
            down = summary.ratios.download;
            overall = summary.ratios.overall;
        }
        let m = mean(&ppls);
        let sd = stddev(&ppls);
        println!(
            "{:<26} {:>9.2} ± {:<5.2} {:>7.1}x {:>7.1}x {:>7.1}x",
            nc.name, m, sd, down, up, overall
        );
        jsonl.push_str(
            &obj(vec![
                ("experiment", s("table1")),
                ("method", s(nc.name)),
                ("ppl_mean", num(m)),
                ("ppl_std", num(sd)),
                ("download", num(down)),
                ("upload", num(up)),
                ("total", num(overall)),
            ])
            .to_json(),
        );
        jsonl.push('\n');
    }
    std::fs::write(p.out_dir.join("table1.jsonl"), jsonl)?;
    println!("\n[table1] wrote {}", p.out_dir.join("table1.jsonl").display());
    Ok(())
}
