//! Ablations over FetchSGD's design choices (DESIGN.md §4 abl1–abl4):
//!
//! - `zero_vs_subtract` — §5's empirical stabilization (zero out the
//!   extracted coordinates of S_e) vs Algorithm 1's exact subtraction;
//! - `masking` — momentum factor masking on/off;
//! - `sliding_window` — vanilla error sketch vs the ring-of-I and
//!   log(I) sliding-window accumulators of §4.2 / Appendix D;
//! - `momentum` — ρ = 0 (Theorem 2's setting) vs ρ = 0.9 (Theorem 1's).

use anyhow::Result;
use std::path::PathBuf;

use crate::config::{LrSchedule, StrategyConfig, TrainConfig};
use crate::experiments::runner::{ExperimentScale, Quality, Sweep, SweepRow};
use crate::model::DataScale;

pub struct AblationParams {
    pub which: String,
    pub scale: ExperimentScale,
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
}

fn base_config(p: &AblationParams, rounds: usize) -> TrainConfig {
    let clients = p.scale.clients(200);
    TrainConfig {
        task: "cifar10".into(),
        strategy: StrategyConfig::Uncompressed { rho_g: 0.9 },
        rounds,
        clients_per_round: (clients / 20).max(2),
        lr: LrSchedule::Triangular { peak: 0.02, pivot: 0.2 },
        scale: DataScale {
            num_clients: clients,
            samples_per_client: 5,
            eval_batches: 8,
            partition: "label_skew".into(),
            ..DataScale::default()
        },
        eval_every: 0,
        seed: 41,
        artifacts_dir: p.artifacts_dir.clone(),
        log_path: None,
        baseline_rounds: None,
        verbose: false,
        parallelism: 0,
        ..TrainConfig::default_smoke()
    }
}

fn fetchsgd(
    k: usize,
    cols: usize,
    rho: f32,
    error_update: &str,
    error_window: &str,
    masking: bool,
) -> StrategyConfig {
    StrategyConfig::FetchSgd {
        k,
        cols,
        rho,
        error_update: error_update.into(),
        error_window: error_window.into(),
        masking,
    }
}

pub fn run(p: AblationParams) -> Result<Vec<SweepRow>> {
    let rounds = p.scale.rounds(60);
    let (k, cols) = (5000usize, 8192usize);
    let mut sweep = Sweep::new(&format!("ablation_{}", p.which), Quality::Accuracy);

    let variants: Vec<(String, StrategyConfig)> = match p.which.as_str() {
        "zero_vs_subtract" => vec![
            ("zero_out".into(), fetchsgd(k, cols, 0.9, "zero_out", "vanilla", true)),
            ("subtract".into(), fetchsgd(k, cols, 0.9, "subtract", "vanilla", true)),
        ],
        "masking" => vec![
            ("masking=on".into(), fetchsgd(k, cols, 0.9, "zero_out", "vanilla", true)),
            ("masking=off".into(), fetchsgd(k, cols, 0.9, "zero_out", "vanilla", false)),
        ],
        "sliding_window" => vec![
            ("vanilla".into(), fetchsgd(k, cols, 0.9, "zero_out", "vanilla", true)),
            ("ring:4".into(), fetchsgd(k, cols, 0.9, "zero_out", "ring:4", true)),
            ("ring:16".into(), fetchsgd(k, cols, 0.9, "zero_out", "ring:16", true)),
            ("log:16".into(), fetchsgd(k, cols, 0.9, "zero_out", "log:16", true)),
        ],
        "momentum" => vec![
            ("rho=0".into(), fetchsgd(k, cols, 0.0, "zero_out", "vanilla", true)),
            ("rho=0.9".into(), fetchsgd(k, cols, 0.9, "zero_out", "vanilla", true)),
        ],
        other => anyhow::bail!(
            "unknown ablation '{other}' \
             (zero_vs_subtract | masking | sliding_window | momentum)"
        ),
    };

    for (label, strat) in variants {
        let mut cfg = base_config(&p, rounds);
        cfg.baseline_rounds = Some(rounds);
        cfg.strategy = strat;
        sweep.push("fetchsgd", &label, cfg);
    }

    sweep.execute(&p.out_dir)
}
