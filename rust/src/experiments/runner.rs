//! Shared experiment machinery: scales, sweep execution, result tables,
//! Pareto frontiers, JSONL dumps.

use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::Arc;

use crate::config::TrainConfig;
use crate::coordinator::Trainer;
use crate::metrics::pareto::{pareto_frontier, RunPoint};
use crate::runtime::Runtime;
use crate::serialize::json::{num, obj, s};

/// Experiment scale: smoke (CI-fast), small (default; minutes), full
/// (closer to paper workloads; hours on CPU).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExperimentScale {
    Smoke,
    Small,
    Full,
}

impl ExperimentScale {
    pub fn parse(sc: &str) -> Result<Self> {
        match sc {
            "smoke" => Ok(Self::Smoke),
            "small" => Ok(Self::Small),
            "full" => Ok(Self::Full),
            other => anyhow::bail!("unknown scale '{other}' (smoke|small|full)"),
        }
    }

    /// Multiplier applied to round counts.
    pub fn round_mult(self) -> f64 {
        match self {
            Self::Smoke => 0.15,
            Self::Small => 1.0,
            Self::Full => 4.0,
        }
    }

    /// Multiplier applied to client populations.
    pub fn client_mult(self) -> f64 {
        match self {
            Self::Smoke => 0.25,
            Self::Small => 1.0,
            Self::Full => 4.0,
        }
    }

    pub fn rounds(self, base: usize) -> usize {
        ((base as f64 * self.round_mult()) as usize).max(4)
    }

    pub fn clients(self, base: usize) -> usize {
        ((base as f64 * self.client_mult()) as usize).max(8)
    }
}

/// Quality metric direction for Pareto extraction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Quality {
    Accuracy,
    Perplexity,
}

/// One completed run in a sweep.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub method: String,
    pub label: String,
    pub up: f64,
    pub down: f64,
    pub overall: f64,
    pub quality: f64,
    pub eval_loss: f64,
    pub final_train_loss: f64,
}

/// A set of labeled configs to run and report together.
pub struct Sweep {
    pub name: String,
    pub quality: Quality,
    pub runs: Vec<(String, String, TrainConfig)>, // (method, label, config)
}

impl Sweep {
    pub fn new(name: &str, quality: Quality) -> Self {
        Sweep { name: name.to_string(), quality, runs: Vec::new() }
    }

    pub fn push(&mut self, method: &str, label: &str, cfg: TrainConfig) {
        self.runs.push((method.to_string(), label.to_string(), cfg));
    }

    /// Execute all runs with one shared PJRT runtime, print tables, and
    /// dump JSONL into `results/`.
    pub fn execute(mut self, out_dir: &PathBuf) -> Result<Vec<SweepRow>> {
        std::fs::create_dir_all(out_dir)?;
        let runtime = Arc::new(Runtime::cpu()?);
        let total = self.runs.len();
        let mut rows = Vec::new();
        let runs = std::mem::take(&mut self.runs);
        for (i, (method, label, cfg)) in runs.into_iter().enumerate() {
            eprintln!("[{}] run {}/{total}: {method} {label}", self.name, i + 1);
            let t0 = std::time::Instant::now();
            let mut trainer = Trainer::with_runtime(cfg, runtime.clone())
                .with_context(|| format!("building trainer for {method} {label}"))?;
            let summary = trainer.run().with_context(|| format!("run {method} {label}"))?;
            let quality = match self.quality {
                Quality::Accuracy => summary.accuracy,
                Quality::Perplexity => summary.perplexity,
            };
            eprintln!(
                "[{}]   -> quality {quality:.4} (eval loss {:.4}) overall {:.1}x in {:.1}s",
                self.name,
                summary.eval_loss,
                summary.ratios.overall,
                t0.elapsed().as_secs_f64()
            );
            rows.push(SweepRow {
                method,
                label,
                up: summary.ratios.upload,
                down: summary.ratios.download,
                overall: summary.ratios.overall,
                quality,
                eval_loss: summary.eval_loss,
                final_train_loss: summary.final_loss,
            });
        }
        self.report(&rows, out_dir)?;
        Ok(rows)
    }

    fn report(&self, rows: &[SweepRow], out_dir: &PathBuf) -> Result<()> {
        let metric = match self.quality {
            Quality::Accuracy => "accuracy",
            Quality::Perplexity => "perplexity",
        };
        println!("\n=== {} (all runs) ===", self.name);
        println!(
            "{:<14} {:<34} {:>8} {:>8} {:>9} {:>12}",
            "method", "params", "up", "down", "overall", metric
        );
        for r in rows {
            println!(
                "{:<14} {:<34} {:>7.1}x {:>7.1}x {:>8.1}x {:>12.4}",
                r.method, r.label, r.up, r.down, r.overall, r.quality
            );
        }
        // Pareto frontier per method (the paper's presentation).
        let higher_better = self.quality == Quality::Accuracy;
        let mut methods: Vec<String> = rows.iter().map(|r| r.method.clone()).collect();
        methods.sort();
        methods.dedup();
        println!("\n--- Pareto frontier (overall compression vs {metric}) ---");
        for m in &methods {
            let pts: Vec<RunPoint> = rows
                .iter()
                .filter(|r| &r.method == m)
                .map(|r| RunPoint {
                    compression: r.overall,
                    quality: r.quality,
                    label: r.label.clone(),
                })
                .collect();
            for p in pareto_frontier(&pts, higher_better) {
                println!("{m:<14} {:<34} {:>8.1}x {:>12.4}", p.label, p.compression, p.quality);
            }
        }
        // JSONL dump.
        let path = out_dir.join(format!("{}.jsonl", self.name));
        let mut out = String::new();
        for r in rows {
            out.push_str(
                &obj(vec![
                    ("experiment", s(&self.name)),
                    ("method", s(&r.method)),
                    ("label", s(&r.label)),
                    ("up", num(r.up)),
                    ("down", num(r.down)),
                    ("overall", num(r.overall)),
                    (metric, num(r.quality)),
                    ("eval_loss", num(r.eval_loss)),
                    ("final_train_loss", num(r.final_train_loss)),
                ])
                .to_json(),
            );
            out.push('\n');
        }
        std::fs::write(&path, out)?;
        println!("\n[{}] wrote {}", self.name, path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_multipliers() {
        assert_eq!(ExperimentScale::Small.rounds(60), 60);
        assert!(ExperimentScale::Smoke.rounds(60) < 15);
        assert_eq!(ExperimentScale::Full.rounds(60), 240);
        assert!(ExperimentScale::Smoke.clients(100) >= 8);
        assert!(ExperimentScale::parse("small").is_ok());
        assert!(ExperimentScale::parse("nope").is_err());
    }
}
