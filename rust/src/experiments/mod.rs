//! Experiment drivers: regenerate every table and figure of the paper's
//! evaluation at a configurable scale (DESIGN.md §4 maps each driver to
//! its paper artifact).

pub mod ablations;
pub mod assumption;
pub mod fig10;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod runner;
pub mod table1;

pub use runner::{ExperimentScale, SweepRow};
