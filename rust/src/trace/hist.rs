//! Fixed-bucket, log-spaced latency histograms.
//!
//! Buckets are powers of two of microseconds: bucket 0 holds the value
//! 0, bucket `i ≥ 1` holds `[2^(i-1), 2^i)` µs. The layout is a
//! constant of the format — every histogram ever emitted uses the same
//! bucket edges — so merging histograms from different processes,
//! rounds, or relay tiers is *exact*: counts add, nothing is resampled.
//! 48 buckets cover [0, 2^47) µs ≈ 4.5 years, comfortably past any
//! round duration.
//!
//! Percentiles are read off the merged counts and quoted as the upper
//! edge of the bucket the rank falls in (clamped to the largest value
//! actually observed), so a quoted p99 is an upper bound with at most
//! one octave of slack — the standard trade of log-bucketed recorders.

use anyhow::{bail, Result};

use crate::serialize::json::{arr, num, Value};

/// Number of power-of-two buckets. A format constant: changing it
/// breaks exact merging with previously written traces.
pub const NUM_BUCKETS: usize = 48;

/// A log-spaced latency histogram over microsecond values.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; NUM_BUCKETS],
    total: u64,
    /// Largest value recorded (exact, not bucketed) — clamps quoted
    /// percentiles so p99 never exceeds the observed maximum.
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { counts: [0; NUM_BUCKETS], total: 0, max: 0 }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    /// Bucket index for a microsecond value: 0 for 0, else
    /// `floor(log2(v)) + 1`, clamped to the last bucket.
    pub fn bucket_of(v_us: u64) -> usize {
        if v_us == 0 {
            0
        } else {
            ((64 - v_us.leading_zeros()) as usize).min(NUM_BUCKETS - 1)
        }
    }

    /// Inclusive value range `[lo, hi]` of bucket `i`.
    pub fn bucket_bounds(i: usize) -> (u64, u64) {
        match i {
            0 => (0, 0),
            _ => (1u64 << (i - 1), if i >= 63 { u64::MAX } else { (1u64 << i) - 1 }),
        }
    }

    pub fn record(&mut self, v_us: u64) {
        self.counts[Self::bucket_of(v_us)] += 1;
        self.total += 1;
        self.max = self.max.max(v_us);
    }

    /// Exact merge: counts add (the bucket layout is shared by
    /// construction), the observed max is the max of maxes.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn max_us(&self) -> u64 {
        self.max
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Value at quantile `q ∈ [0, 1]`: the upper edge of the bucket the
    /// rank `ceil(q · total)` falls in, clamped to the observed max.
    /// 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = ((q * self.total as f64).ceil() as u64).clamp(1, self.total);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_bounds(i).1.min(self.max);
            }
        }
        self.max
    }

    /// Sparse `[bucket, count]` pairs for the JSONL `hist` event.
    pub fn sparse_buckets(&self) -> Value {
        arr(self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| arr(vec![num(i as f64), num(c as f64)]))
            .collect())
    }

    /// Rebuild from a `hist` event's `buckets` array plus its `max_us`.
    /// The inverse of [`Histogram::sparse_buckets`]; merging the result
    /// with other parsed histograms is as exact as merging the
    /// originals.
    pub fn from_sparse(buckets: &[Value], max_us: u64) -> Result<Histogram> {
        let mut h = Histogram::new();
        for pair in buckets {
            let p = pair.as_array().filter(|p| p.len() == 2);
            let Some([i, c]) = p.map(|p| [&p[0], &p[1]]) else {
                bail!("hist bucket entries must be [index, count] pairs");
            };
            let (Some(i), Some(c)) = (i.as_usize(), c.as_u64()) else {
                bail!("hist bucket entries must be numeric [index, count] pairs");
            };
            if i >= NUM_BUCKETS {
                bail!("hist bucket index {i} out of range (format has {NUM_BUCKETS} buckets)");
            }
            h.counts[i] += c;
            h.total += c;
        }
        h.max = max_us;
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_is_power_of_two_octaves() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(1023), 10);
        assert_eq!(Histogram::bucket_of(1024), 11);
        assert_eq!(Histogram::bucket_of(u64::MAX), NUM_BUCKETS - 1);
        for i in 0..NUM_BUCKETS {
            let (lo, hi) = Histogram::bucket_bounds(i);
            assert_eq!(Histogram::bucket_of(lo), i, "lower edge of bucket {i}");
            assert!(lo <= hi);
        }
    }

    #[test]
    fn percentiles_quote_bucket_upper_edges_clamped_to_max() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max_us(), 1000);
        // p50 rank 3 → value 30 lives in bucket [16,31].
        assert_eq!(h.percentile(0.5), 31);
        // p99 rank 5 → bucket [512,1023], clamped to the observed 1000.
        assert_eq!(h.percentile(0.99), 1000);
        assert_eq!(Histogram::new().percentile(0.5), 0);
    }

    #[test]
    fn merge_is_exact_and_order_independent() {
        let samples: Vec<u64> = (0..200).map(|i| i * i % 7919).collect();
        let mut whole = Histogram::new();
        for &v in &samples {
            whole.record(v);
        }
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &v) in samples.iter().enumerate() {
            if i % 3 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
        }
        let mut merged = b.clone();
        merged.merge(&a);
        assert_eq!(merged, whole, "split+merge must equal the unsplit histogram");
        let mut other_order = a;
        other_order.merge(&b);
        assert_eq!(other_order, whole);
    }

    #[test]
    fn sparse_roundtrip_preserves_counts() {
        let mut h = Histogram::new();
        for v in [0u64, 1, 5, 5, 900, 1 << 20] {
            h.record(v);
        }
        let v = h.sparse_buckets();
        let back = Histogram::from_sparse(v.as_array().unwrap(), h.max_us()).unwrap();
        assert_eq!(back, h);
        assert!(Histogram::from_sparse(&[num(3.0)], 0).is_err());
    }
}
