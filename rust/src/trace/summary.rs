//! Fold one or more trace files (the per-tier outputs of an engine run,
//! a round server, and its relays) into a per-phase, per-tier breakdown
//! — the library behind `fetchsgd trace-summary`.
//!
//! Merging needs no synchronized clocks: spans fold by *duration*
//! (per-process), slot events by count, and histograms bucket-exactly
//! (`trace::hist`), so a depth-N tree's files can be folded in any
//! order and the result is the same.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use crate::serialize::json::{parse, Value};
use crate::trace::{Histogram, Phase};

/// Aggregate of one (tier, phase) cell: how many spans and how much
/// wall-clock they covered.
#[derive(Clone, Copy, Debug, Default)]
pub struct PhaseAgg {
    pub count: u64,
    pub total_us: u64,
    pub max_us: u64,
}

impl PhaseAgg {
    fn add(&mut self, dur_us: u64) {
        self.count += 1;
        self.total_us += dur_us;
        self.max_us = self.max_us.max(dur_us);
    }
}

/// One round's reconstructed timeline across every tier that reported.
#[derive(Clone, Debug, Default)]
pub struct RoundTimeline {
    /// (tier, phase) → folded spans.
    pub phases: BTreeMap<(String, String), PhaseAgg>,
    /// (tier, slot event) → occurrences.
    pub events: BTreeMap<(String, String), u64>,
}

/// Everything `fold_files` extracts from a set of trace files.
#[derive(Clone, Debug, Default)]
pub struct TraceReport {
    pub files: usize,
    /// (tier, source) header of each file, in input order.
    pub sources: Vec<(String, String)>,
    pub rounds: BTreeMap<u64, RoundTimeline>,
    /// Run-level (tier, phase) totals across all rounds.
    pub phase_totals: BTreeMap<(String, String), PhaseAgg>,
    /// Run-level (tier, slot event) counts.
    pub event_counts: BTreeMap<(String, String), u64>,
    /// (tier, metric) → exactly merged histograms (per-round and
    /// run-level `hist` events all fold in).
    pub hists: BTreeMap<(String, String), Histogram>,
    /// Per-connection IO totals: (tier, peer) → (stall, read, write) µs.
    pub conn_totals: BTreeMap<(String, u64), (u64, u64, u64)>,
    /// Lines whose `type` this version does not know (skipped, counted
    /// so truncation is visible rather than silent).
    pub unknown_lines: usize,
}

impl TraceReport {
    /// Tiers seen in any header or event, in deterministic order.
    pub fn tiers(&self) -> Vec<String> {
        let mut tiers: Vec<String> = self.sources.iter().map(|(t, _)| t.clone()).collect();
        for (tier, _) in self.phase_totals.keys() {
            tiers.push(tier.clone());
        }
        tiers.sort();
        tiers.dedup();
        tiers
    }
}

/// Parse and fold one trace file's text into `report`. Malformed JSON
/// or a malformed known event is an error (a trace produced by this
/// build must round-trip); *unknown* event types are skipped and
/// counted, so newer traces degrade gracefully.
pub fn fold_text(report: &mut TraceReport, text: &str, origin: &str) -> Result<()> {
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = parse(line)
            .with_context(|| format!("{origin}:{}: malformed trace line", lineno + 1))?;
        fold_event(report, &v)
            .with_context(|| format!("{origin}:{}: malformed trace event", lineno + 1))?;
    }
    Ok(())
}

/// Fold several trace files (one per tier of a relay tree, typically)
/// into one report.
pub fn fold_files<P: AsRef<Path>>(paths: &[P]) -> Result<TraceReport> {
    if paths.is_empty() {
        bail!("trace-summary needs at least one trace file");
    }
    let mut report = TraceReport::default();
    for p in paths {
        let p = p.as_ref();
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading trace file {}", p.display()))?;
        report.files += 1;
        fold_text(&mut report, &text, &p.display().to_string())?;
    }
    Ok(report)
}

fn fold_event(report: &mut TraceReport, v: &Value) -> Result<()> {
    match v.req_str("type")? {
        "trace_meta" => {
            report
                .sources
                .push((v.req_str("tier")?.to_string(), v.req_str("source")?.to_string()));
        }
        "span" => {
            let tier = v.req_str("tier")?.to_string();
            let phase = v.req_str("phase")?.to_string();
            let round = v.req_u64("round")?;
            let dur = v.req_u64("dur_us")?;
            let key = (tier, phase);
            report.rounds.entry(round).or_default().phases.entry(key.clone()).or_default().add(dur);
            report.phase_totals.entry(key).or_default().add(dur);
        }
        "slot" => {
            let tier = v.req_str("tier")?.to_string();
            let event = v.req_str("event")?.to_string();
            let round = v.req_u64("round")?;
            v.req_u64("slot")?;
            let key = (tier, event);
            *report.rounds.entry(round).or_default().events.entry(key.clone()).or_default() += 1;
            *report.event_counts.entry(key).or_default() += 1;
        }
        "conn" => {
            let key = (v.req_str("tier")?.to_string(), v.req_u64("peer")?);
            let (stall, read, write) = report.conn_totals.entry(key).or_default();
            *stall += v.req_u64("stall_us")?;
            *read += v.req_u64("read_us")?;
            *write += v.req_u64("write_us")?;
        }
        "hist" => {
            let key = (v.req_str("tier")?.to_string(), v.req_str("metric")?.to_string());
            let h = Histogram::from_sparse(v.req_array("buckets")?, v.req_u64("max_us")?)?;
            report.hists.entry(key).or_default().merge(&h);
        }
        _ => report.unknown_lines += 1,
    }
    Ok(())
}

const MS: f64 = 1e3;

/// Phases in canonical order first, then any stragglers alphabetically
/// — keeps `plan → compute → … → broadcast` reading top to bottom.
fn phase_rank(name: &str) -> usize {
    Phase::ALL.iter().position(|p| p.as_str() == name).unwrap_or(Phase::ALL.len())
}

const EVENT_ORDER: [&str; 8] =
    ["offered", "validated", "absorbed", "parked", "folded", "retried", "reassigned", "dropped"];

/// Render the folded report as the human-readable breakdown
/// `fetchsgd trace-summary` prints.
pub fn render(r: &TraceReport) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace summary: {} file(s), {} round(s), tiers: {}",
        r.files,
        r.rounds.len(),
        if r.tiers().is_empty() { "(none)".to_string() } else { r.tiers().join(", ") }
    );
    for (tier, source) in &r.sources {
        let _ = writeln!(out, "  source [{tier}] {source}");
    }
    if r.unknown_lines > 0 {
        let _ = writeln!(out, "  ({} line(s) of unknown type skipped)", r.unknown_lines);
    }

    if !r.phase_totals.is_empty() {
        let _ = writeln!(out, "\nper-phase totals (all rounds):");
        let _ = writeln!(
            out,
            "  {:<8} {:<12} {:>7} {:>12} {:>10} {:>10}",
            "tier", "phase", "spans", "total_ms", "mean_ms", "max_ms"
        );
        let mut keys: Vec<&(String, String)> = r.phase_totals.keys().collect();
        keys.sort_by_key(|(tier, phase)| (tier.clone(), phase_rank(phase), phase.clone()));
        for key in keys {
            let a = &r.phase_totals[key];
            let _ = writeln!(
                out,
                "  {:<8} {:<12} {:>7} {:>12.3} {:>10.3} {:>10.3}",
                key.0,
                key.1,
                a.count,
                a.total_us as f64 / MS,
                a.total_us as f64 / MS / a.count.max(1) as f64,
                a.max_us as f64 / MS,
            );
        }
    }

    if !r.event_counts.is_empty() {
        let _ = writeln!(out, "\nslot events (all rounds):");
        for tier in r.tiers() {
            let mut cells = Vec::new();
            for ev in EVENT_ORDER {
                if let Some(n) = r.event_counts.get(&(tier.clone(), ev.to_string())) {
                    cells.push(format!("{ev} {n}"));
                }
            }
            if !cells.is_empty() {
                let _ = writeln!(out, "  {:<8} {}", tier, cells.join("  "));
            }
        }
    }

    if !r.hists.is_empty() {
        let _ = writeln!(out, "\nlatency percentiles (log-bucket upper bounds):");
        let _ = writeln!(
            out,
            "  {:<8} {:<18} {:>7} {:>10} {:>10} {:>10} {:>10}",
            "tier", "metric", "count", "p50_ms", "p90_ms", "p99_ms", "max_ms"
        );
        for ((tier, metric), h) in &r.hists {
            let _ = writeln!(
                out,
                "  {:<8} {:<18} {:>7} {:>10.3} {:>10.3} {:>10.3} {:>10.3}",
                tier,
                metric,
                h.count(),
                h.percentile(0.5) as f64 / MS,
                h.percentile(0.9) as f64 / MS,
                h.percentile(0.99) as f64 / MS,
                h.max_us() as f64 / MS,
            );
        }
    }

    if !r.conn_totals.is_empty() {
        let _ = writeln!(out, "\nper-connection IO (all rounds):");
        for ((tier, peer), (stall, read, write)) in &r.conn_totals {
            let _ = writeln!(
                out,
                "  {:<8} peer {:<4} stall {:>9.3} ms  read {:>9.3} ms  write {:>9.3} ms",
                tier,
                peer,
                *stall as f64 / MS,
                *read as f64 / MS,
                *write as f64 / MS,
            );
        }
    }

    if !r.rounds.is_empty() {
        let _ = writeln!(out, "\nper-round timeline:");
        for (round, tl) in &r.rounds {
            let _ = writeln!(out, "  round {round}:");
            let mut tiers: Vec<String> =
                tl.phases.keys().map(|(t, _)| t.clone()).collect::<Vec<_>>();
            tiers.extend(tl.events.keys().map(|(t, _)| t.clone()));
            tiers.sort();
            tiers.dedup();
            for tier in tiers {
                let mut cells = Vec::new();
                let mut phases: Vec<&(String, String)> =
                    tl.phases.keys().filter(|(t, _)| *t == tier).collect();
                phases.sort_by_key(|(_, p)| (phase_rank(p), p.clone()));
                for key in phases {
                    let a = &tl.phases[key];
                    cells.push(format!("{} {:.3}ms", key.1, a.total_us as f64 / MS));
                }
                for ev in EVENT_ORDER {
                    if let Some(n) = tl.events.get(&(tier.clone(), ev.to_string())) {
                        cells.push(format!("{ev}×{n}"));
                    }
                }
                let _ = writeln!(out, "    {:<8} {}", tier, cells.join("  "));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Phase, SlotEvent, TraceSink};

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fsgd_tsum_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    /// Write two tiers' trace files the way a root + one relay would,
    /// then fold them back into one timeline.
    #[test]
    fn folds_multi_tier_files_into_one_timeline() {
        let dir = tmpdir("fold");
        let root_p = dir.join("root.jsonl");
        let relay_p = dir.join("relay.jsonl");
        {
            let root = TraceSink::create(&root_p, "root", "uds:/tmp/root.sock").unwrap();
            let relay = TraceSink::create(&relay_p, "relay", "uds:/tmp/relay0.sock").unwrap();
            for round in 0..2u64 {
                let t0 = root.now_us();
                root.span(round, Phase::AbsorbWait, t0, t0 + 800);
                root.span(round, Phase::Reduce, t0 + 800, t0 + 1000);
                root.slot_event(round, 0, SlotEvent::Offered, Some(0));
                root.slot_event(round, 0, SlotEvent::Absorbed, None);
                let r0 = relay.now_us();
                relay.span(round, Phase::AbsorbWait, r0, r0 + 300);
                relay.slot_event(round, 1, SlotEvent::Offered, Some(1));
                let mut h = Histogram::new();
                h.record(100 + round * 50);
                relay.histogram(Some(round), "slot_arrival_us", &h);
            }
            root.flush().unwrap();
            relay.flush().unwrap();
        }
        let report = fold_files(&[&root_p, &relay_p]).unwrap();
        assert_eq!(report.files, 2);
        assert_eq!(report.tiers(), vec!["relay".to_string(), "root".to_string()]);
        assert_eq!(report.rounds.len(), 2);
        // Both tiers land in one round's timeline.
        let r0 = &report.rounds[&0];
        assert!(r0.phases.contains_key(&("root".into(), "absorb_wait".into())));
        assert!(r0.phases.contains_key(&("relay".into(), "absorb_wait".into())));
        let agg = &report.phase_totals[&("root".into(), "absorb_wait".into())];
        assert_eq!(agg.count, 2);
        assert_eq!(agg.total_us, 1600);
        assert_eq!(report.event_counts[&("root".into(), "absorbed".into())], 2);
        // Per-round histograms merged exactly across rounds.
        let h = &report.hists[&("relay".into(), "slot_arrival_us".into())];
        assert_eq!(h.count(), 2);
        let text = render(&report);
        assert!(text.contains("per-phase totals"), "{text}");
        assert!(text.contains("per-round timeline"), "{text}");
        assert!(text.contains("round 1:"), "{text}");
        assert!(text.contains("absorb_wait"), "{text}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unknown_event_types_are_skipped_not_fatal() {
        let mut report = TraceReport::default();
        fold_text(
            &mut report,
            "{\"type\":\"future_thing\",\"round\":0}\n",
            "inline",
        )
        .unwrap();
        assert_eq!(report.unknown_lines, 1);
        // Malformed JSON and malformed known events are loud.
        assert!(fold_text(&mut TraceReport::default(), "{nope", "inline").is_err());
        assert!(fold_text(&mut TraceReport::default(), "{\"type\":\"span\"}", "inline").is_err());
    }
}
