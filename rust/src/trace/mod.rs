//! Structured round tracing: phase spans, per-slot timelines, and
//! latency histograms, written as typed JSONL next to the metrics log.
//!
//! Hand-rolled like `serialize::json` (no `tracing` crate): a
//! [`TraceSink`] is a buffered JSONL writer that every driver —
//! `coordinator::engine`, `transport::server`, `relay` — stamps events
//! into. Each event carries the `round` and the emitting `tier`
//! (`"engine"` for in-process runs, `"root"` for a round server,
//! `"relay"` for a mid-tier aggregator), so the per-tier files of a
//! relay tree merge back into one timeline (`trace::summary`, surfaced
//! as `fetchsgd trace-summary`).
//!
//! ## Event grammar (one JSON object per line)
//!
//! | `type`       | fields |
//! |--------------|--------|
//! | `trace_meta` | `v`, `tier`, `source`, `epoch_unix_ms` — first line of every file |
//! | `span`       | `tier`, `round`, `phase`, `start_us`, `dur_us` |
//! | `slot`       | `tier`, `round`, `slot`, `event`, `t_us` [, `peer`][, `reason`] |
//! | `conn`       | `tier`, `round`, `peer`, `stall_us`, `read_us`, `write_us` |
//! | `hist`       | `tier`, `metric`, `count`, `max_us`, `p50_us`, `p90_us`, `p99_us`, `buckets` [, `round`] |
//!
//! Phases are `plan`, `compute`, `absorb_wait`, `reduce`, `finalize`,
//! `broadcast`; slot events are `offered`, `validated`, `absorbed`,
//! `parked`, `folded`, `retried`, `reassigned`, `dropped`. Times are
//! microseconds since the sink's epoch (`epoch_unix_ms` anchors that
//! epoch to the wall clock, so cross-process offsets can be aligned
//! approximately; the summary tool never needs synchronized clocks —
//! it folds durations, which are per-process).
//!
//! ## Contract
//!
//! - **Disabled is free.** Every call site guards on an
//!   `Option<&TraceSink>` (or the `Option` field inside
//!   `RoundInFlight`): with tracing off the hot paths perform no
//!   timing syscalls and no allocation — verified by the trace-off row
//!   of `benches/bench_round.rs`.
//! - **Bounded buffering when enabled.** Lines accumulate in a mutex'd
//!   buffer flushed at [`FLUSH_BYTES`]; after the first write error the
//!   sink stops recording (the error is surfaced on flush/drop), so a
//!   full disk can't grow the buffer without bound.
//! - **Bitwise-neutral always.** Timestamps are observability, never
//!   inputs: nothing read from the clock feeds aggregation, scheduling
//!   of slots, or any value that reaches an accumulator. The
//!   determinism matrix runs green with tracing on.

pub mod hist;
pub mod summary;

pub use hist::Histogram;

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;
use std::sync::Mutex;
use std::time::Instant;

use crate::serialize::json::{num, obj, s, Value};

/// Flush threshold for the line buffer — the bound in "bounded
/// buffering".
pub const FLUSH_BYTES: usize = 64 * 1024;

/// Trace format version, stamped into `trace_meta`.
pub const TRACE_VERSION: u64 = 1;

/// A round's lifecycle phases. Which phases a tier emits depends on
/// where its time can go: an in-process engine computes and absorbs in
/// one pool (`compute`), a round server waits for remote uploads
/// (`absorb_wait`), and both reduce, finalize, and broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Plan,
    Compute,
    AbsorbWait,
    Reduce,
    Finalize,
    Broadcast,
}

impl Phase {
    pub fn as_str(self) -> &'static str {
        match self {
            Phase::Plan => "plan",
            Phase::Compute => "compute",
            Phase::AbsorbWait => "absorb_wait",
            Phase::Reduce => "reduce",
            Phase::Finalize => "finalize",
            Phase::Broadcast => "broadcast",
        }
    }

    /// Canonical presentation order for summary tables.
    pub const ALL: [Phase; 6] = [
        Phase::Plan,
        Phase::Compute,
        Phase::AbsorbWait,
        Phase::Reduce,
        Phase::Finalize,
        Phase::Broadcast,
    ];
}

/// One step of a slot's arrival timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotEvent {
    /// The driver handed the slot's upload to the round (engine worker
    /// finished compute; server read a frame off a connection).
    Offered,
    /// The pipeline parsed and shape-validated the upload's frame.
    Validated,
    /// The upload folded into its shard accumulator on arrival.
    Absorbed,
    /// The upload arrived ahead of an earlier slot of its shard and was
    /// parked.
    Parked,
    /// A parked upload's deferred fold finally ran.
    Folded,
    /// The slot's compute or delivery failed and was retried.
    Retried,
    /// The slot was reassigned to another worker connection.
    Reassigned,
    /// The slot was excluded from the round (carries a `reason`).
    Dropped,
}

impl SlotEvent {
    pub fn as_str(self) -> &'static str {
        match self {
            SlotEvent::Offered => "offered",
            SlotEvent::Validated => "validated",
            SlotEvent::Absorbed => "absorbed",
            SlotEvent::Parked => "parked",
            SlotEvent::Folded => "folded",
            SlotEvent::Retried => "retried",
            SlotEvent::Reassigned => "reassigned",
            SlotEvent::Dropped => "dropped",
        }
    }
}

/// Wall-clock phase durations of one round, in milliseconds — the
/// aggregate numbers surfaced in `RoundRecord` / `RunSummary` /
/// `ServeSummary` whether or not a trace file is attached.
///
/// `round_ms` is always measured (a handful of per-round clock reads,
/// nowhere near a hot path). `absorb_ms` is the *cumulative* time spent
/// inside pipeline offers, which requires per-upload timing — so it is
/// only measured while a trace sink is attached and stays 0 otherwise,
/// keeping the disabled hot path syscall-free.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundTiming {
    /// Full wall-clock round duration.
    pub round_ms: f64,
    /// Client-compute phase (engine worker pool span; 0 for a round
    /// server, whose compute is remote).
    pub compute_ms: f64,
    /// Cumulative time folding uploads into shard accumulators (traced
    /// runs only), or the server's absorb-wait span.
    pub absorb_ms: f64,
    /// Shard reduce + finalize span.
    pub reduce_ms: f64,
}

impl RoundTiming {
    pub fn accumulate(&mut self, other: &RoundTiming) {
        self.round_ms += other.round_ms;
        self.compute_ms += other.compute_ms;
        self.absorb_ms += other.absorb_ms;
        self.reduce_ms += other.reduce_ms;
    }
}

/// Convert an elapsed `Instant` span to milliseconds.
pub fn ms_since(start: Instant) -> f64 {
    start.elapsed().as_secs_f64() * 1e3
}

/// Per-connection IO time split a transport reader accumulates over one
/// round and emits as a `conn` event (see [`TraceSink::conn`]): time
/// blocked waiting for a peer's next message to start, time consuming
/// message bodies, time writing to the peer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ConnIo {
    pub stall_us: u64,
    pub read_us: u64,
    pub write_us: u64,
}

/// Identity of one traced transport connection: the sink plus the
/// `(round, peer)` stamp every event it emits carries. `Copy` so reader
/// loops pass it by value; the mutable accumulator travels separately
/// (see [`ConnIo`]).
#[derive(Clone, Copy)]
pub struct ConnTrace<'a> {
    pub sink: &'a TraceSink,
    pub round: u64,
    pub peer: usize,
}

struct SinkState {
    file: std::fs::File,
    buf: String,
    /// First write/flush error, kept until `flush` surfaces it (or drop
    /// prints it). Once set, the sink stops recording.
    error: Option<std::io::Error>,
    /// Whether `error` was already reported through `flush`, so drop
    /// doesn't shout twice.
    error_reported: bool,
}

/// A structured trace writer: one per process/tier, shared by reference
/// (`&TraceSink` / `Arc<TraceSink>`) across round workers and reader
/// threads. All event methods take `&self`; a mutex serializes the line
/// buffer.
pub struct TraceSink {
    tier: &'static str,
    epoch: Instant,
    state: Mutex<SinkState>,
}

impl TraceSink {
    /// Create the trace file (truncating), stamp the `trace_meta`
    /// header, and hand back the sink. `tier` tags every event;
    /// `source` identifies the process instance (endpoint, task name)
    /// in the header only.
    pub fn create(path: &Path, tier: &'static str, source: &str) -> Result<TraceSink> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating trace dir for {}", path.display()))?;
            }
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        let epoch_unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as f64)
            .unwrap_or(0.0);
        let sink = TraceSink {
            tier,
            epoch: Instant::now(),
            state: Mutex::new(SinkState {
                file,
                buf: String::with_capacity(FLUSH_BYTES),
                error: None,
                error_reported: false,
            }),
        };
        sink.emit(obj(vec![
            ("type", s("trace_meta")),
            ("v", num(TRACE_VERSION as f64)),
            ("tier", s(tier)),
            ("source", s(source)),
            ("epoch_unix_ms", num(epoch_unix_ms)),
        ]));
        Ok(sink)
    }

    pub fn tier(&self) -> &'static str {
        self.tier
    }

    /// Microseconds since this sink's epoch — the time base of every
    /// event it emits.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one phase span of `round`: `[start_us, end_us]` in this
    /// sink's time base (see [`TraceSink::now_us`]).
    pub fn span(&self, round: u64, phase: Phase, start_us: u64, end_us: u64) {
        self.emit(obj(vec![
            ("type", s("span")),
            ("tier", s(self.tier)),
            ("round", num(round as f64)),
            ("phase", s(phase.as_str())),
            ("start_us", num(start_us as f64)),
            ("dur_us", num(end_us.saturating_sub(start_us) as f64)),
        ]));
    }

    /// Record one step of a slot's timeline, stamped with the current
    /// time. `peer` identifies the delivering connection / relay child
    /// where the caller knows it.
    pub fn slot_event(&self, round: u64, slot: usize, ev: SlotEvent, peer: Option<usize>) {
        let mut fields = vec![
            ("type", s("slot")),
            ("tier", s(self.tier)),
            ("round", num(round as f64)),
            ("slot", num(slot as f64)),
            ("event", s(ev.as_str())),
            ("t_us", num(self.now_us() as f64)),
        ];
        if let Some(p) = peer {
            fields.push(("peer", num(p as f64)));
        }
        self.emit(obj(fields));
    }

    /// A slot's terminal `dropped` event, with the membership reason
    /// ("faulted", "deadline", "disconnect", ...).
    pub fn slot_dropped(&self, round: u64, slot: usize, reason: &str) {
        self.emit(obj(vec![
            ("type", s("slot")),
            ("tier", s(self.tier)),
            ("round", num(round as f64)),
            ("slot", num(slot as f64)),
            ("event", s(SlotEvent::Dropped.as_str())),
            ("t_us", num(self.now_us() as f64)),
            ("reason", s(reason)),
        ]));
    }

    /// Per-connection IO timing for one round: `stall_us` blocked
    /// waiting for a peer's next message to start, `read_us` reading
    /// message bodies, `write_us` writing to the peer.
    pub fn conn(&self, round: u64, peer: usize, stall_us: u64, read_us: u64, write_us: u64) {
        self.emit(obj(vec![
            ("type", s("conn")),
            ("tier", s(self.tier)),
            ("round", num(round as f64)),
            ("peer", num(peer as f64)),
            ("stall_us", num(stall_us as f64)),
            ("read_us", num(read_us as f64)),
            ("write_us", num(write_us as f64)),
        ]));
    }

    /// Emit a latency histogram (per round when `round` is given,
    /// run-level otherwise) with its quoted percentiles and the sparse
    /// bucket counts that make downstream merging exact.
    pub fn histogram(&self, round: Option<u64>, metric: &str, h: &Histogram) {
        if h.is_empty() {
            return;
        }
        let mut fields = vec![("type", s("hist")), ("tier", s(self.tier))];
        if let Some(r) = round {
            fields.push(("round", num(r as f64)));
        }
        fields.extend([
            ("metric", s(metric)),
            ("count", num(h.count() as f64)),
            ("max_us", num(h.max_us() as f64)),
            ("p50_us", num(h.percentile(0.50) as f64)),
            ("p90_us", num(h.percentile(0.90) as f64)),
            ("p99_us", num(h.percentile(0.99) as f64)),
            ("buckets", h.sparse_buckets()),
        ]);
        self.emit(obj(fields));
    }

    fn emit(&self, v: Value) {
        let mut st = self.state.lock().expect("trace sink poisoned");
        if st.error.is_some() {
            return;
        }
        st.buf.push_str(&v.to_json());
        st.buf.push('\n');
        if st.buf.len() >= FLUSH_BYTES {
            Self::flush_locked(&mut st);
        }
    }

    fn flush_locked(st: &mut SinkState) {
        if st.error.is_none() {
            if let Err(e) = st.file.write_all(st.buf.as_bytes()).and_then(|()| st.file.flush()) {
                st.error = Some(e);
            }
        }
        st.buf.clear();
    }

    /// Flush buffered events and surface the first write error, if any.
    /// Call at end of run; drop also flushes (and complains on stderr
    /// about errors nobody collected).
    pub fn flush(&self) -> Result<()> {
        let mut st = self.state.lock().expect("trace sink poisoned");
        Self::flush_locked(&mut st);
        if let Some(e) = &st.error {
            st.error_reported = true;
            return Err(anyhow::anyhow!("trace file write failed: {e}"));
        }
        Ok(())
    }
}

impl Drop for TraceSink {
    fn drop(&mut self) {
        let st = self.state.get_mut().expect("trace sink poisoned");
        Self::flush_locked(st);
        if let (Some(e), false) = (&st.error, st.error_reported) {
            eprintln!("warning: trace file write failed; trace is truncated: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serialize::json::parse;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fsgd_trace_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn sink_writes_typed_jsonl_with_meta_header() {
        let dir = tmpdir("sink");
        let p = dir.join("t.jsonl");
        {
            let sink = TraceSink::create(&p, "engine", "unit-test").unwrap();
            let t0 = sink.now_us();
            sink.span(3, Phase::Compute, t0, sink.now_us());
            sink.slot_event(3, 7, SlotEvent::Offered, Some(2));
            sink.slot_dropped(3, 9, "deadline");
            sink.conn(3, 1, 10, 20, 30);
            let mut h = Histogram::new();
            h.record(500);
            sink.histogram(Some(3), "slot_arrival_us", &h);
            sink.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 6);
        let meta = parse(lines[0]).unwrap();
        assert_eq!(meta.req_str("type").unwrap(), "trace_meta");
        assert_eq!(meta.req_str("tier").unwrap(), "engine");
        assert_eq!(meta.req_str("source").unwrap(), "unit-test");
        let span = parse(lines[1]).unwrap();
        assert_eq!(span.req_str("phase").unwrap(), "compute");
        assert_eq!(span.req_u64("round").unwrap(), 3);
        let slot = parse(lines[2]).unwrap();
        assert_eq!(slot.req_str("event").unwrap(), "offered");
        assert_eq!(slot.req_u64("peer").unwrap(), 2);
        let dropped = parse(lines[3]).unwrap();
        assert_eq!(dropped.req_str("event").unwrap(), "dropped");
        assert_eq!(dropped.req_str("reason").unwrap(), "deadline");
        let conn = parse(lines[4]).unwrap();
        assert_eq!(conn.req_u64("stall_us").unwrap(), 10);
        let hist = parse(lines[5]).unwrap();
        assert_eq!(hist.req_str("metric").unwrap(), "slot_arrival_us");
        assert_eq!(hist.req_u64("count").unwrap(), 1);
        assert!(hist.req_u64("p50_us").unwrap() >= 500);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sink_buffers_until_flush_threshold() {
        let dir = tmpdir("buf");
        let p = dir.join("t.jsonl");
        let sink = TraceSink::create(&p, "root", "buffering").unwrap();
        sink.slot_event(0, 0, SlotEvent::Absorbed, None);
        // Nothing hits the file until flush (the buffer is far below
        // FLUSH_BYTES) — the hot path pays no per-event syscalls.
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "");
        sink.flush().unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap().lines().count(), 2);
        drop(sink);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_histograms_are_not_emitted() {
        let dir = tmpdir("empty");
        let p = dir.join("t.jsonl");
        let sink = TraceSink::create(&p, "relay", "x").unwrap();
        sink.histogram(None, "slot_arrival_us", &Histogram::new());
        sink.flush().unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap().lines().count(), 1, "meta only");
        drop(sink);
        std::fs::remove_dir_all(&dir).ok();
    }
}
