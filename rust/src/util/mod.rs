//! Small self-contained substrates: deterministic PRNGs, statistics
//! helpers, and a miniature property-testing harness.
//!
//! The build environment is offline, so this crate cannot depend on
//! `rand`, `proptest`, or `statrs`; everything here is implemented from
//! scratch and unit-tested in place.

pub mod affinity;
pub mod kernels;
pub mod proptest;
pub mod rng;
pub mod simd;
pub mod stats;

pub use rng::{splitmix64, Rng};
