//! Best-effort thread→core pinning for the absorb/reduce worker pools.
//!
//! With `pin_shards` on, each spawned worker pins itself round-robin to
//! a core so the shard accumulator strips it touches stay in one cache
//! domain instead of bouncing between whichever cores the scheduler
//! picks per round. Pinning is strictly a *placement hint*: it never
//! changes which bits come out (the shard layout and fold order are
//! fixed elsewhere), so a failed or unsupported affinity call is
//! silently ignored — workers just run wherever the scheduler puts
//! them, exactly as before.

/// Pin the calling thread to core `core % available_parallelism`.
///
/// Returns whether the affinity syscall succeeded. `false` is not an
/// error: non-Linux targets always return it, and on Linux a container
/// cpuset that excludes the requested core rejects the call — callers
/// must treat the result as informational only.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(core: usize) -> bool {
    // std already links libc, so declaring the one symbol we need
    // avoids a crate dependency the offline image doesn't carry.
    // pid 0 = the calling thread.
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let ncores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .max(1);
    let cpu = core % ncores;
    // 16 × u64 = 1024 CPUs, the glibc cpu_set_t size.
    let mut mask = [0u64; 16];
    if cpu >= mask.len() * 64 {
        return false;
    }
    mask[cpu / 64] = 1u64 << (cpu % 64);
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

/// Non-Linux fallback: no-op, reports failure.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    // The call must never crash or wedge a thread, whatever the host's
    // cpuset looks like. The return value is intentionally not pinned:
    // restricted containers may legitimately reject affinity changes.
    #[test]
    fn pinning_is_safe_to_call_from_spawned_threads() {
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    let _ = pin_current_thread(t);
                    // thread still does useful work after the call
                    (0..1000u64).sum::<u64>()
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 499500);
        }
        // out-of-range cores wrap via modulo rather than failing
        let _ = pin_current_thread(usize::MAX);
    }
}
