//! Deterministic pseudo-random number generation.
//!
//! `splitmix64` is the seeding/stream-splitting primitive (it is also the
//! constant-derivation function of the cross-language Count-Sketch hash
//! spec — see `crate::hashing`). `Rng` is xoshiro256++, a small fast
//! generator with good statistical quality, used for everything
//! stochastic in the simulator: client sampling, synthetic data,
//! minibatch order.
//!
//! All randomness in the system flows from explicit `u64` seeds so every
//! experiment is exactly reproducible.

/// One step of the splitmix64 sequence: returns the value for `state` and
/// advances it. Used both as a stand-alone hash/seed-derivation function
/// and to seed `Rng`.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive the `i`-th independent sub-seed from a master seed. Stable
/// across the whole codebase (and mirrored in Python) so components can
/// agree on stream identities.
#[inline]
pub fn derive_seed(master: u64, stream: u64) -> u64 {
    let mut s = master ^ stream.wrapping_mul(0xA0761D6478BD642F);
    splitmix64(&mut s)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single `u64` by running splitmix64 (the procedure
    /// recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Create an independent child generator (for per-client / per-worker
    /// streams).
    pub fn split(&mut self, stream: u64) -> Rng {
        Rng::new(derive_seed(self.next_u64(), stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's unbiased method.
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` uniformly (Floyd's
    /// algorithm); order is randomized. Used for per-round client
    /// selection.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        // Floyd's: for j in n-k..n, pick t in [0, j]; insert t unless
        // present, else insert j.
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.gen_range(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        self.shuffle(&mut out);
        out
    }

    /// Sample from a power-law (Zipf-like) distribution over `[0, n)`
    /// with exponent `alpha` via inverse-CDF on precomputed weights.
    /// Returns the index. Prefer `PowerLaw` for repeated draws.
    pub fn next_zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.next_f64();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precomputed power-law sampler: P(i) ∝ (i+1)^-alpha over [0, n).
/// Used to model the paper's observation that client dataset sizes follow
/// a power law (§1, §5).
#[derive(Clone, Debug)]
pub struct PowerLaw {
    cdf: Vec<f64>,
}

impl PowerLaw {
    pub fn new(n: usize, alpha: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += ((i + 1) as f64).powf(-alpha);
            cdf.push(acc);
        }
        let norm = acc;
        for p in cdf.iter_mut() {
            *p /= norm;
        }
        PowerLaw { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        rng.next_zipf(&self.cdf)
    }

    /// Deterministic per-index weight (normalized).
    pub fn weight(&self, i: usize) -> f64 {
        if i == 0 {
            self.cdf[0]
        } else {
            self.cdf[i] - self.cdf[i - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed=1234567 from the public-domain
        // splitmix64 implementation.
        let mut s = 1234567u64;
        let a = splitmix64(&mut s);
        let b = splitmix64(&mut s);
        assert_ne!(a, b);
        // determinism
        let mut s2 = 1234567u64;
        assert_eq!(a, splitmix64(&mut s2));
        assert_eq!(b, splitmix64(&mut s2));
    }

    #[test]
    fn rng_deterministic_and_distinct_streams() {
        let mut r1 = Rng::new(42);
        let mut r2 = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
        let mut r3 = Rng::new(43);
        let same = (0..100).filter(|_| r1.next_u64() == r3.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&b| b), "all buckets hit in 1000 draws");
    }

    #[test]
    fn sample_distinct_is_distinct_and_complete() {
        let mut r = Rng::new(9);
        for _ in 0..50 {
            let s = r.sample_distinct(20, 7);
            assert_eq!(s.len(), 7);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 7);
            assert!(s.iter().all(|&i| i < 20));
        }
        // k == n returns a permutation
        let s = r.sample_distinct(5, 5);
        let mut sorted = s.clone();
        sorted.sort();
        assert_eq!(sorted, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 20000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn power_law_is_heavy_headed() {
        let pl = PowerLaw::new(1000, 1.2);
        let mut r = Rng::new(3);
        let mut head = 0;
        for _ in 0..2000 {
            if pl.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // top-1% of indices should hold far more than 1% of the mass
        assert!(head > 400, "head draws {head}");
        let total: f64 = (0..1000).map(|i| pl.weight(i)).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
