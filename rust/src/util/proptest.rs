//! Miniature property-based testing harness.
//!
//! The real `proptest` crate is unavailable offline, so this module
//! provides the 20% we need: run a property over many seeded random
//! cases, report the failing seed, and re-run a specific seed for
//! debugging. No shrinking — the generators below produce small cases by
//! construction, and the failing seed is always printed so a case can be
//! replayed exactly.
//!
//! ```no_run
//! use fetchsgd::util::proptest::{check, Gen};
//! check("add commutes", 100, |g| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Case generator handed to each property invocation.
pub struct Gen {
    rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.rng.gen_range(hi - lo)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A vector of f32 in [lo, hi) with length in [min_len, max_len).
    pub fn vec_f32(&mut self, min_len: usize, max_len: usize, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// A sparse vector of dimension `d` with `nnz` heavy entries of
    /// magnitude around `scale` plus optional dense Gaussian noise of
    /// standard deviation `noise`.
    pub fn heavy_vec(&mut self, d: usize, nnz: usize, scale: f32, noise: f32) -> Vec<f32> {
        let mut v = vec![0f32; d];
        if noise > 0.0 {
            for x in v.iter_mut() {
                *x = (self.rng.next_gaussian() as f32) * noise;
            }
        }
        for _ in 0..nnz {
            let i = self.rng.gen_range(d);
            let sign = if self.bool() { 1.0 } else { -1.0 };
            v[i] += sign * scale * (0.5 + self.rng.next_f32());
        }
        v
    }

    /// Access the underlying RNG for custom generation.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` generated cases. Panics (with the case index
/// and seed) on the first failure. Honors `FETCHSGD_PROP_SEED` to replay
/// one specific case.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    if let Ok(s) = std::env::var("FETCHSGD_PROP_SEED") {
        let seed: u64 = s.parse().expect("FETCHSGD_PROP_SEED must be u64");
        let mut g = Gen { rng: Rng::new(seed), case: 0 };
        prop(&mut g);
        return;
    }
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::new(seed), case };
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case} (replay with FETCHSGD_PROP_SEED={seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        check("counter", 25, |_| n += 1);
        assert_eq!(n, 25);
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 50, |g| {
            let u = g.usize_in(3, 9);
            assert!((3..9).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_f32(1, 10, 0.0, 5.0);
            assert!(!v.is_empty() && v.len() < 10);
            assert!(v.iter().all(|&x| (0.0..5.0).contains(&x)));
        });
    }

    #[test]
    #[should_panic]
    fn failure_propagates() {
        check("always fails", 3, |_| panic!("boom"));
    }
}
