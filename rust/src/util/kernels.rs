//! Cache-blocked f32 slice kernels for the absorb/reduce hot path.
//!
//! The fixed-width block loops below give the compiler a shape it can
//! autovectorize (a constant-trip-count inner loop over an array
//! reference, no bounds checks) while performing exactly the same
//! per-cell operation in exactly the same order as the scalar `zip`
//! loops they replace — so the bitwise-determinism contract of
//! `compression::aggregate` is untouched: within a slice the fold order
//! is identical, element by element.
//!
//! `add` is kept separate from `axpy` rather than calling
//! `axpy(dst, src, 1.0)`: the accumulate paths that historically did a
//! bare `+=` must keep doing a bare `+=`, not a `+ 1.0 *` — we do not
//! lean on `1.0 * x` being a bitwise identity for every f32.

/// Block width of the inner loops. 8 f32 lanes = one 256-bit vector,
/// and small enough that the scalar remainder is negligible.
pub const LANES: usize = 8;

/// `dst[i] += scale * src[i]` for every `i` (in index order).
pub fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
    debug_assert_eq!(dst.len(), src.len());
    let mut d = dst.chunks_exact_mut(LANES);
    let mut s = src.chunks_exact(LANES);
    for (db, sb) in d.by_ref().zip(s.by_ref()) {
        let db: &mut [f32; LANES] = db.try_into().unwrap();
        let sb: &[f32; LANES] = sb.try_into().unwrap();
        for i in 0..LANES {
            db[i] += scale * sb[i];
        }
    }
    for (a, &b) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *a += scale * b;
    }
}

/// `dst[i] += src[i]` for every `i` (in index order).
pub fn add(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let mut d = dst.chunks_exact_mut(LANES);
    let mut s = src.chunks_exact(LANES);
    for (db, sb) in d.by_ref().zip(s.by_ref()) {
        let db: &mut [f32; LANES] = db.try_into().unwrap();
        let sb: &[f32; LANES] = sb.try_into().unwrap();
        for i in 0..LANES {
            db[i] += sb[i];
        }
    }
    for (a, &b) in d.into_remainder().iter_mut().zip(s.remainder()) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn axpy_matches_scalar_reference_including_remainders() {
        for n in [0usize, 1, 7, 8, 9, 16, 63, 100] {
            let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin() * 100.0).collect();
            let mut blocked: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
            let mut scalar = blocked.clone();
            axpy(&mut blocked, &src, -0.625);
            for (a, &b) in scalar.iter_mut().zip(&src) {
                *a += -0.625 * b;
            }
            assert_eq!(bits(&blocked), bits(&scalar), "n={n}");
        }
    }

    #[test]
    fn add_matches_scalar_reference_including_remainders() {
        for n in [0usize, 1, 7, 8, 9, 16, 63, 100] {
            let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.73).sin() * 10.0).collect();
            let mut blocked: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
            let mut scalar = blocked.clone();
            add(&mut blocked, &src);
            for (a, &b) in scalar.iter_mut().zip(&src) {
                *a += b;
            }
            assert_eq!(bits(&blocked), bits(&scalar), "n={n}");
        }
    }
}
