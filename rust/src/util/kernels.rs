//! f32 slice kernels for the absorb/reduce hot path.
//!
//! These are the historical entry points the accumulate paths call;
//! since the explicit-SIMD layer landed they are thin forwards into
//! [`crate::util::simd`], which dispatches to hand-written SSE2 kernels
//! under `--features simd` and to the scalar reference otherwise. The
//! bitwise-determinism contract of `compression::aggregate` is
//! untouched either way: every configuration performs the same per-cell
//! operation in the same order (see the contract notes in
//! `util::simd`).
//!
//! `add` is kept separate from `axpy` rather than calling
//! `axpy(dst, src, 1.0)`: the accumulate paths that historically did a
//! bare `+=` must keep doing a bare `+=`, not a `+ 1.0 *` — we do not
//! lean on `1.0 * x` being a bitwise identity for every f32.

use crate::util::simd;

/// Block width of the scalar-reference inner loops. 8 f32 lanes = one
/// 256-bit vector, and small enough that the remainder is negligible.
pub const LANES: usize = simd::scalar::LANES;

/// `dst[i] += scale * src[i]` for every `i` (in index order).
pub fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
    simd::axpy(dst, src, scale)
}

/// `dst[i] += src[i]` for every `i` (in index order).
pub fn add(dst: &mut [f32], src: &[f32]) {
    simd::add(dst, src)
}

/// `dst[i] *= s` for every `i` (cells independent, order-free).
pub fn scale(dst: &mut [f32], s: f32) {
    simd::scale(dst, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn axpy_matches_scalar_reference_including_remainders() {
        for n in [0usize, 1, 7, 8, 9, 16, 63, 100] {
            let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin() * 100.0).collect();
            let mut blocked: Vec<f32> = (0..n).map(|i| (i as f32 * 0.11).cos()).collect();
            let mut scalar = blocked.clone();
            axpy(&mut blocked, &src, -0.625);
            for (a, &b) in scalar.iter_mut().zip(&src) {
                *a += -0.625 * b;
            }
            assert_eq!(bits(&blocked), bits(&scalar), "n={n}");
        }
    }

    #[test]
    fn add_matches_scalar_reference_including_remainders() {
        for n in [0usize, 1, 7, 8, 9, 16, 63, 100] {
            let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.73).sin() * 10.0).collect();
            let mut blocked: Vec<f32> = (0..n).map(|i| i as f32 * 0.5 - 3.0).collect();
            let mut scalar = blocked.clone();
            add(&mut blocked, &src);
            for (a, &b) in scalar.iter_mut().zip(&src) {
                *a += b;
            }
            assert_eq!(bits(&blocked), bits(&scalar), "n={n}");
        }
    }

    #[test]
    fn scale_matches_scalar_reference_including_remainders() {
        for n in [0usize, 1, 7, 8, 9, 16, 63, 100] {
            let mut kern: Vec<f32> = (0..n).map(|i| (i as f32 * 0.19).sin() * 5.0).collect();
            let mut scalar = kern.clone();
            scale(&mut kern, 0.875);
            for a in scalar.iter_mut() {
                *a *= 0.875;
            }
            assert_eq!(bits(&kern), bits(&scalar), "n={n}");
        }
    }
}
