//! Tiny statistics helpers shared by metrics, benches, and tests.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// p-th percentile (0 <= p <= 100) with linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median. Convenience wrapper over `percentile`.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// L2 norm of an f32 slice, accumulated in f64 for robustness.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Dot product in f64 accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Relative L2 error ||a-b|| / max(||b||, eps).
pub fn rel_l2_error(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let diff: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum();
    diff.sqrt() / l2_norm(b).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_stddev_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-9);
        assert_eq!(stddev(&[1.0]), 0.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-9);
        assert!(rel_l2_error(&[1.0, 0.0], &[1.0, 0.0]) < 1e-12);
        assert!((rel_l2_error(&[2.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-9);
    }
}
