//! Explicit SIMD kernels for the encode/absorb/reduce hot paths.
//!
//! The block loops in `util::kernels` lean on the autovectorizer; this
//! module pins the codegen instead. Behind the `simd` cargo feature (on
//! x86_64, where SSE2 is baseline so no runtime detection is needed)
//! every entry point dispatches to a hand-written intrinsic kernel; in
//! every other configuration it falls through to the scalar reference
//! in [`scalar`], which is always compiled *and always exported* so
//! parity tests and benches can hold both implementations side by side
//! in one binary.
//!
//! ## The bitwise contract
//!
//! Every vector kernel performs the same per-cell IEEE operation, in
//! the same order, as its scalar twin:
//!
//! - multiply then add as two rounded ops (`_mm_mul_ps` + `_mm_add_ps`)
//!   — never a fused multiply-add, which would skip the intermediate
//!   rounding and change bits;
//! - cells are independent (`dst[i]` only ever meets `src[i]`), so
//!   packing four of them into one register cannot reorder any fold —
//!   lane width never changes the order in which a given cell sees its
//!   updates;
//! - the multiply-shift hashes are exact `u32` wrapping arithmetic in
//!   both forms (`_mm_add_epi32`/`mullo` wrap just like
//!   `wrapping_mul`/`wrapping_add`), and the scatter into sketch rows
//!   stays scalar and in index order, zero-skip included, because
//!   scattered cells *do* collide (two indices can hash to one bucket)
//!   and their order is part of the determinism contract;
//! - f16→f32 widening uses a branchless bit-manipulation sequence
//!   (exponent rebias + exact float subtract for subnormals) proven
//!   bit-identical to [`crate::wire::codec::f16_bits_to_f32`] over all
//!   65536 patterns by the exhaustive test at the bottom of this file.
//!
//! `rust/tests/prop_sketch.rs` holds property tests pinning dispatch ==
//! scalar bitwise across odd lengths, remainder tails, and unaligned
//! offsets; run them with and without `--features simd` (CI does both).

use crate::hashing::RowHash;

/// Scalar reference kernels — the semantics every SIMD kernel must
/// reproduce bit for bit. Always compiled, always public: parity tests
/// compare dispatch output against these, and benches time both in the
/// same binary.
pub mod scalar {
    use crate::hashing::RowHash;

    /// Block width for the autovectorizer-friendly loops (see
    /// `util::kernels` for why blocking helps even without intrinsics).
    pub const LANES: usize = 8;

    /// `dst[i] += scale * src[i]` (two rounded ops per cell, no FMA).
    pub fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
        debug_assert_eq!(dst.len(), src.len());
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (db, sb) in (&mut d).zip(&mut s) {
            for i in 0..LANES {
                db[i] += scale * sb[i];
            }
        }
        for (a, b) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *a += scale * *b;
        }
    }

    /// `dst[i] += src[i]` — a bare `+=`, deliberately not
    /// `axpy(dst, src, 1.0)`: we do not lean on `1.0 * x` being a
    /// bitwise identity for every f32.
    pub fn add(dst: &mut [f32], src: &[f32]) {
        debug_assert_eq!(dst.len(), src.len());
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (db, sb) in (&mut d).zip(&mut s) {
            for i in 0..LANES {
                db[i] += sb[i];
            }
        }
        for (a, b) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *a += *b;
        }
    }

    /// `dst[i] *= s` — per-cell, order-free (cells are independent).
    pub fn scale(dst: &mut [f32], s: f32) {
        for a in dst.iter_mut() {
            *a *= s;
        }
    }

    /// Weighted absorb of a little-endian f32 payload:
    /// `dst[i] += weight * f32_le(bytes[4i..4i+4])`.
    pub fn axpy_f32_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
        debug_assert_eq!(bytes.len(), 4 * dst.len());
        let mut b = bytes.chunks_exact(4 * LANES);
        let mut d = dst.chunks_exact_mut(LANES);
        for (bb, db) in (&mut b).zip(&mut d) {
            for i in 0..LANES {
                let raw = [bb[4 * i], bb[4 * i + 1], bb[4 * i + 2], bb[4 * i + 3]];
                db[i] += weight * f32::from_le_bytes(raw);
            }
        }
        for (bb, a) in b.remainder().chunks_exact(4).zip(d.into_remainder()) {
            *a += weight * f32::from_le_bytes([bb[0], bb[1], bb[2], bb[3]]);
        }
    }

    /// Weighted absorb of a little-endian f16 payload:
    /// `dst[i] += weight * widen(f16_le(bytes[2i..2i+2]))`, where
    /// `widen` is the exact codec decode
    /// ([`crate::wire::codec::f16_bits_to_f32`]).
    pub fn axpy_f16_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
        debug_assert_eq!(bytes.len(), 2 * dst.len());
        for (a, hb) in dst.iter_mut().zip(bytes.chunks_exact(2)) {
            let h = u16::from_le_bytes([hb[0], hb[1]]);
            *a += weight * crate::wire::codec::f16_bits_to_f32(h);
        }
    }

    /// One sketch row's dense encode: for each coordinate `i` with
    /// `g[i] != 0.0`, multiply-shift hash `(bucket, sign)` from the
    /// hoisted per-row coefficients and scatter
    /// `row[bucket] += (±g[i]) * scale`. Exactly the inner loop of
    /// `CountSketch::accumulate_dense`; the zero-skip (which also
    /// catches `-0.0`) and the in-index-order scatter are part of the
    /// contract.
    pub fn accumulate_row(row: &mut [f32], h: RowHash, shift: u32, g: &[f32], scale: f32) {
        for (i, &gi) in g.iter().enumerate() {
            if gi == 0.0 {
                continue;
            }
            let iu = i as u32;
            let b = (h.a_bucket.wrapping_mul(iu).wrapping_add(h.b_bucket) >> shift) as usize;
            let sgn_neg = h.a_sign.wrapping_mul(iu).wrapping_add(h.b_sign) >> 31;
            let signed = if sgn_neg == 0 { gi } else { -gi };
            row[b] += signed * scale;
        }
    }

    /// One sketch row's sparse encode: same hash+scatter as
    /// [`accumulate_row`], but walking `(idx, val)` pairs. The
    /// zero-skip matches the dense path's convention (an explicit
    /// `±0.0` entry contributes nothing there either, since
    /// `(±0.0) * scale` adds as zero), so hoisting it is
    /// bitwise-neutral for every non-NaN payload.
    pub fn accumulate_row_sparse(
        row: &mut [f32],
        h: RowHash,
        shift: u32,
        idx: &[u32],
        val: &[f32],
        scale: f32,
    ) {
        debug_assert_eq!(idx.len(), val.len());
        for (&iu, &v) in idx.iter().zip(val) {
            if v == 0.0 {
                continue;
            }
            let b = (h.a_bucket.wrapping_mul(iu).wrapping_add(h.b_bucket) >> shift) as usize;
            let sgn_neg = h.a_sign.wrapping_mul(iu).wrapping_add(h.b_sign) >> 31;
            let signed = if sgn_neg == 0 { v } else { -v };
            row[b] += signed * scale;
        }
    }
}

/// SSE2 kernels. SSE2 is part of the x86_64 baseline, so inside this
/// `cfg` every intrinsic is unconditionally available — no runtime
/// feature detection, no `target_feature` attributes, and therefore no
/// unsafe-to-call functions: the `unsafe` blocks below are only for the
/// raw-pointer loads/stores, whose bounds the surrounding slice math
/// guarantees.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod sse2 {
    use crate::hashing::RowHash;
    use core::arch::x86_64::*;

    pub fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len().min(src.len());
        let blocks = n / 4;
        unsafe {
            let s = _mm_set1_ps(scale);
            let dp = dst.as_mut_ptr();
            let sp = src.as_ptr();
            for b in 0..blocks {
                let d = _mm_loadu_ps(dp.add(4 * b));
                let x = _mm_loadu_ps(sp.add(4 * b));
                // mul then add, matching `d += scale * x` — not FMA.
                _mm_storeu_ps(dp.add(4 * b), _mm_add_ps(d, _mm_mul_ps(s, x)));
            }
        }
        for i in 4 * blocks..n {
            dst[i] += scale * src[i];
        }
    }

    pub fn add(dst: &mut [f32], src: &[f32]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len().min(src.len());
        let blocks = n / 4;
        unsafe {
            let dp = dst.as_mut_ptr();
            let sp = src.as_ptr();
            for b in 0..blocks {
                let d = _mm_loadu_ps(dp.add(4 * b));
                let x = _mm_loadu_ps(sp.add(4 * b));
                _mm_storeu_ps(dp.add(4 * b), _mm_add_ps(d, x));
            }
        }
        for i in 4 * blocks..n {
            dst[i] += src[i];
        }
    }

    pub fn scale(dst: &mut [f32], s: f32) {
        let n = dst.len();
        let blocks = n / 4;
        unsafe {
            let sv = _mm_set1_ps(s);
            let dp = dst.as_mut_ptr();
            for b in 0..blocks {
                let d = _mm_loadu_ps(dp.add(4 * b));
                // operand order matches scalar `*a *= s` (a * s).
                _mm_storeu_ps(dp.add(4 * b), _mm_mul_ps(d, sv));
            }
        }
        for a in dst[4 * blocks..].iter_mut() {
            *a *= s;
        }
    }

    pub fn axpy_f32_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
        debug_assert_eq!(bytes.len(), 4 * dst.len());
        let n = dst.len().min(bytes.len() / 4);
        let blocks = n / 4;
        unsafe {
            let w = _mm_set1_ps(weight);
            let bp = bytes.as_ptr();
            let dp = dst.as_mut_ptr();
            for b in 0..blocks {
                // x86_64 is little-endian, so reinterpreting 16 LE
                // payload bytes as 4 f32 lanes is exactly
                // `f32::from_le_bytes` per lane.
                let x = _mm_castsi128_ps(_mm_loadu_si128(bp.add(16 * b) as *const __m128i));
                let d = _mm_loadu_ps(dp.add(4 * b));
                _mm_storeu_ps(dp.add(4 * b), _mm_add_ps(d, _mm_mul_ps(w, x)));
            }
        }
        for i in 4 * blocks..n {
            let o = 4 * i;
            let raw = [bytes[o], bytes[o + 1], bytes[o + 2], bytes[o + 3]];
            dst[i] += weight * f32::from_le_bytes(raw);
        }
    }

    /// Widen 4 packed f16 bit patterns (in the low 64 bits of `h`) to 4
    /// f32 lanes, bit-identical to
    /// [`crate::wire::codec::f16_bits_to_f32`] on every pattern.
    ///
    /// Branchless rebias: shift the sign-stripped half 13 left so its
    /// exponent/mantissa land in f32 position, add the exponent bias
    /// delta `(127-15) << 23`, then per-lane select the two irregular
    /// classes — inf/NaN get a second bias bump to exponent 255, and
    /// subnormals are renormalized by an *exact* float subtract
    /// (`(m + 2^-14) - 2^-14` in f32; both operands and the result are
    /// normal f32s, so no rounding and no dependence on DAZ/FTZ).
    #[inline]
    fn widen4_f16(h: __m128i) -> __m128 {
        unsafe {
            let e = _mm_unpacklo_epi16(h, _mm_setzero_si128());
            let sign = _mm_slli_epi32(_mm_and_si128(e, _mm_set1_epi32(0x8000)), 16);
            let em = _mm_and_si128(e, _mm_set1_epi32(0x7fff));
            let mut o = _mm_slli_epi32(em, 13);
            let shifted_exp = _mm_set1_epi32(0x7c00 << 13);
            let exp = _mm_and_si128(o, shifted_exp);
            o = _mm_add_epi32(o, _mm_set1_epi32((127 - 15) << 23));
            // inf/NaN: exponent field was 0x1f; bump it on to 0xff.
            let infnan = _mm_cmpeq_epi32(exp, shifted_exp);
            o = _mm_add_epi32(o, _mm_and_si128(infnan, _mm_set1_epi32((128 - 16) << 23)));
            // subnormal (exponent field 0, incl. ±0): renormalize.
            let sub = _mm_cmpeq_epi32(exp, _mm_setzero_si128());
            let renorm = _mm_castps_si128(_mm_sub_ps(
                _mm_castsi128_ps(_mm_add_epi32(o, _mm_set1_epi32(1 << 23))),
                _mm_castsi128_ps(_mm_set1_epi32(113 << 23)),
            ));
            o = _mm_or_si128(_mm_and_si128(sub, renorm), _mm_andnot_si128(sub, o));
            _mm_castsi128_ps(_mm_or_si128(o, sign))
        }
    }

    pub fn axpy_f16_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
        debug_assert_eq!(bytes.len(), 2 * dst.len());
        let n = dst.len().min(bytes.len() / 2);
        let blocks = n / 4;
        unsafe {
            let w = _mm_set1_ps(weight);
            let bp = bytes.as_ptr();
            let dp = dst.as_mut_ptr();
            for b in 0..blocks {
                // 4 halves = 8 bytes; movq tolerates any alignment.
                let h = _mm_loadl_epi64(bp.add(8 * b) as *const __m128i);
                let x = widen4_f16(h);
                let d = _mm_loadu_ps(dp.add(4 * b));
                _mm_storeu_ps(dp.add(4 * b), _mm_add_ps(d, _mm_mul_ps(w, x)));
            }
        }
        for i in 4 * blocks..n {
            let h = u16::from_le_bytes([bytes[2 * i], bytes[2 * i + 1]]);
            dst[i] += weight * crate::wire::codec::f16_bits_to_f32(h);
        }
    }

    /// 32-bit lane-wise wrapping multiply. SSE2 has no `pmulld`; build
    /// it from two 32×32→64 even-lane multiplies (low halves of the
    /// products are exactly the wrapping 32-bit products).
    #[inline]
    fn mullo_epi32(a: __m128i, b: __m128i) -> __m128i {
        unsafe {
            let even = _mm_mul_epu32(a, b);
            let odd = _mm_mul_epu32(_mm_srli_si128(a, 4), _mm_srli_si128(b, 4));
            _mm_unpacklo_epi32(
                _mm_shuffle_epi32(even, 0b00_00_10_00),
                _mm_shuffle_epi32(odd, 0b00_00_10_00),
            )
        }
    }

    /// Hash 4 consecutive indices' (bucket, sign-bit) pairs in
    /// registers, then scatter scalar-with-zero-skip in index order.
    pub fn accumulate_row(row: &mut [f32], h: RowHash, shift: u32, g: &[f32], scale: f32) {
        let n = g.len();
        let blocks = n / 4;
        unsafe {
            let sh = _mm_cvtsi32_si128(shift as i32);
            let ab = _mm_set1_epi32(h.a_bucket as i32);
            let bb = _mm_set1_epi32(h.b_bucket as i32);
            let asg = _mm_set1_epi32(h.a_sign as i32);
            let bsg = _mm_set1_epi32(h.b_sign as i32);
            let step = _mm_setr_epi32(0, 1, 2, 3);
            let mut buckets = [0u32; 4];
            let mut neg = [0u32; 4];
            for blk in 0..blocks {
                let i0 = (4 * blk) as u32;
                let idx = _mm_add_epi32(_mm_set1_epi32(i0 as i32), step);
                let b = _mm_srl_epi32(_mm_add_epi32(mullo_epi32(ab, idx), bb), sh);
                let s = _mm_srli_epi32(_mm_add_epi32(mullo_epi32(asg, idx), bsg), 31);
                _mm_storeu_si128(buckets.as_mut_ptr() as *mut __m128i, b);
                _mm_storeu_si128(neg.as_mut_ptr() as *mut __m128i, s);
                // The scatter stays scalar and in index order: two
                // indices can land in one bucket, and their add order
                // is part of the bitwise contract.
                for (k, (&bk, &nk)) in buckets.iter().zip(&neg).enumerate() {
                    let gi = g[4 * blk + k];
                    if gi == 0.0 {
                        continue;
                    }
                    let signed = if nk == 0 { gi } else { -gi };
                    row[bk as usize] += signed * scale;
                }
            }
        }
        for (i, &gi) in g.iter().enumerate().skip(4 * blocks) {
            if gi == 0.0 {
                continue;
            }
            let iu = i as u32;
            let b = (h.a_bucket.wrapping_mul(iu).wrapping_add(h.b_bucket) >> shift) as usize;
            let sgn_neg = h.a_sign.wrapping_mul(iu).wrapping_add(h.b_sign) >> 31;
            let signed = if sgn_neg == 0 { gi } else { -gi };
            row[b] += signed * scale;
        }
    }

    pub fn accumulate_row_sparse(
        row: &mut [f32],
        h: RowHash,
        shift: u32,
        idx: &[u32],
        val: &[f32],
        scale: f32,
    ) {
        debug_assert_eq!(idx.len(), val.len());
        let n = idx.len().min(val.len());
        let blocks = n / 4;
        unsafe {
            let sh = _mm_cvtsi32_si128(shift as i32);
            let ab = _mm_set1_epi32(h.a_bucket as i32);
            let bb = _mm_set1_epi32(h.b_bucket as i32);
            let asg = _mm_set1_epi32(h.a_sign as i32);
            let bsg = _mm_set1_epi32(h.b_sign as i32);
            let ip = idx.as_ptr();
            let mut buckets = [0u32; 4];
            let mut neg = [0u32; 4];
            for blk in 0..blocks {
                let iv = _mm_loadu_si128(ip.add(4 * blk) as *const __m128i);
                let b = _mm_srl_epi32(_mm_add_epi32(mullo_epi32(ab, iv), bb), sh);
                let s = _mm_srli_epi32(_mm_add_epi32(mullo_epi32(asg, iv), bsg), 31);
                _mm_storeu_si128(buckets.as_mut_ptr() as *mut __m128i, b);
                _mm_storeu_si128(neg.as_mut_ptr() as *mut __m128i, s);
                for (k, (&bk, &nk)) in buckets.iter().zip(&neg).enumerate() {
                    let v = val[4 * blk + k];
                    if v == 0.0 {
                        continue;
                    }
                    let signed = if nk == 0 { v } else { -v };
                    row[bk as usize] += signed * scale;
                }
            }
        }
        for j in 4 * blocks..n {
            let (iu, v) = (idx[j], val[j]);
            if v == 0.0 {
                continue;
            }
            let b = (h.a_bucket.wrapping_mul(iu).wrapping_add(h.b_bucket) >> shift) as usize;
            let sgn_neg = h.a_sign.wrapping_mul(iu).wrapping_add(h.b_sign) >> 31;
            let signed = if sgn_neg == 0 { v } else { -v };
            row[b] += signed * scale;
        }
    }
}

// Dispatch layer: one public entry point per kernel. With the `simd`
// feature on an x86_64 target each forwards to the SSE2 kernel;
// everywhere else, to the scalar reference. The twin-definition shape
// (instead of cfg'd blocks inside one body) keeps every configuration a
// plain tail call with no dead code for clippy to complain about.

/// `dst[i] += scale * src[i]`. See [`scalar::axpy`] for the contract.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
    sse2::axpy(dst, src, scale)
}
/// `dst[i] += scale * src[i]`. See [`scalar::axpy`] for the contract.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn axpy(dst: &mut [f32], src: &[f32], scale: f32) {
    scalar::axpy(dst, src, scale)
}

/// `dst[i] += src[i]`. See [`scalar::add`] for the contract.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn add(dst: &mut [f32], src: &[f32]) {
    sse2::add(dst, src)
}
/// `dst[i] += src[i]`. See [`scalar::add`] for the contract.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn add(dst: &mut [f32], src: &[f32]) {
    scalar::add(dst, src)
}

/// `dst[i] *= s`. See [`scalar::scale`] for the contract.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn scale(dst: &mut [f32], s: f32) {
    sse2::scale(dst, s)
}
/// `dst[i] *= s`. See [`scalar::scale`] for the contract.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn scale(dst: &mut [f32], s: f32) {
    scalar::scale(dst, s)
}

/// Weighted LE-f32 absorb. See [`scalar::axpy_f32_le`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn axpy_f32_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
    sse2::axpy_f32_le(bytes, weight, dst)
}
/// Weighted LE-f32 absorb. See [`scalar::axpy_f32_le`].
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn axpy_f32_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
    scalar::axpy_f32_le(bytes, weight, dst)
}

/// Weighted LE-f16 absorb with in-register widening. See
/// [`scalar::axpy_f16_le`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn axpy_f16_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
    sse2::axpy_f16_le(bytes, weight, dst)
}
/// Weighted LE-f16 absorb with in-register widening. See
/// [`scalar::axpy_f16_le`].
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn axpy_f16_le(bytes: &[u8], weight: f32, dst: &mut [f32]) {
    scalar::axpy_f16_le(bytes, weight, dst)
}

/// Dense sketch-row encode (vectorized hashing, scalar in-order
/// scatter). See [`scalar::accumulate_row`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn accumulate_row(row: &mut [f32], h: RowHash, shift: u32, g: &[f32], scale: f32) {
    sse2::accumulate_row(row, h, shift, g, scale)
}
/// Dense sketch-row encode (vectorized hashing, scalar in-order
/// scatter). See [`scalar::accumulate_row`].
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn accumulate_row(row: &mut [f32], h: RowHash, shift: u32, g: &[f32], scale: f32) {
    scalar::accumulate_row(row, h, shift, g, scale)
}

/// Sparse sketch-row encode. See [`scalar::accumulate_row_sparse`].
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn accumulate_row_sparse(
    row: &mut [f32],
    h: RowHash,
    shift: u32,
    idx: &[u32],
    val: &[f32],
    scale: f32,
) {
    sse2::accumulate_row_sparse(row, h, shift, idx, val, scale)
}
/// Sparse sketch-row encode. See [`scalar::accumulate_row_sparse`].
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn accumulate_row_sparse(
    row: &mut [f32],
    h: RowHash,
    shift: u32,
    idx: &[u32],
    val: &[f32],
    scale: f32,
) {
    scalar::accumulate_row_sparse(row, h, shift, idx, val, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    // Dispatch == scalar reference, bitwise, over lengths that hit
    // every tail shape. With `--features simd` this pins the SSE2
    // kernels; without it, it pins the (then-trivial) dispatch wiring.
    #[test]
    fn dispatch_matches_scalar_reference_bitwise() {
        let mut rng = Rng::new(0x51AD_0001);
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 63, 100] {
            let src: Vec<f32> = (0..n).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.next_f32() * 8.0 - 4.0).collect();
            let w = 0.12345_f32;

            let (mut a, mut b) = (base.clone(), base.clone());
            axpy(&mut a, &src, w);
            scalar::axpy(&mut b, &src, w);
            assert_bits(&a, &b, "axpy", n);

            let (mut a, mut b) = (base.clone(), base.clone());
            add(&mut a, &src);
            scalar::add(&mut b, &src);
            assert_bits(&a, &b, "add", n);

            let (mut a, mut b) = (base.clone(), base.clone());
            scale(&mut a, w);
            scalar::scale(&mut b, w);
            assert_bits(&a, &b, "scale", n);

            let bytes: Vec<u8> = src.iter().flat_map(|v| v.to_le_bytes()).collect();
            let (mut a, mut b) = (base.clone(), base.clone());
            axpy_f32_le(&bytes, w, &mut a);
            scalar::axpy_f32_le(&bytes, w, &mut b);
            assert_bits(&a, &b, "axpy_f32_le", n);
        }
    }

    // The f16 widening sequence must match the codec decode on *every*
    // half bit pattern: normals, subnormals, ±0, ±inf, and NaNs.
    // Exhaustive, not sampled — 65536 patterns is cheap.
    #[test]
    fn f16_widening_matches_codec_decode_over_all_bit_patterns() {
        let mut bytes = Vec::with_capacity(2 * 65536);
        for h in 0..=u16::MAX {
            bytes.extend_from_slice(&h.to_le_bytes());
        }
        let mut out = vec![0f32; 65536];
        // weight 1.0 onto a zero accumulator: `0.0 + 1.0 * x` performs
        // identical IEEE ops in both paths, so any bit difference here
        // is a widening bug, not an arithmetic artifact.
        axpy_f16_le(&bytes, 1.0, &mut out);
        let mut reference = vec![0f32; 65536];
        scalar::axpy_f16_le(&bytes, 1.0, &mut reference);
        for h in 0..=u16::MAX as usize {
            assert_eq!(
                out[h].to_bits(),
                reference[h].to_bits(),
                "f16 widen diverged on bit pattern {h:#06x}"
            );
        }
    }

    #[test]
    fn encode_hashing_matches_scalar_including_zero_skip() {
        use crate::hashing::SketchHasher;
        let hasher = SketchHasher::new(3, 256, 0xFEED).unwrap();
        let shift = 32 - 256u32.trailing_zeros();
        let mut rng = Rng::new(0x51AD_0002);
        for n in [1usize, 3, 4, 5, 8, 13, 100, 257] {
            let mut g: Vec<f32> = (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
            // plant zeros (and a negative zero) so the skip path runs
            g[0] = 0.0;
            if n > 4 {
                g[4] = -0.0;
            }
            for r in 0..3 {
                let h = hasher.row(r);
                let mut a = vec![0f32; 256];
                let mut b = vec![0f32; 256];
                accumulate_row(&mut a, h, shift, &g, 0.5);
                scalar::accumulate_row(&mut b, h, shift, &g, 0.5);
                assert_bits(&a, &b, "accumulate_row", n);

                let idx: Vec<u32> = (0..n as u32).map(|i| i * 3).collect();
                let mut a = vec![0f32; 256];
                let mut b = vec![0f32; 256];
                accumulate_row_sparse(&mut a, h, shift, &idx, &g, 0.5);
                scalar::accumulate_row_sparse(&mut b, h, shift, &idx, &g, 0.5);
                assert_bits(&a, &b, "accumulate_row_sparse", n);
            }
        }
    }

    fn assert_bits(a: &[f32], b: &[f32], what: &str, n: usize) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what} diverged at cell {i} (n={n}): {x} vs {y}"
            );
        }
    }
}
