//! Batch assembly helpers: pad client samples to the artifact's fixed
//! batch shape, produce masks, and stack batches for FedAvg's local
//! steps.

use crate::runtime::exec::Batch;
use crate::runtime::Tensor;

/// Assemble an image batch: `samples` are (pixels, label) pairs, padded
/// with zeros up to `batch` (mask marks real examples).
pub fn image_batch(
    samples: &[(Vec<f32>, usize)],
    batch: usize,
    image: [usize; 3],
) -> Batch {
    let pix = image[0] * image[1] * image[2];
    assert!(samples.len() <= batch, "{} samples > batch {batch}", samples.len());
    let mut x = vec![0f32; batch * pix];
    let mut y = vec![0i32; batch];
    let mut mask = vec![0f32; batch];
    for (j, (img, label)) in samples.iter().enumerate() {
        assert_eq!(img.len(), pix);
        x[j * pix..(j + 1) * pix].copy_from_slice(img);
        y[j] = *label as i32;
        mask[j] = 1.0;
    }
    Batch {
        x: Tensor::f32(x, &[batch, image[0], image[1], image[2]]),
        y: Tensor::i32(y, &[batch]),
        mask: Tensor::f32(mask, &[batch]),
    }
}

/// Assemble a text batch: `samples` are (input, target) token pairs.
pub fn text_batch(samples: &[(Vec<i32>, Vec<i32>)], batch: usize, seq: usize) -> Batch {
    assert!(samples.len() <= batch);
    let mut x = vec![0i32; batch * seq];
    let mut y = vec![0i32; batch * seq];
    let mut mask = vec![0f32; batch * seq];
    for (j, (xi, yi)) in samples.iter().enumerate() {
        assert_eq!(xi.len(), seq);
        x[j * seq..(j + 1) * seq].copy_from_slice(xi);
        y[j * seq..(j + 1) * seq].copy_from_slice(yi);
        mask[j * seq..(j + 1) * seq].iter_mut().for_each(|m| *m = 1.0);
    }
    Batch {
        x: Tensor::i32(x, &[batch, seq]),
        y: Tensor::i32(y, &[batch, seq]),
        mask: Tensor::f32(mask, &[batch, seq]),
    }
}

/// Stack `k` batches along a new leading axis (FedAvg local steps).
pub fn stack_batches(batches: &[Batch]) -> (Tensor, Tensor, Tensor) {
    assert!(!batches.is_empty());
    let k = batches.len();
    let cat_f32 = |get: &dyn Fn(&Batch) -> (&Vec<f32>, &Vec<i64>)| {
        let (first, shape) = get(&batches[0]);
        let mut data = Vec::with_capacity(first.len() * k);
        for b in batches {
            data.extend_from_slice(get(b).0);
        }
        let mut s = vec![k as i64];
        s.extend_from_slice(shape);
        Tensor::F32 { data, shape: s }
    };
    let cat_any = |get: &dyn Fn(&Batch) -> &Tensor| {
        let first = get(&batches[0]);
        match first {
            Tensor::F32 { shape, .. } => {
                let mut data = Vec::new();
                for b in batches {
                    if let Tensor::F32 { data: d, .. } = get(b) {
                        data.extend_from_slice(d);
                    } else {
                        panic!("mixed dtypes in stack");
                    }
                }
                let mut s = vec![k as i64];
                s.extend_from_slice(shape);
                Tensor::F32 { data, shape: s }
            }
            Tensor::I32 { shape, .. } => {
                let mut data = Vec::new();
                for b in batches {
                    if let Tensor::I32 { data: d, .. } = get(b) {
                        data.extend_from_slice(d);
                    } else {
                        panic!("mixed dtypes in stack");
                    }
                }
                let mut s = vec![k as i64];
                s.extend_from_slice(shape);
                Tensor::I32 { data, shape: s }
            }
        }
    };
    let _ = cat_f32; // kept for clarity; cat_any handles both dtypes
    (
        cat_any(&|b: &Batch| &b.x),
        cat_any(&|b: &Batch| &b.y),
        cat_any(&|b: &Batch| &b.mask),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_batch_pads_and_masks() {
        let samples = vec![(vec![1.0; 4], 3usize)];
        let b = image_batch(&samples, 3, [2, 2, 1]);
        match &b.mask {
            Tensor::F32 { data, .. } => assert_eq!(data, &vec![1.0, 0.0, 0.0]),
            _ => panic!(),
        }
        match &b.y {
            Tensor::I32 { data, .. } => assert_eq!(data, &vec![3, 0, 0]),
            _ => panic!(),
        }
        match &b.x {
            Tensor::F32 { data, shape } => {
                assert_eq!(shape, &vec![3, 2, 2, 1]);
                assert_eq!(&data[..4], &[1.0; 4]);
                assert_eq!(&data[4..], &[0.0; 8]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn text_batch_masks_tokens() {
        let samples = vec![(vec![1, 2, 3], vec![2, 3, 4])];
        let b = text_batch(&samples, 2, 3);
        match &b.mask {
            Tensor::F32 { data, .. } => assert_eq!(data, &vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]),
            _ => panic!(),
        }
    }

    #[test]
    fn stacking_adds_leading_axis() {
        let samples = vec![(vec![1.0; 4], 0usize)];
        let b1 = image_batch(&samples, 2, [2, 2, 1]);
        let b2 = image_batch(&samples, 2, [2, 2, 1]);
        let (xs, ys, ms) = stack_batches(&[b1, b2]);
        match xs {
            Tensor::F32 { shape, data } => {
                assert_eq!(shape, vec![2, 2, 2, 2, 1]);
                assert_eq!(data.len(), 16);
            }
            _ => panic!(),
        }
        match ys {
            Tensor::I32 { shape, .. } => assert_eq!(shape, vec![2, 2]),
            _ => panic!(),
        }
        match ms {
            Tensor::F32 { shape, .. } => assert_eq!(shape, vec![2, 2]),
            _ => panic!(),
        }
    }
}
