//! Synthetic image generator: Gaussian-mixture classes with smooth
//! low-frequency prototypes, plus per-writer style transforms for the
//! FEMNIST analog.
//!
//! Class `c`'s prototype is a random coarse 4x4-per-channel pattern,
//! bilinearly upsampled — smooth structure a small conv/MLP model can
//! learn, with enough inter-class separation that test accuracy is a
//! meaningful metric. A sample is `prototype + sigma * noise`, clipped
//! to [-2, 2]. Writer styles apply brightness/contrast jitter and a
//! small cyclic translation, giving writer-partitioned clients a mild
//! covariate shift (more i.i.d. than the label-skew split — matching
//! the paper's characterization of FEMNIST vs CIFAR splits).

use crate::util::rng::{derive_seed, Rng};

/// Generator for one synthetic image task.
#[derive(Clone, Debug)]
pub struct ImageGen {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    pub classes: usize,
    pub noise_sigma: f32,
    seed: u64,
    prototypes: Vec<Vec<f32>>, // classes x (h*w*c)
}

const COARSE: usize = 4;

impl ImageGen {
    pub fn new(
        height: usize,
        width: usize,
        channels: usize,
        classes: usize,
        noise_sigma: f32,
        seed: u64,
    ) -> Self {
        let mut prototypes = Vec::with_capacity(classes);
        for c in 0..classes {
            let mut rng = Rng::new(derive_seed(seed, 0x1000 + c as u64));
            prototypes.push(Self::make_prototype(height, width, channels, &mut rng));
        }
        ImageGen { height, width, channels, classes, noise_sigma, seed, prototypes }
    }

    fn make_prototype(h: usize, w: usize, c: usize, rng: &mut Rng) -> Vec<f32> {
        // coarse grid per channel, bilinear upsample
        let mut coarse = vec![0f32; COARSE * COARSE * c];
        for v in coarse.iter_mut() {
            *v = rng.next_gaussian() as f32;
        }
        let mut out = vec![0f32; h * w * c];
        for y in 0..h {
            for x in 0..w {
                // continuous coords in coarse grid
                let fy = y as f32 / h as f32 * (COARSE - 1) as f32;
                let fx = x as f32 / w as f32 * (COARSE - 1) as f32;
                let y0 = fy.floor() as usize;
                let x0 = fx.floor() as usize;
                let y1 = (y0 + 1).min(COARSE - 1);
                let x1 = (x0 + 1).min(COARSE - 1);
                let dy = fy - y0 as f32;
                let dx = fx - x0 as f32;
                for ch in 0..c {
                    let g = |yy: usize, xx: usize| coarse[(yy * COARSE + xx) * c + ch];
                    let v = g(y0, x0) * (1.0 - dy) * (1.0 - dx)
                        + g(y0, x1) * (1.0 - dy) * dx
                        + g(y1, x0) * dy * (1.0 - dx)
                        + g(y1, x1) * dy * dx;
                    out[(y * w + x) * c + ch] = v;
                }
            }
        }
        out
    }

    pub fn pixels(&self) -> usize {
        self.height * self.width * self.channels
    }

    /// Deterministic sample `sample_id` of class `class`.
    pub fn sample(&self, class: usize, sample_id: u64) -> Vec<f32> {
        let mut rng = Rng::new(derive_seed(self.seed, (class as u64) << 32 | sample_id));
        let proto = &self.prototypes[class];
        proto
            .iter()
            .map(|&p| (p + self.noise_sigma * rng.next_gaussian() as f32).clamp(-2.0, 2.0))
            .collect()
    }

    /// Sample with a writer style applied (FEMNIST analog). The style is
    /// derived from `writer`, so all of a writer's samples share it.
    pub fn sample_writer(&self, class: usize, writer: u64, sample_id: u64) -> Vec<f32> {
        let base = self.sample(class, writer << 24 | sample_id);
        let mut style_rng = Rng::new(derive_seed(self.seed ^ 0x57AA, writer));
        let contrast = 0.7 + 0.6 * style_rng.next_f32(); // [0.7, 1.3)
        let brightness = 0.4 * style_rng.next_f32() - 0.2; // [-0.2, 0.2)
        let shift_y = style_rng.gen_range(3);
        let shift_x = style_rng.gen_range(3);
        let (h, w, c) = (self.height, self.width, self.channels);
        let mut out = vec![0f32; base.len()];
        for y in 0..h {
            for x in 0..w {
                let sy = (y + shift_y) % h;
                let sx = (x + shift_x) % w;
                for ch in 0..c {
                    let v = base[(sy * w + sx) * c + ch];
                    out[(y * w + x) * c + ch] = (v * contrast + brightness).clamp(-2.0, 2.0);
                }
            }
        }
        out
    }

    /// Mean inter-class prototype L2 distance (diagnostic: separation).
    pub fn class_separation(&self) -> f64 {
        let mut total = 0.0;
        let mut n = 0;
        for a in 0..self.classes {
            for b in (a + 1)..self.classes {
                let d: f64 = self.prototypes[a]
                    .iter()
                    .zip(&self.prototypes[b])
                    .map(|(&x, &y)| ((x - y) as f64).powi(2))
                    .sum();
                total += d.sqrt();
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            total / n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_samples() {
        let g = ImageGen::new(8, 8, 3, 10, 0.3, 42);
        assert_eq!(g.sample(3, 7), g.sample(3, 7));
        assert_ne!(g.sample(3, 7), g.sample(3, 8));
        assert_ne!(g.sample(3, 7), g.sample(4, 7));
        assert_eq!(g.sample(0, 0).len(), 8 * 8 * 3);
    }

    #[test]
    fn classes_are_separated() {
        let g = ImageGen::new(16, 16, 3, 10, 0.3, 1);
        let sep = g.class_separation();
        assert!(sep > 5.0, "class separation too small: {sep}");
        // within-class spread should be smaller than between-class
        let a1 = g.sample(0, 1);
        let a2 = g.sample(0, 2);
        let b = g.sample(1, 1);
        let da: f64 = a1.iter().zip(&a2).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
        let db: f64 = a1.iter().zip(&b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum();
        assert!(da < db, "within {da} should be < between {db}");
    }

    #[test]
    fn writer_style_consistent_within_writer() {
        let g = ImageGen::new(8, 8, 1, 5, 0.1, 9);
        // same writer, two samples: both shifted/scaled the same way, so
        // the mean pixel offset should match closely across samples of
        // the same prototype id.
        let w1a = g.sample_writer(2, 11, 0);
        let w1b = g.sample_writer(2, 11, 0);
        assert_eq!(w1a, w1b, "writer samples deterministic");
        let w2 = g.sample_writer(2, 12, 0);
        assert_ne!(w1a, w2, "different writers differ");
    }

    #[test]
    fn values_clipped() {
        let g = ImageGen::new(8, 8, 1, 3, 2.0, 5);
        for s in 0..20 {
            assert!(g.sample(0, s).iter().all(|&v| (-2.0..=2.0).contains(&v)));
        }
    }
}
