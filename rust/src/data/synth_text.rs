//! Persona-conditioned synthetic character LM corpus — the PersonaChat
//! analog.
//!
//! A global first-order Markov chain over the vocabulary provides shared
//! linguistic structure; each persona perturbs the transition rows of a
//! persona-specific subset of tokens and over-weights a small set of
//! "favorite" tokens. A transformer trained across personas thus learns
//! a common backbone (global bigrams) plus per-client idiosyncrasies —
//! the same shape of non-i.i.d.-ness the paper gets from per-personality
//! conversation styles. Perplexity against held-out sequences is the
//! metric, as in the paper.

use crate::util::rng::{derive_seed, Rng};

/// Generator for one synthetic text task.
pub struct TextGen {
    pub vocab: usize,
    pub seq: usize,
    seed: u64,
    /// Global bigram transition CDFs, vocab x vocab.
    global_cdf: Vec<f32>,
}

impl TextGen {
    pub fn new(vocab: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Rng::new(derive_seed(seed, 0x7E47));
        // Sparse-ish bigram structure: each token strongly transitions to
        // a handful of successors (concentrated rows -> learnable).
        let mut global_cdf = vec![0f32; vocab * vocab];
        for t in 0..vocab {
            let row = &mut global_cdf[t * vocab..(t + 1) * vocab];
            // base uniform mass
            for v in row.iter_mut() {
                *v = 0.2 / vocab as f32;
            }
            // concentrated successors
            for _ in 0..4 {
                let succ = rng.gen_range(vocab);
                row[succ] += 0.2;
            }
            // normalize + cumsum
            let total: f32 = row.iter().sum();
            let mut acc = 0.0;
            for v in row.iter_mut() {
                acc += *v / total;
                *v = acc;
            }
        }
        TextGen { vocab, seq, seed, global_cdf }
    }

    /// Persona-specific favorite tokens (deterministic per persona).
    fn favorites(&self, persona: u64) -> Vec<usize> {
        let mut rng = Rng::new(derive_seed(self.seed ^ 0x9E12, persona));
        (0..6).map(|_| rng.gen_range(self.vocab)).collect()
    }

    fn next_token(&self, prev: usize, favorites: &[usize], rng: &mut Rng) -> usize {
        // With prob 0.3, emit a persona favorite; else follow the global
        // bigram CDF.
        if rng.next_f32() < 0.3 {
            return favorites[rng.gen_range(favorites.len())];
        }
        let u = rng.next_f32();
        let row = &self.global_cdf[prev * self.vocab..(prev + 1) * self.vocab];
        match row.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.vocab - 1),
        }
    }

    /// Deterministic sequence `sample_id` for `persona`: returns
    /// (input tokens, target tokens), both length `seq` (targets are the
    /// inputs shifted by one).
    pub fn sample(&self, persona: u64, sample_id: u64) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(derive_seed(self.seed, persona << 24 ^ sample_id));
        let favorites = self.favorites(persona);
        let mut toks = Vec::with_capacity(self.seq + 1);
        toks.push(rng.gen_range(self.vocab));
        for i in 0..self.seq {
            let t = self.next_token(toks[i], &favorites, &mut rng);
            toks.push(t);
        }
        let x: Vec<i32> = toks[..self.seq].iter().map(|&t| t as i32).collect();
        let y: Vec<i32> = toks[1..=self.seq].iter().map(|&t| t as i32).collect();
        (x, y)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_shifted() {
        let g = TextGen::new(64, 32, 7);
        let (x1, y1) = g.sample(5, 3);
        let (x2, y2) = g.sample(5, 3);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        assert_eq!(x1.len(), 32);
        // target = input shifted by one
        assert_eq!(&x1[1..], &y1[..31]);
        assert!(x1.iter().all(|&t| (0..64).contains(&t)));
    }

    #[test]
    fn personas_have_distinct_token_distributions() {
        let g = TextGen::new(64, 32, 7);
        let hist = |persona: u64| {
            let mut h = vec![0f64; 64];
            for s in 0..50 {
                let (x, _) = g.sample(persona, s);
                for t in x {
                    h[t as usize] += 1.0;
                }
            }
            let total: f64 = h.iter().sum();
            h.iter().map(|&c| c / total).collect::<Vec<_>>()
        };
        let h1 = hist(1);
        let h2 = hist(2);
        let tv: f64 = h1.iter().zip(&h2).map(|(&a, &b)| (a - b).abs()).sum::<f64>() / 2.0;
        assert!(tv > 0.1, "personas should differ in token distribution: tv={tv}");
    }

    #[test]
    fn global_structure_shared_across_personas() {
        // Bigram statistics (beyond favorites) come from the shared chain:
        // the most frequent successor of a token should often agree
        // between personas.
        let g = TextGen::new(32, 64, 11);
        let succ_mode = |persona: u64| {
            let mut counts = vec![vec![0u32; 32]; 32];
            for s in 0..200 {
                let (x, y) = g.sample(persona, s);
                for (a, b) in x.iter().zip(&y) {
                    counts[*a as usize][*b as usize] += 1;
                }
            }
            counts
                .iter()
                .map(|row| row.iter().enumerate().max_by_key(|(_, &c)| c).unwrap().0)
                .collect::<Vec<_>>()
        };
        let m1 = succ_mode(10);
        let m2 = succ_mode(20);
        let agree = m1.iter().zip(&m2).filter(|(a, b)| a == b).count();
        // Persona favorites (30% of emissions) dilute the bigram counts,
        // so agreement is well below 100% — but must beat chance (~1/32
        // per row ≈ 1–2 total).
        assert!(agree >= 5, "global bigram structure should be shared: agree={agree}/32");
    }
}
