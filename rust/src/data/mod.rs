//! Synthetic federated datasets.
//!
//! The paper evaluates on CIFAR10/100 (per-class non-i.i.d. split),
//! FEMNIST (writer split), and PersonaChat (persona split). Real
//! datasets are unavailable in this environment, so we build
//! deterministic synthetic substitutes that preserve the properties the
//! comparison depends on (DESIGN.md §5):
//!
//! - label-skew image clients (one class per client, 1–5 samples) —
//!   the CIFAR analog, [`synth_images`] + [`partition`];
//! - writer-partitioned image clients (~200 samples, per-writer style) —
//!   the FEMNIST analog;
//! - persona-conditioned char-LM clients with power-law sizes — the
//!   PersonaChat analog, [`synth_text`].
//!
//! Nothing is stored: every sample is regenerated on demand from
//! `(dataset seed, client id, sample id)`, so 50k-client populations
//! cost no memory and every run is reproducible.

pub mod batcher;
pub mod partition;
pub mod synth_images;
pub mod synth_text;

use crate::runtime::exec::Batch;
use crate::runtime::Tensor;

/// A federated dataset: a population of clients plus a held-out eval set.
///
/// `Send + Sync` because the round engine generates client batches from
/// worker threads; implementations are pure functions of
/// `(dataset seed, client id, sample id)` with no interior mutability,
/// which is also what makes 50k-client populations free.
pub trait FedDataset: Send + Sync {
    fn num_clients(&self) -> usize;
    /// Number of local examples held by `client`.
    fn client_size(&self, client: usize) -> usize;
    /// One (padded, masked) minibatch of local data for `client`.
    /// `round_seed` decorrelates batches across rounds while staying
    /// deterministic.
    fn client_batch(&self, client: usize, round_seed: u64) -> Batch;
    /// `k` stacked local batches for FedAvg's local epochs:
    /// (xs, ys, masks) with a leading `k` axis.
    fn client_batches_stacked(&self, client: usize, k: usize, round_seed: u64)
        -> (Tensor, Tensor, Tensor);
    /// Held-out evaluation batches (balanced, identical across runs).
    fn num_eval_batches(&self) -> usize;
    fn eval_batch(&self, idx: usize) -> Batch;
}
