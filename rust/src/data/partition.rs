//! Federated partitions: the three client populations of the paper's
//! evaluation, over the synthetic generators.
//!
//! - [`LabelSkewImages`] — CIFAR analog: each client holds 1–5 images of
//!   a *single* class (`class = client % classes`), exactly the paper's
//!   §5.1 split.
//! - [`WriterImages`] — FEMNIST analog: each client is a "writer" with
//!   ~`mean_size` samples across all classes in a writer-specific style.
//! - [`PersonaText`] — PersonaChat analog: one persona per client,
//!   power-law client sizes (paper §1/§5: user activity follows a power
//!   law).

use crate::data::batcher::{image_batch, stack_batches, text_batch};
use crate::data::synth_images::ImageGen;
use crate::data::synth_text::TextGen;
use crate::data::FedDataset;
use crate::runtime::exec::Batch;
use crate::runtime::Tensor;
use crate::util::rng::{derive_seed, Rng};

const EVAL_STREAM: u64 = 1 << 40; // sample-id offset for held-out data

// ---------------------------------------------------------------------------
// Label-skew images (CIFAR analog)
// ---------------------------------------------------------------------------

pub struct LabelSkewImages {
    gen: ImageGen,
    num_clients: usize,
    samples_per_client: usize,
    batch: usize,
    eval_batches: usize,
}

impl LabelSkewImages {
    pub fn new(
        gen: ImageGen,
        num_clients: usize,
        samples_per_client: usize,
        batch: usize,
        eval_batches: usize,
    ) -> Self {
        LabelSkewImages { gen, num_clients, samples_per_client, batch, eval_batches }
    }

    fn client_class(&self, client: usize) -> usize {
        client % self.gen.classes
    }
}

impl FedDataset for LabelSkewImages {
    fn num_clients(&self) -> usize {
        self.num_clients
    }

    fn client_size(&self, _client: usize) -> usize {
        self.samples_per_client
    }

    fn client_batch(&self, client: usize, round_seed: u64) -> Batch {
        let class = self.client_class(client);
        let n = self.samples_per_client.min(self.batch);
        let mut rng = Rng::new(derive_seed(round_seed, client as u64));
        let samples: Vec<(Vec<f32>, usize)> = (0..n)
            .map(|_| {
                let sid = rng.gen_range(self.samples_per_client) as u64;
                (self.gen.sample(class, (client as u64) << 20 | sid), class)
            })
            .collect();
        image_batch(&samples, self.batch, [self.gen.height, self.gen.width, self.gen.channels])
    }

    fn client_batches_stacked(
        &self,
        client: usize,
        k: usize,
        round_seed: u64,
    ) -> (Tensor, Tensor, Tensor) {
        let batches: Vec<Batch> =
            (0..k).map(|j| self.client_batch(client, derive_seed(round_seed, j as u64))).collect();
        stack_batches(&batches)
    }

    fn num_eval_batches(&self) -> usize {
        self.eval_batches
    }

    fn eval_batch(&self, idx: usize) -> Batch {
        // balanced: cycle classes deterministically
        let samples: Vec<(Vec<f32>, usize)> = (0..self.batch)
            .map(|j| {
                let class = (idx * self.batch + j) % self.gen.classes;
                let sid = EVAL_STREAM + (idx * self.batch + j) as u64;
                (self.gen.sample(class, sid), class)
            })
            .collect();
        image_batch(&samples, self.batch, [self.gen.height, self.gen.width, self.gen.channels])
    }
}

// ---------------------------------------------------------------------------
// Writer-partitioned images (FEMNIST analog)
// ---------------------------------------------------------------------------

pub struct WriterImages {
    gen: ImageGen,
    num_clients: usize,
    batch: usize,
    eval_batches: usize,
    sizes: Vec<usize>,
}

impl WriterImages {
    pub fn new(
        gen: ImageGen,
        num_clients: usize,
        mean_size: usize,
        batch: usize,
        eval_batches: usize,
        seed: u64,
    ) -> Self {
        // sizes ~ N(mean, mean * 0.4), clipped to [mean/4, mean*2]
        let mut rng = Rng::new(derive_seed(seed, 0x517E5));
        let sizes = (0..num_clients)
            .map(|_| {
                let s = mean_size as f64 + rng.next_gaussian() * mean_size as f64 * 0.4;
                (s.round() as usize).clamp(mean_size / 4, mean_size * 2).max(1)
            })
            .collect();
        WriterImages { gen, num_clients, batch, eval_batches, sizes }
    }
}

impl FedDataset for WriterImages {
    fn num_clients(&self) -> usize {
        self.num_clients
    }

    fn client_size(&self, client: usize) -> usize {
        self.sizes[client]
    }

    fn client_batch(&self, client: usize, round_seed: u64) -> Batch {
        let size = self.sizes[client];
        let n = size.min(self.batch);
        let mut rng = Rng::new(derive_seed(round_seed, client as u64));
        let samples: Vec<(Vec<f32>, usize)> = (0..n)
            .map(|_| {
                let sid = rng.gen_range(size) as u64;
                // class deterministic per (writer, sample id): uniform mix
                let class = (derive_seed(client as u64, sid) % self.gen.classes as u64) as usize;
                (self.gen.sample_writer(class, client as u64, sid), class)
            })
            .collect();
        image_batch(&samples, self.batch, [self.gen.height, self.gen.width, self.gen.channels])
    }

    fn client_batches_stacked(
        &self,
        client: usize,
        k: usize,
        round_seed: u64,
    ) -> (Tensor, Tensor, Tensor) {
        let batches: Vec<Batch> =
            (0..k).map(|j| self.client_batch(client, derive_seed(round_seed, j as u64))).collect();
        stack_batches(&batches)
    }

    fn num_eval_batches(&self) -> usize {
        self.eval_batches
    }

    fn eval_batch(&self, idx: usize) -> Batch {
        // Held-out writers: writer ids above the training population.
        let samples: Vec<(Vec<f32>, usize)> = (0..self.batch)
            .map(|j| {
                let u = (idx * self.batch + j) as u64;
                let writer = self.num_clients as u64 + u % 97;
                let class = (derive_seed(writer, u) % self.gen.classes as u64) as usize;
                (self.gen.sample_writer(class, writer, EVAL_STREAM + u), class)
            })
            .collect();
        image_batch(&samples, self.batch, [self.gen.height, self.gen.width, self.gen.channels])
    }
}

// ---------------------------------------------------------------------------
// Persona-partitioned text (PersonaChat analog)
// ---------------------------------------------------------------------------

pub struct PersonaText {
    gen: TextGen,
    num_clients: usize,
    batch: usize,
    eval_batches: usize,
    sizes: Vec<usize>,
}

impl PersonaText {
    pub fn new(
        gen: TextGen,
        num_clients: usize,
        max_size: usize,
        alpha: f64,
        batch: usize,
        eval_batches: usize,
        seed: u64,
    ) -> Self {
        // Power-law sizes: rank clients by a permuted order, size =
        // max_size / rank^alpha, clipped to >= 1.
        let mut order: Vec<usize> = (0..num_clients).collect();
        let mut rng = Rng::new(derive_seed(seed, 0x9A12));
        rng.shuffle(&mut order);
        let mut sizes = vec![1usize; num_clients];
        for (rank, &c) in order.iter().enumerate() {
            let s = max_size as f64 / ((rank + 1) as f64).powf(alpha);
            sizes[c] = (s.round() as usize).max(1);
        }
        PersonaText { gen, num_clients, batch, eval_batches, sizes }
    }
}

impl FedDataset for PersonaText {
    fn num_clients(&self) -> usize {
        self.num_clients
    }

    fn client_size(&self, client: usize) -> usize {
        self.sizes[client]
    }

    fn client_batch(&self, client: usize, round_seed: u64) -> Batch {
        let size = self.sizes[client];
        let n = size.min(self.batch);
        let mut rng = Rng::new(derive_seed(round_seed, client as u64));
        let samples: Vec<(Vec<i32>, Vec<i32>)> = (0..n)
            .map(|_| {
                let sid = rng.gen_range(size) as u64;
                self.gen.sample(client as u64, sid)
            })
            .collect();
        text_batch(&samples, self.batch, self.gen.seq)
    }

    fn client_batches_stacked(
        &self,
        client: usize,
        k: usize,
        round_seed: u64,
    ) -> (Tensor, Tensor, Tensor) {
        let batches: Vec<Batch> =
            (0..k).map(|j| self.client_batch(client, derive_seed(round_seed, j as u64))).collect();
        stack_batches(&batches)
    }

    fn num_eval_batches(&self) -> usize {
        self.eval_batches
    }

    fn eval_batch(&self, idx: usize) -> Batch {
        // Held-out personas (ids above the training population) measure
        // generalization of the shared structure, like the paper's
        // validation perplexity.
        let samples: Vec<(Vec<i32>, Vec<i32>)> = (0..self.batch)
            .map(|j| {
                let u = (idx * self.batch + j) as u64;
                let persona = self.num_clients as u64 + u % 101;
                self.gen.sample(persona, EVAL_STREAM + u)
            })
            .collect();
        text_batch(&samples, self.batch, self.gen.seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn img_gen() -> ImageGen {
        ImageGen::new(8, 8, 1, 10, 0.2, 3)
    }

    #[test]
    fn label_skew_single_class_per_client() {
        let ds = LabelSkewImages::new(img_gen(), 100, 5, 8, 2);
        for client in [0usize, 7, 53] {
            let b = ds.client_batch(client, 1);
            if let (Tensor::I32 { data: y, .. }, Tensor::F32 { data: m, .. }) = (&b.y, &b.mask) {
                for (label, mask) in y.iter().zip(m) {
                    if *mask > 0.0 {
                        assert_eq!(*label as usize, client % 10);
                    }
                }
                assert_eq!(m.iter().filter(|&&x| x > 0.0).count(), 5);
            } else {
                panic!("wrong tensor types");
            }
        }
    }

    #[test]
    fn eval_batches_are_balanced_and_stable() {
        let ds = LabelSkewImages::new(img_gen(), 100, 5, 10, 2);
        let b1 = ds.eval_batch(0);
        let b2 = ds.eval_batch(0);
        assert_eq!(b1.y, b2.y);
        if let Tensor::I32 { data: y, .. } = &b1.y {
            let mut seen = vec![false; 10];
            for &l in y {
                seen[l as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "balanced eval batch covers classes");
        }
    }

    #[test]
    fn writer_sizes_vary_but_bounded() {
        let ds = WriterImages::new(img_gen(), 200, 40, 16, 2, 5);
        let sizes: Vec<usize> = (0..200).map(|c| ds.client_size(c)).collect();
        assert!(sizes.iter().any(|&s| s != sizes[0]), "sizes should vary");
        assert!(sizes.iter().all(|&s| (10..=80).contains(&s)));
    }

    #[test]
    fn persona_sizes_power_law() {
        let g = TextGen::new(64, 16, 1);
        let ds = PersonaText::new(g, 1000, 500, 1.1, 4, 2, 9);
        let mut sizes: Vec<usize> = (0..1000).map(|c| ds.client_size(c)).collect();
        sizes.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(sizes[0], 500);
        assert!(sizes[999] == 1);
        // median should be tiny relative to max (heavy head)
        assert!(sizes[500] <= 5, "median size {}", sizes[500]);
    }

    #[test]
    fn stacked_batches_shapes() {
        let ds = LabelSkewImages::new(img_gen(), 10, 5, 4, 1);
        let (xs, ys, ms) = ds.client_batches_stacked(3, 2, 99);
        if let Tensor::F32 { shape, .. } = xs {
            assert_eq!(shape, vec![2, 4, 8, 8, 1]);
        } else {
            panic!()
        }
        if let Tensor::I32 { shape, .. } = ys {
            assert_eq!(shape, vec![2, 4]);
        } else {
            panic!()
        }
        if let Tensor::F32 { shape, .. } = ms {
            assert_eq!(shape, vec![2, 4]);
        } else {
            panic!()
        }
    }

    #[test]
    fn round_seed_decorrelates_batches() {
        let ds = LabelSkewImages::new(img_gen(), 10, 5, 4, 1);
        let b1 = ds.client_batch(2, 1);
        let b2 = ds.client_batch(2, 2);
        // same client, different round -> possibly different subset; at
        // minimum the call is deterministic per seed
        let b1b = ds.client_batch(2, 1);
        assert_eq!(b1.x, b1b.x);
        let _ = b2;
    }
}
