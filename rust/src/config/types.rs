//! Typed training configuration + JSON/CLI parsing.
//!
//! Configs load from JSON files (`fetchsgd train --config cfg.json`) and
//! accept `key=value` CLI overrides for every field, so experiment
//! drivers and users share one source of truth.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;

use crate::config::schedule::LrSchedule;
use crate::model::DataScale;
use crate::serialize::json::{parse, Value};

/// Which optimization strategy to run (paper §5's comparison set).
#[derive(Clone, Debug, PartialEq)]
pub enum StrategyConfig {
    FetchSgd {
        k: usize,
        cols: usize,
        rho: f32,
        /// "zero_out" (paper §5) or "subtract" (Algorithm 1 line 14).
        error_update: String,
        /// "vanilla" | "ring:I" | "log:I"
        error_window: String,
        masking: bool,
    },
    LocalTopK {
        k: usize,
        rho_g: f32,
        masking: bool,
        local_error: bool,
    },
    FedAvg {
        local_steps: usize,
        rho_g: f32,
    },
    Uncompressed {
        rho_g: f32,
    },
    TrueTopK {
        k: usize,
        rho: f32,
        masking: bool,
    },
}

/// Normalize an optional string knob (`wire`, `transport`): "off"/""/
/// "none" = disabled, anything else is kept and validated downstream
/// (codec registry / endpoint parser), so typos fail before any round
/// runs.
fn parse_wire(v: &str) -> Option<String> {
    match v {
        "" | "off" | "none" => None,
        codec => Some(codec.to_string()),
    }
}

/// Parse the `shard_tiers` knob: `"off"`/`""`/`"none"` = flat reduce,
/// otherwise `x`-separated per-tier relay fan-outs, root first (e.g.
/// `"2x2"` = a depth-3 tree of 2 relays with 2 relay children each).
/// Every fan-out must be a positive integer.
fn parse_tiers(v: &str) -> Result<Vec<usize>> {
    match v {
        "" | "off" | "none" => Ok(Vec::new()),
        s => s
            .split('x')
            .map(|t| match t.trim().parse::<usize>() {
                Ok(n) if n > 0 => Ok(n),
                _ => bail!("shard_tiers must be x-separated positive fan-outs, got '{s}'"),
            })
            .collect(),
    }
}

/// Validate a JSON `round_deadline_ms` before the float→integer cast:
/// a negative or non-finite value would silently saturate to 0
/// (wait-forever) instead of erroring like the same value does on the
/// CLI override path.
fn deadline_ms_from_json(ms: f64) -> Result<u64> {
    if !ms.is_finite() || ms < 0.0 {
        bail!("round_deadline_ms must be a non-negative number of milliseconds, got {ms}");
    }
    Ok(ms as u64)
}

impl StrategyConfig {
    pub fn name(&self) -> &'static str {
        match self {
            StrategyConfig::FetchSgd { .. } => "fetchsgd",
            StrategyConfig::LocalTopK { .. } => "local_topk",
            StrategyConfig::FedAvg { .. } => "fedavg",
            StrategyConfig::Uncompressed { .. } => "uncompressed",
            StrategyConfig::TrueTopK { .. } => "true_topk",
        }
    }
}

/// Full configuration of one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Manifest task name (smoke / cifar10 / cifar100 / femnist /
    /// persona / persona_large).
    pub task: String,
    pub strategy: StrategyConfig,
    pub rounds: usize,
    /// Clients sampled per round (W).
    pub clients_per_round: usize,
    pub lr: LrSchedule,
    pub scale: DataScale,
    /// Evaluate every N rounds (0 = only at the end).
    pub eval_every: usize,
    /// Run seed (client selection etc.).
    pub seed: u64,
    /// Artifacts directory.
    pub artifacts_dir: PathBuf,
    /// Optional JSONL metrics output.
    pub log_path: Option<PathBuf>,
    /// Optional structured trace output (`crate::trace` JSONL): phase
    /// spans, per-slot timelines, latency histograms. Off by default —
    /// and with it off, the round hot paths stay free of clock reads.
    pub trace_path: Option<PathBuf>,
    /// Baseline rounds for compression ratios (defaults to `rounds`).
    pub baseline_rounds: Option<usize>,
    /// Print per-round progress lines.
    pub verbose: bool,
    /// Worker threads for per-round client compute (0 = all available
    /// cores). Any value produces bitwise-identical results for a given
    /// seed — the round pipeline's shard layout and reduction tree are
    /// thread-invariant (`compression::aggregate`). Workers pull
    /// individual participant slots, so values up to
    /// `clients_per_round` keep paying off; beyond that they idle.
    pub parallelism: usize,
    /// Wire mode: `Some(codec)` round-trips every upload and broadcast
    /// through the framed binary encoding of `crate::wire` under the
    /// named codec ("f32le" | "f16le"), recording *measured* frame
    /// bytes next to the paper-convention estimates. `None` keeps
    /// uploads in memory (estimates only). Under "f32le" the training
    /// trajectory is bitwise identical to wire-off; "f16le" quantizes
    /// the payloads (lossy, half the value bytes).
    pub wire: Option<String>,
    /// Transport endpoint for served training (`fetchsgd serve` /
    /// `fetchsgd join`): `tcp:HOST:PORT` or `uds:/path.sock`; "off" /
    /// "" / "none" = in-process training. Serving implies wire framing:
    /// uploads and broadcasts cross this socket as `FSGW` frames under
    /// the `wire` codec (default `f32le`, under which a served run is
    /// bitwise identical to `fetchsgd train` on the same config).
    pub transport: Option<String>,
    /// Worker connections a `serve` run waits for; each worker computes
    /// one or more participant slots per round. Ignored in-process.
    pub transport_workers: usize,
    /// Worker threads for the round pipeline's row-strip shard
    /// reduction. 0 = inherit `parallelism` for in-process training; in
    /// serve mode (where `parallelism` governs nothing — client compute
    /// is remote) 0 means all available cores. Like `parallelism`, a
    /// pure throughput knob: the strip partition is a function of the
    /// accumulator geometry only, so any value produces bitwise-
    /// identical results.
    pub reduce_parallelism: usize,
    /// Serve mode: per-connection read/write deadline in seconds. A
    /// peer that stalls longer than this mid-round fails the round
    /// instead of wedging it. The 30 s default suits loopback and LAN;
    /// raise it for WAN workers with slow links or big models.
    pub serve_read_timeout_s: f64,
    /// Serve mode: how long to wait for the worker pool to fill at
    /// round start, in seconds.
    pub serve_accept_timeout_s: f64,
    /// Serve/join mode: per-message size cap in bytes — forged length
    /// prefixes are rejected against this before any allocation. 0 =
    /// auto-size from the model dimension and cohort (the default; set
    /// explicitly only to clamp hostile peers harder or to lift the cap
    /// for giant frames).
    pub serve_max_msg: usize,
    /// Minimum fraction of the sampled cohort that must deliver an
    /// upload for a round to close, in (0, 1]. Below the quorum the
    /// round fails; at or above it, missing slots are dropped and the
    /// aggregation weights are renormalized over the actual
    /// participants (`cohort::RoundMembership`). 1.0 (the default)
    /// requires the full cohort — the pre-cohort behavior.
    pub quorum_fraction: f64,
    /// Wall-clock budget per round in milliseconds. Once it expires
    /// with the quorum met, outstanding stragglers are dropped instead
    /// of holding the round open. 0 (the default) = wait forever,
    /// preserving the pre-cohort pacing.
    pub round_deadline_ms: u64,
    /// How many times a faulted slot is retried (in-process: the client
    /// compute re-run; served: the slot re-offered to a healthy worker
    /// connection) before it is dropped. 0 (the default) = no retries.
    pub max_slot_retries: usize,
    /// Root accumulator shards for the round pipeline. 0 (the default)
    /// = auto (`shard_count(parallelism)`). A flat server or in-process
    /// run that wants to reproduce a relay tree's bits sets this to the
    /// tree's relay count: each relay owns exactly one shard chain, so
    /// matching the shard layout makes the two topologies fold in the
    /// same order.
    pub shards: usize,
    /// Per-tier relay fan-outs (root first) for the tree-shaped shard
    /// reduction, empty (the default) = flat left-assoc reduce. A flat
    /// server or in-process run that wants to reproduce a *nested*
    /// relay tree's bits sets this to the tree's fan-outs (e.g.
    /// `shard_tiers=2x2` for a depth-3 tree of 2 relays with 2 relay
    /// children each); a single tier is equivalent to `shards=R`. See
    /// `compression::aggregate::reduce_shards_tree`.
    pub shard_tiers: Vec<usize>,
    /// Serve mode: number of downstream *relays* this server aggregates
    /// over instead of direct workers. 0 (the default) = flat serving.
    /// When set, the server expects `relay-hello` handshakes, assigns
    /// each relay a slot chain via `subtree-assign`, and absorbs one
    /// merged frame per relay; `transport_workers` is ignored.
    pub relay_children: usize,
    /// Relay mode (`fetchsgd relay`): the downstream endpoint this relay
    /// listens on for its own workers (`tcp:HOST:PORT` or
    /// `uds:/path.sock`). The upstream endpoint it joins is `transport`.
    pub relay_listen: Option<String>,
    /// Join/relay mode: how many times a lost upstream connection is
    /// re-dialed before giving up. Each successful round resets the
    /// counter. 0 (the default) = fail on the first disconnect.
    pub reconnect_attempts: usize,
    /// Join/relay mode: initial reconnect backoff in milliseconds;
    /// doubles per consecutive failure, capped at 10 s.
    pub reconnect_backoff_ms: u64,
    /// Let the round pipeline re-size its absorb shard count between
    /// rounds from the previous rounds' observed lock contention
    /// (`compression::aggregate`: stall rate above 25% doubles the
    /// shard count up to a clamp; under 5% decays it back). Off (the
    /// default) keeps the fixed auto layout. Conflicts with anything
    /// that pins the layout: explicit `shards`, `shard_tiers`, or
    /// `relay_children` (a tree's shard layout *is* its contract).
    pub adaptive_shards: bool,
    /// Pin absorb/reduce workers to cores (round-robin by worker
    /// index, Linux `sched_setaffinity`; best-effort elsewhere and
    /// under restrictive cpusets). A placement hint only — results are
    /// bitwise identical either way. Requires some parallelism to
    /// exist: it is an error to combine with `parallelism=1` and
    /// `reduce_parallelism=1`.
    pub pin_shards: bool,
}

impl TrainConfig {
    /// Tiny config for tests and the quickstart example.
    pub fn default_smoke() -> TrainConfig {
        TrainConfig {
            task: "smoke".into(),
            strategy: StrategyConfig::FetchSgd {
                k: 50,
                cols: 512,
                rho: 0.9,
                error_update: "zero_out".into(),
                error_window: "vanilla".into(),
                masking: true,
            },
            rounds: 20,
            clients_per_round: 4,
            lr: LrSchedule::Triangular { peak: 0.2, pivot: 0.25 },
            scale: DataScale::smoke(),
            eval_every: 10,
            seed: 1,
            artifacts_dir: PathBuf::from("artifacts"),
            log_path: None,
            trace_path: None,
            baseline_rounds: None,
            verbose: false,
            parallelism: 0,
            wire: None,
            transport: None,
            transport_workers: 1,
            reduce_parallelism: 0,
            serve_read_timeout_s: 30.0,
            serve_accept_timeout_s: 30.0,
            serve_max_msg: 0,
            quorum_fraction: 1.0,
            round_deadline_ms: 0,
            max_slot_retries: 0,
            shards: 0,
            shard_tiers: Vec::new(),
            relay_children: 0,
            relay_listen: None,
            reconnect_attempts: 0,
            reconnect_backoff_ms: 200,
            adaptive_shards: false,
            pin_shards: false,
        }
    }

    /// The single validation point for the absorb-pipeline knobs
    /// (`adaptive_shards` / `pin_shards`), run eagerly at JSON parse
    /// and override time so nonsense combinations fail loudly before
    /// any round starts.
    pub fn validate_pipeline_knobs(&self) -> Result<()> {
        if self.adaptive_shards {
            if self.shards > 0 {
                bail!(
                    "adaptive_shards=true conflicts with shards={}: an explicit shard count \
                     pins the fold layout, which is exactly what the adaptive sizer would \
                     change. Drop one of the two knobs.",
                    self.shards
                );
            }
            if !self.shard_tiers.is_empty() {
                bail!(
                    "adaptive_shards=true conflicts with shard_tiers: a tier layout pins the \
                     reduction tree shape. Drop one of the two knobs."
                );
            }
            if self.relay_children > 0 {
                bail!(
                    "adaptive_shards=true conflicts with relay_children={}: a relay tree's \
                     shard layout (one shard per child) is part of the tree contract and \
                     cannot self-size. Drop one of the two knobs.",
                    self.relay_children
                );
            }
        }
        if self.pin_shards && self.parallelism == 1 && self.reduce_parallelism == 1 {
            bail!(
                "pin_shards=true has nothing to pin when parallelism=1 and \
                 reduce_parallelism=1: both pools are explicitly single-threaded. Raise one \
                 of them (or 0 = auto) or drop pin_shards."
            );
        }
        Ok(())
    }

    /// The quorum policy these knobs describe; the single validation
    /// point for `quorum_fraction` / `round_deadline_ms` /
    /// `max_slot_retries` (also run eagerly at config parse time so a
    /// bad value fails before any round starts).
    pub fn quorum_policy(&self) -> Result<crate::cohort::QuorumPolicy> {
        crate::cohort::QuorumPolicy::new(
            self.quorum_fraction,
            self.round_deadline_ms,
            self.max_slot_retries,
        )
    }

    /// Load from a JSON file then apply `key=value` overrides.
    pub fn load(path: &std::path::Path, overrides: &[String]) -> Result<TrainConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let v = parse(&text)?;
        let mut cfg = Self::from_json(&v)?;
        cfg.apply_overrides(overrides)?;
        Ok(cfg)
    }

    pub fn from_json(v: &Value) -> Result<TrainConfig> {
        let strategy = Self::strategy_from_json(v.req("strategy")?)?;
        let mut scale = DataScale::default();
        if let Some(s) = v.get("scale") {
            scale.num_clients = s.opt_usize("num_clients", scale.num_clients);
            scale.samples_per_client = s.opt_usize("samples_per_client", scale.samples_per_client);
            scale.writer_mean_size = s.opt_usize("writer_mean_size", scale.writer_mean_size);
            scale.persona_max_size = s.opt_usize("persona_max_size", scale.persona_max_size);
            scale.persona_alpha = s.opt_f64("persona_alpha", scale.persona_alpha);
            scale.eval_batches = s.opt_usize("eval_batches", scale.eval_batches);
            scale.noise_sigma = s.opt_f64("noise_sigma", scale.noise_sigma as f64) as f32;
            scale.partition = s.opt_str("partition", &scale.partition).to_string();
            scale.seed = s.opt_f64("seed", scale.seed as f64) as u64;
        }
        let cfg = TrainConfig {
            task: v.req_str("task")?.to_string(),
            strategy,
            rounds: v.req_usize("rounds")?,
            clients_per_round: v.req_usize("clients_per_round")?,
            lr: LrSchedule::parse(v.req_str("lr")?)?,
            scale,
            eval_every: v.opt_usize("eval_every", 0),
            seed: v.opt_f64("seed", 1.0) as u64,
            artifacts_dir: PathBuf::from(v.opt_str("artifacts_dir", "artifacts")),
            log_path: v.get("log_path").and_then(|p| p.as_str()).map(PathBuf::from),
            trace_path: v.get("trace_path").and_then(|p| p.as_str()).map(PathBuf::from),
            baseline_rounds: v.get("baseline_rounds").and_then(|b| b.as_usize()),
            verbose: v.opt_bool("verbose", false),
            parallelism: v.opt_usize("parallelism", 0),
            wire: parse_wire(v.opt_str("wire", "off")),
            transport: parse_wire(v.opt_str("transport", "off")),
            transport_workers: v.opt_usize("transport_workers", 1),
            reduce_parallelism: v.opt_usize("reduce_parallelism", 0),
            serve_read_timeout_s: v.opt_f64("serve_read_timeout_s", 30.0),
            serve_accept_timeout_s: v.opt_f64("serve_accept_timeout_s", 30.0),
            serve_max_msg: v.opt_usize("serve_max_msg", 0),
            quorum_fraction: v.opt_f64("quorum_fraction", 1.0),
            round_deadline_ms: deadline_ms_from_json(v.opt_f64("round_deadline_ms", 0.0))?,
            max_slot_retries: v.opt_usize("max_slot_retries", 0),
            shards: v.opt_usize("shards", 0),
            shard_tiers: parse_tiers(v.opt_str("shard_tiers", "off"))?,
            relay_children: v.opt_usize("relay_children", 0),
            relay_listen: parse_wire(v.opt_str("relay_listen", "off")),
            reconnect_attempts: v.opt_usize("reconnect_attempts", 0),
            reconnect_backoff_ms: v.opt_f64("reconnect_backoff_ms", 200.0) as u64,
            adaptive_shards: v.opt_bool("adaptive_shards", false),
            pin_shards: v.opt_bool("pin_shards", false),
        };
        cfg.quorum_policy()?;
        cfg.validate_pipeline_knobs()?;
        Ok(cfg)
    }

    fn strategy_from_json(v: &Value) -> Result<StrategyConfig> {
        let kind = v.req_str("kind")?;
        Ok(match kind {
            "fetchsgd" => StrategyConfig::FetchSgd {
                k: v.req_usize("k")?,
                cols: v.req_usize("cols")?,
                rho: v.opt_f64("rho", 0.9) as f32,
                error_update: v.opt_str("error_update", "zero_out").to_string(),
                error_window: v.opt_str("error_window", "vanilla").to_string(),
                masking: v.opt_bool("masking", true),
            },
            "local_topk" => StrategyConfig::LocalTopK {
                k: v.req_usize("k")?,
                rho_g: v.opt_f64("rho_g", 0.0) as f32,
                masking: v.opt_bool("masking", true),
                local_error: v.opt_bool("local_error", false),
            },
            "fedavg" => StrategyConfig::FedAvg {
                local_steps: v.req_usize("local_steps")?,
                rho_g: v.opt_f64("rho_g", 0.0) as f32,
            },
            "uncompressed" => {
                StrategyConfig::Uncompressed { rho_g: v.opt_f64("rho_g", 0.9) as f32 }
            }
            "true_topk" => StrategyConfig::TrueTopK {
                k: v.req_usize("k")?,
                rho: v.opt_f64("rho", 0.9) as f32,
                masking: v.opt_bool("masking", true),
            },
            other => bail!("unknown strategy kind '{other}'"),
        })
    }

    /// Apply `key=value` overrides (dotted paths for nested fields).
    pub fn apply_overrides(&mut self, overrides: &[String]) -> Result<()> {
        for ov in overrides {
            let (key, val) = ov
                .split_once('=')
                .with_context(|| format!("override '{ov}' must be key=value"))?;
            match key {
                "task" => self.task = val.to_string(),
                "rounds" => self.rounds = val.parse()?,
                "clients_per_round" => self.clients_per_round = val.parse()?,
                "lr" => self.lr = LrSchedule::parse(val)?,
                "eval_every" => self.eval_every = val.parse()?,
                "seed" => self.seed = val.parse()?,
                "artifacts_dir" => self.artifacts_dir = PathBuf::from(val),
                "log_path" => self.log_path = Some(PathBuf::from(val)),
                "trace_path" => self.trace_path = Some(PathBuf::from(val)),
                "baseline_rounds" => self.baseline_rounds = Some(val.parse()?),
                "verbose" => self.verbose = val.parse()?,
                "parallelism" => self.parallelism = val.parse()?,
                "wire" => self.wire = parse_wire(val),
                "transport" => self.transport = parse_wire(val),
                "transport_workers" => self.transport_workers = val.parse()?,
                "reduce_parallelism" => self.reduce_parallelism = val.parse()?,
                "serve_read_timeout_s" => self.serve_read_timeout_s = val.parse()?,
                "serve_accept_timeout_s" => self.serve_accept_timeout_s = val.parse()?,
                "serve_max_msg" => self.serve_max_msg = val.parse()?,
                "quorum_fraction" => self.quorum_fraction = val.parse()?,
                "round_deadline_ms" => self.round_deadline_ms = val.parse()?,
                "max_slot_retries" => self.max_slot_retries = val.parse()?,
                "shards" => self.shards = val.parse()?,
                "shard_tiers" => self.shard_tiers = parse_tiers(val)?,
                "relay_children" => self.relay_children = val.parse()?,
                "relay_listen" => self.relay_listen = parse_wire(val),
                "reconnect_attempts" => self.reconnect_attempts = val.parse()?,
                "reconnect_backoff_ms" => self.reconnect_backoff_ms = val.parse()?,
                "adaptive_shards" => self.adaptive_shards = val.parse()?,
                "pin_shards" => self.pin_shards = val.parse()?,
                "scale.num_clients" => self.scale.num_clients = val.parse()?,
                "scale.samples_per_client" => self.scale.samples_per_client = val.parse()?,
                "scale.writer_mean_size" => self.scale.writer_mean_size = val.parse()?,
                "scale.persona_max_size" => self.scale.persona_max_size = val.parse()?,
                "scale.eval_batches" => self.scale.eval_batches = val.parse()?,
                "scale.partition" => self.scale.partition = val.to_string(),
                "scale.seed" => self.scale.seed = val.parse()?,
                _ => {
                    if !self.apply_strategy_override(key, val)? {
                        bail!("unknown config key '{key}'");
                    }
                }
            }
        }
        self.quorum_policy()?;
        self.validate_pipeline_knobs()?;
        Ok(())
    }

    fn apply_strategy_override(&mut self, key: &str, val: &str) -> Result<bool> {
        match (&mut self.strategy, key) {
            (StrategyConfig::FetchSgd { k, .. }, "strategy.k")
            | (StrategyConfig::LocalTopK { k, .. }, "strategy.k")
            | (StrategyConfig::TrueTopK { k, .. }, "strategy.k") => {
                *k = val.parse()?;
                Ok(true)
            }
            (StrategyConfig::FetchSgd { cols, .. }, "strategy.cols") => {
                *cols = val.parse()?;
                Ok(true)
            }
            (StrategyConfig::FetchSgd { rho, .. }, "strategy.rho")
            | (StrategyConfig::TrueTopK { rho, .. }, "strategy.rho") => {
                *rho = val.parse()?;
                Ok(true)
            }
            (StrategyConfig::FetchSgd { error_update, .. }, "strategy.error_update") => {
                *error_update = val.to_string();
                Ok(true)
            }
            (StrategyConfig::FetchSgd { error_window, .. }, "strategy.error_window") => {
                *error_window = val.to_string();
                Ok(true)
            }
            (StrategyConfig::FetchSgd { masking, .. }, "strategy.masking")
            | (StrategyConfig::LocalTopK { masking, .. }, "strategy.masking")
            | (StrategyConfig::TrueTopK { masking, .. }, "strategy.masking") => {
                *masking = val.parse()?;
                Ok(true)
            }
            (StrategyConfig::LocalTopK { rho_g, .. }, "strategy.rho_g")
            | (StrategyConfig::FedAvg { rho_g, .. }, "strategy.rho_g")
            | (StrategyConfig::Uncompressed { rho_g }, "strategy.rho_g") => {
                *rho_g = val.parse()?;
                Ok(true)
            }
            (StrategyConfig::FedAvg { local_steps, .. }, "strategy.local_steps") => {
                *local_steps = val.parse()?;
                Ok(true)
            }
            _ => Ok(false),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CFG: &str = r#"{
      "task": "cifar10",
      "strategy": {"kind": "fetchsgd", "k": 100, "cols": 4096, "rho": 0.9},
      "rounds": 50, "clients_per_round": 10,
      "lr": "triangular:0.3:0.2",
      "scale": {"num_clients": 500, "samples_per_client": 5},
      "eval_every": 10
    }"#;

    #[test]
    fn parses_full_config() {
        let v = parse(CFG).unwrap();
        let cfg = TrainConfig::from_json(&v).unwrap();
        assert_eq!(cfg.task, "cifar10");
        assert_eq!(cfg.rounds, 50);
        assert_eq!(cfg.scale.num_clients, 500);
        assert_eq!(cfg.parallelism, 0, "parallelism defaults to auto");
        assert_eq!(cfg.reduce_parallelism, 0, "reduce parallelism defaults to inherit");
        assert_eq!(cfg.serve_read_timeout_s, 30.0, "loopback-tuned default");
        assert_eq!(cfg.serve_accept_timeout_s, 30.0);
        assert_eq!(cfg.serve_max_msg, 0, "message cap defaults to auto-size");
        match cfg.strategy {
            StrategyConfig::FetchSgd { k, cols, masking, .. } => {
                assert_eq!(k, 100);
                assert_eq!(cols, 4096);
                assert!(masking); // default true
            }
            _ => panic!(),
        }
    }

    #[test]
    fn overrides_work() {
        let v = parse(CFG).unwrap();
        let mut cfg = TrainConfig::from_json(&v).unwrap();
        cfg.apply_overrides(&[
            "rounds=99".into(),
            "strategy.k=7".into(),
            "lr=constant:0.05".into(),
            "scale.num_clients=42".into(),
            "parallelism=4".into(),
        ])
        .unwrap();
        assert_eq!(cfg.rounds, 99);
        assert_eq!(cfg.scale.num_clients, 42);
        assert_eq!(cfg.parallelism, 4);
        assert_eq!(cfg.wire, None, "wire defaults to off");
        cfg.apply_overrides(&["wire=f16le".into()]).unwrap();
        assert_eq!(cfg.wire.as_deref(), Some("f16le"));
        cfg.apply_overrides(&["wire=off".into()]).unwrap();
        assert_eq!(cfg.wire, None);
        assert_eq!(cfg.transport, None, "transport defaults to off");
        assert_eq!(cfg.transport_workers, 1, "one worker by default");
        cfg.apply_overrides(&["transport=uds:/tmp/f.sock".into(), "transport_workers=4".into()])
            .unwrap();
        assert_eq!(cfg.transport.as_deref(), Some("uds:/tmp/f.sock"));
        assert_eq!(cfg.transport_workers, 4);
        cfg.apply_overrides(&["transport=none".into()]).unwrap();
        assert_eq!(cfg.transport, None);
        cfg.apply_overrides(&[
            "reduce_parallelism=3".into(),
            "serve_read_timeout_s=120".into(),
            "serve_accept_timeout_s=7.5".into(),
            "serve_max_msg=1048576".into(),
        ])
        .unwrap();
        assert_eq!(cfg.reduce_parallelism, 3);
        assert_eq!(cfg.serve_read_timeout_s, 120.0);
        assert_eq!(cfg.serve_accept_timeout_s, 7.5);
        assert_eq!(cfg.serve_max_msg, 1 << 20);
        match cfg.strategy {
            StrategyConfig::FetchSgd { k, .. } => assert_eq!(k, 7),
            _ => panic!(),
        }
        assert!(cfg.apply_overrides(&["nope=1".into()]).is_err());
        assert!(cfg.apply_overrides(&["strategy.local_steps=2".into()]).is_err());
    }

    #[test]
    fn quorum_knobs_parse_validate_and_default_to_strict() {
        let v = parse(CFG).unwrap();
        let mut cfg = TrainConfig::from_json(&v).unwrap();
        assert_eq!(cfg.quorum_fraction, 1.0, "full cohort by default");
        assert_eq!(cfg.round_deadline_ms, 0, "wait-forever by default");
        assert_eq!(cfg.max_slot_retries, 0, "no retries by default");
        assert!(cfg.quorum_policy().unwrap().is_strict());
        cfg.apply_overrides(&[
            "quorum_fraction=0.5".into(),
            "round_deadline_ms=1500".into(),
            "max_slot_retries=2".into(),
        ])
        .unwrap();
        assert_eq!(cfg.quorum_fraction, 0.5);
        assert_eq!(cfg.round_deadline_ms, 1500);
        assert_eq!(cfg.max_slot_retries, 2);
        let p = cfg.quorum_policy().unwrap();
        assert_eq!(p.quorum_target(10), 5);
        // Out-of-range fractions are rejected at override time…
        assert!(cfg.apply_overrides(&["quorum_fraction=0".into()]).is_err());
        assert!(cfg.apply_overrides(&["quorum_fraction=1.5".into()]).is_err());
        // …and at JSON parse time.
        let bad = CFG.replace("\"eval_every\": 10", "\"eval_every\": 10, \"quorum_fraction\": -1");
        let v = parse(&bad).unwrap();
        assert!(TrainConfig::from_json(&v).is_err());
        // A negative deadline must error, not saturate to wait-forever.
        let bad =
            CFG.replace("\"eval_every\": 10", "\"eval_every\": 10, \"round_deadline_ms\": -500");
        let v = parse(&bad).unwrap();
        let err = TrainConfig::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("round_deadline_ms"), "{err}");
    }

    #[test]
    fn relay_and_reconnect_knobs_parse_and_override() {
        let v = parse(CFG).unwrap();
        let mut cfg = TrainConfig::from_json(&v).unwrap();
        assert_eq!(cfg.shards, 0, "shard layout defaults to auto");
        assert_eq!(cfg.relay_children, 0, "flat serving by default");
        assert_eq!(cfg.relay_listen, None);
        assert_eq!(cfg.reconnect_attempts, 0, "no reconnects by default");
        assert_eq!(cfg.reconnect_backoff_ms, 200);
        cfg.apply_overrides(&[
            "shards=3".into(),
            "relay_children=2".into(),
            "relay_listen=uds:/tmp/relay.sock".into(),
            "reconnect_attempts=5".into(),
            "reconnect_backoff_ms=50".into(),
        ])
        .unwrap();
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.relay_children, 2);
        assert_eq!(cfg.relay_listen.as_deref(), Some("uds:/tmp/relay.sock"));
        assert_eq!(cfg.reconnect_attempts, 5);
        assert_eq!(cfg.reconnect_backoff_ms, 50);
        cfg.apply_overrides(&["relay_listen=off".into()]).unwrap();
        assert_eq!(cfg.relay_listen, None);
        // Tier layouts: x-separated fan-outs, root first.
        assert!(cfg.shard_tiers.is_empty(), "flat reduce by default");
        cfg.apply_overrides(&["shard_tiers=2x2".into()]).unwrap();
        assert_eq!(cfg.shard_tiers, vec![2, 2]);
        cfg.apply_overrides(&["shard_tiers=off".into()]).unwrap();
        assert!(cfg.shard_tiers.is_empty());
        assert!(cfg.apply_overrides(&["shard_tiers=2x0".into()]).is_err());
        assert!(cfg.apply_overrides(&["shard_tiers=two".into()]).is_err());
        // JSON path accepts the same keys.
        let json = CFG.replace(
            "\"eval_every\": 10",
            "\"eval_every\": 10, \"shards\": 2, \"relay_children\": 4, \
             \"relay_listen\": \"tcp:127.0.0.1:9001\", \"reconnect_attempts\": 3, \
             \"reconnect_backoff_ms\": 100, \"shard_tiers\": \"3x2\"",
        );
        let v = parse(&json).unwrap();
        let cfg = TrainConfig::from_json(&v).unwrap();
        assert_eq!(cfg.shards, 2);
        assert_eq!(cfg.shard_tiers, vec![3, 2]);
        assert_eq!(cfg.relay_children, 4);
        assert_eq!(cfg.relay_listen.as_deref(), Some("tcp:127.0.0.1:9001"));
        assert_eq!(cfg.reconnect_attempts, 3);
        assert_eq!(cfg.reconnect_backoff_ms, 100);
    }

    #[test]
    fn pipeline_knobs_parse_validate_and_reject_nonsense_combos() {
        let v = parse(CFG).unwrap();
        let mut cfg = TrainConfig::from_json(&v).unwrap();
        assert!(!cfg.adaptive_shards, "fixed layout by default");
        assert!(!cfg.pin_shards, "no pinning by default");
        cfg.apply_overrides(&["adaptive_shards=true".into(), "pin_shards=true".into()]).unwrap();
        assert!(cfg.adaptive_shards);
        assert!(cfg.pin_shards);
        // Anything that pins the shard layout conflicts with the
        // adaptive sizer, loudly.
        let err = cfg.apply_overrides(&["shards=3".into()]).unwrap_err().to_string();
        assert!(err.contains("adaptive_shards") && err.contains("shards=3"), "{err}");
        cfg.shards = 0;
        let err = cfg.apply_overrides(&["shard_tiers=2x2".into()]).unwrap_err().to_string();
        assert!(err.contains("shard_tiers"), "{err}");
        cfg.shard_tiers.clear();
        let err = cfg.apply_overrides(&["relay_children=2".into()]).unwrap_err().to_string();
        assert!(err.contains("relay_children"), "{err}");
        cfg.relay_children = 0;
        // Pinning with both pools explicitly single-threaded is an
        // error; auto (0) or >1 on either pool is fine.
        let err = cfg
            .apply_overrides(&["parallelism=1".into(), "reduce_parallelism=1".into()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("pin_shards"), "{err}");
        cfg.apply_overrides(&["parallelism=0".into(), "reduce_parallelism=1".into()]).unwrap();
        cfg.apply_overrides(&["adaptive_shards=false".into(), "pin_shards=false".into()])
            .unwrap();
        // JSON path accepts the same keys and runs the same validation.
        let json = CFG.replace(
            "\"eval_every\": 10",
            "\"eval_every\": 10, \"adaptive_shards\": true, \"pin_shards\": true",
        );
        let v = parse(&json).unwrap();
        let cfg = TrainConfig::from_json(&v).unwrap();
        assert!(cfg.adaptive_shards && cfg.pin_shards);
        let json = CFG.replace(
            "\"eval_every\": 10",
            "\"eval_every\": 10, \"adaptive_shards\": true, \"shards\": 2",
        );
        let v = parse(&json).unwrap();
        let err = TrainConfig::from_json(&v).unwrap_err().to_string();
        assert!(err.contains("adaptive_shards"), "{err}");
    }

    #[test]
    fn all_strategy_kinds_parse() {
        for (kind, extra) in [
            ("fetchsgd", r#""k": 10, "cols": 64"#),
            ("local_topk", r#""k": 10"#),
            ("fedavg", r#""local_steps": 2"#),
            ("uncompressed", r#""rho_g": 0.9"#),
            ("true_topk", r#""k": 10"#),
        ] {
            let json = format!(
                r#"{{"task":"smoke","strategy":{{"kind":"{kind}",{extra}}},
                  "rounds":1,"clients_per_round":1,"lr":"constant:0.1"}}"#
            );
            let v = parse(&json).unwrap();
            let cfg = TrainConfig::from_json(&v).unwrap();
            assert_eq!(cfg.strategy.name(), kind);
        }
    }
}
