//! Configuration: typed training config, JSON config files, CLI
//! overrides (`key=value`), and the learning-rate schedules from the
//! paper's experiments.

pub mod schedule;
pub mod types;

pub use schedule::LrSchedule;
pub use types::{StrategyConfig, TrainConfig};
