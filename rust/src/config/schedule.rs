//! Learning-rate schedules.
//!
//! The paper uses a triangular schedule peaking early for CIFAR (§A.1),
//! a triangular schedule with pivot 0.2 for FEMNIST (§A.2), and linear
//! decay for PersonaChat (§A.3). When a method runs fewer rounds for
//! compression (FedAvg, uncompressed-fewer-epochs), the schedule is
//! compressed in the iteration dimension — which falls out naturally
//! from parameterizing by `progress = round / total_rounds`.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Constant { lr: f32 },
    /// Linear warmup to `peak` at `pivot` (fraction of training), then
    /// linear decay to 0.
    Triangular { peak: f32, pivot: f32 },
    /// Linear decay from `lr` to 0 over training.
    LinearDecay { lr: f32 },
}

impl LrSchedule {
    /// Learning rate at `round` of `total` rounds.
    pub fn at(&self, round: usize, total: usize) -> f32 {
        let p = if total <= 1 { 0.0 } else { round as f32 / (total - 1) as f32 };
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::Triangular { peak, pivot } => {
                let pivot = pivot.clamp(1e-6, 1.0 - 1e-6);
                if p <= pivot {
                    peak * (p / pivot)
                } else {
                    peak * (1.0 - (p - pivot) / (1.0 - pivot))
                }
            }
            LrSchedule::LinearDecay { lr } => lr * (1.0 - p),
        }
    }

    /// Parse "constant:0.1" | "triangular:0.3:0.2" | "linear:0.16".
    pub fn parse(s: &str) -> Result<LrSchedule> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["constant", lr] => Ok(LrSchedule::Constant { lr: lr.parse()? }),
            ["triangular", peak, pivot] => {
                Ok(LrSchedule::Triangular { peak: peak.parse()?, pivot: pivot.parse()? })
            }
            ["linear", lr] => Ok(LrSchedule::LinearDecay { lr: lr.parse()? }),
            _ => bail!("bad lr schedule '{s}' (constant:LR | triangular:PEAK:PIVOT | linear:LR)"),
        }
    }

    pub fn describe(&self) -> String {
        match *self {
            LrSchedule::Constant { lr } => format!("constant:{lr}"),
            LrSchedule::Triangular { peak, pivot } => format!("triangular:{peak}:{pivot}"),
            LrSchedule::LinearDecay { lr } => format!("linear:{lr}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn triangular_shape() {
        let s = LrSchedule::Triangular { peak: 1.0, pivot: 0.25 };
        assert_eq!(s.at(0, 101), 0.0);
        assert!((s.at(25, 101) - 1.0).abs() < 1e-5);
        assert!((s.at(100, 101) - 0.0).abs() < 1e-5);
        // monotone up then down
        assert!(s.at(10, 101) < s.at(20, 101));
        assert!(s.at(50, 101) > s.at(90, 101));
    }

    #[test]
    fn schedule_compresses_with_fewer_rounds() {
        // FedAvg at 2x compression runs half the rounds; the peak must
        // still occur at the same *fraction*.
        let s = LrSchedule::Triangular { peak: 0.3, pivot: 0.2 };
        let peak_round = |total: usize| {
            (0..total)
                .max_by(|&a, &b| s.at(a, total).partial_cmp(&s.at(b, total)).unwrap())
                .unwrap() as f64
                / total as f64
        };
        assert!((peak_round(100) - 0.2).abs() < 0.05);
        assert!((peak_round(50) - 0.2).abs() < 0.05);
    }

    #[test]
    fn linear_decay() {
        let s = LrSchedule::LinearDecay { lr: 0.16 };
        assert!((s.at(0, 11) - 0.16).abs() < 1e-6);
        assert!((s.at(10, 11) - 0.0).abs() < 1e-6);
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["constant:0.1", "triangular:0.3:0.2", "linear:0.16"] {
            let sched = LrSchedule::parse(s).unwrap();
            assert_eq!(LrSchedule::parse(&sched.describe()).unwrap(), sched);
        }
        assert!(LrSchedule::parse("bogus").is_err());
    }
}
