//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with mean/stddev/percentiles and an
//! aligned table printer. Used by `benches/*.rs` (cargo bench targets
//! with `harness = false`) and by the performance pass recorded in
//! EXPERIMENTS.md §Perf.

use std::time::Instant;

use crate::util::stats::{mean, percentile, stddev};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    /// Optional throughput denominator (elements processed per iter).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean_s)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
/// `f` should return some value to keep the optimizer honest; its result
/// is black-boxed.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        std_s: stddev(&samples),
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        elements: None,
    }
}

/// Like [`bench`] but records a throughput denominator.
pub fn bench_throughput<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    elements: u64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.elements = Some(elements);
    r
}

/// Pretty time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Print results as an aligned table, with optional throughput column.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>16}",
        "case", "mean", "p50", "p95", "throughput"
    );
    for r in results {
        let tp = match r.throughput() {
            Some(t) if t >= 1e9 => format!("{:.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("{:.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("{:.2} K/s", t / 1e3),
            Some(t) => format!("{t:.2} /s"),
            None => "-".to_string(),
        };
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}",
            r.name,
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p95_s),
            tp
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || (0..1000).sum::<u64>());
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn throughput_computed() {
        let r = bench_throughput("t", 1, 5, 1_000_000, || 1 + 1);
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
