//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with mean/stddev/percentiles, an
//! aligned table printer, and a machine-readable JSON emitter
//! ([`write_json_suite`], enabled by the `BENCH_JSON` env var) whose
//! output is committed as the `BENCH_*.json` baselines. Used by
//! `benches/*.rs` (cargo bench targets with `harness = false`) and by
//! the performance pass recorded in EXPERIMENTS.md §Perf.

use std::path::Path;
use std::time::Instant;

use crate::serialize::json::{arr, num, obj, parse, s, Value};
use crate::util::stats::{mean, percentile, stddev};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    /// Optional throughput denominator (elements processed per iter).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.mean_s)
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup calls.
/// `f` should return some value to keep the optimizer honest; its result
/// is black-boxed.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean(&samples),
        std_s: stddev(&samples),
        p50_s: percentile(&samples, 50.0),
        p95_s: percentile(&samples, 95.0),
        elements: None,
    }
}

/// Like [`bench`] but records a throughput denominator.
pub fn bench_throughput<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    elements: u64,
    f: impl FnMut() -> T,
) -> BenchResult {
    let mut r = bench(name, warmup, iters, f);
    r.elements = Some(elements);
    r
}

/// Pretty time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Print results as an aligned table, with optional throughput column.
pub fn print_table(title: &str, results: &[BenchResult]) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>12} {:>12} {:>12} {:>16}",
        "case", "mean", "p50", "p95", "throughput"
    );
    for r in results {
        let tp = match r.throughput() {
            Some(t) if t >= 1e9 => format!("{:.2} G/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("{:.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("{:.2} K/s", t / 1e3),
            Some(t) => format!("{t:.2} /s"),
            None => "-".to_string(),
        };
        println!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}",
            r.name,
            fmt_time(r.mean_s),
            fmt_time(r.p50_s),
            fmt_time(r.p95_s),
            tp
        );
    }
}

/// Emit `results` as one named suite in the JSON results file named by
/// the `BENCH_JSON` env var; no-op when the var is unset. The file is
/// read-modify-written so each bench binary contributes its own suite
/// and a re-run replaces a suite in place — regenerating a committed
/// `BENCH_N.json` is just running every bench with the same
/// `BENCH_JSON` path (see `benches/README.md`).
///
/// Schema:
/// `{"suites": [{"suite": <name>, "results": [{"name", "iters",
/// "mean_ns", "p50_ns", "p95_ns", "elements"?, "throughput_per_s"?},
/// ...]}]}`
pub fn write_json_suite(suite: &str, results: &[BenchResult]) {
    let Ok(path) = std::env::var("BENCH_JSON") else { return };
    if path.is_empty() {
        return;
    }
    if let Err(e) = write_json_suite_to(Path::new(&path), suite, results) {
        eprintln!("bench_util: writing {path} failed: {e:#}");
    }
}

fn write_json_suite_to(path: &Path, suite: &str, results: &[BenchResult]) -> anyhow::Result<()> {
    let mut suites: Vec<Value> = match std::fs::read_to_string(path) {
        Ok(text) => parse(&text)?
            .get("suites")
            .and_then(|v| v.as_array())
            .map(<[Value]>::to_vec)
            .unwrap_or_default(),
        Err(_) => Vec::new(),
    };
    suites.retain(|v| v.get("suite").and_then(Value::as_str) != Some(suite));
    let entries: Vec<Value> = results
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("name", s(&r.name)),
                ("iters", num(r.iters as f64)),
                ("mean_ns", num(r.mean_s * 1e9)),
                ("p50_ns", num(r.p50_s * 1e9)),
                ("p95_ns", num(r.p95_s * 1e9)),
            ];
            if let Some(e) = r.elements {
                fields.push(("elements", num(e as f64)));
            }
            if let Some(t) = r.throughput() {
                fields.push(("throughput_per_s", num(t)));
            }
            obj(fields)
        })
        .collect();
    suites.push(obj(vec![("suite", s(suite)), ("results", arr(entries))]));
    let doc = obj(vec![("suites", arr(suites))]);
    std::fs::write(path, doc.to_json() + "\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 2, 10, || (0..1000).sum::<u64>());
        assert_eq!(r.iters, 10);
        assert!(r.mean_s >= 0.0);
        assert!(r.p95_s >= r.p50_s);
    }

    #[test]
    fn throughput_computed() {
        let r = bench_throughput("t", 1, 5, 1_000_000, || 1 + 1);
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn json_suites_round_trip_and_replace_in_place() {
        let dir = std::env::temp_dir().join(format!("fsgd_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bench.json");
        let r1 = BenchResult {
            name: "case a".into(),
            iters: 5,
            mean_s: 2e-6,
            std_s: 1e-7,
            p50_s: 2e-6,
            p95_s: 3e-6,
            elements: Some(1000),
        };
        write_json_suite_to(&p, "alpha", std::slice::from_ref(&r1)).unwrap();
        write_json_suite_to(&p, "beta", &[]).unwrap();
        // Re-writing a suite replaces it instead of appending.
        write_json_suite_to(&p, "alpha", std::slice::from_ref(&r1)).unwrap();
        let doc = parse(&std::fs::read_to_string(&p).unwrap()).unwrap();
        let suites = doc.req_array("suites").unwrap();
        assert_eq!(suites.len(), 2);
        let alpha = suites
            .iter()
            .find(|v| v.get("suite").and_then(Value::as_str) == Some("alpha"))
            .unwrap();
        let results = alpha.req_array("results").unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].req_str("name").unwrap(), "case a");
        assert!((results[0].req_f64("mean_ns").unwrap() - 2000.0).abs() < 1e-6);
        assert!((results[0].req_f64("elements").unwrap() - 1000.0).abs() < 1e-9);
        assert!(results[0].req_f64("throughput_per_s").unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
