//! Length-prefixed message framing.
//!
//! Every transport message is `u32-le length ‖ body`. The length is
//! validated against an explicit cap *before* any allocation, so a
//! forged or corrupt prefix (e.g. `0xFFFF_FFFF`) is a loud protocol
//! error, never a multi-gigabyte allocation or a wedged read. Reads
//! inherit the socket's read deadline ([`crate::transport::Conn`]):
//! a peer that stalls mid-message surfaces as a timed-out I/O error.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::time::Instant;

/// Bytes of the `u32` little-endian length prefix.
pub const LEN_PREFIX_BYTES: u64 = 4;

/// Default cap on a single message body. Generous for any realistic
/// frame (a 64 MiB dense f32 payload is a 16M-parameter model) while
/// keeping forged prefixes cheap to reject.
pub const DEFAULT_MAX_MSG_BYTES: usize = 64 << 20;

/// Write one length-prefixed message. Returns total bytes put on the
/// wire (prefix + body).
pub fn write_msg<W: Write>(w: &mut W, msg: &[u8]) -> Result<u64> {
    let len = u32::try_from(msg.len()).context("message too large for a u32 length prefix")?;
    w.write_all(&len.to_le_bytes()).context("writing length prefix")?;
    w.write_all(msg).context("writing message body")?;
    w.flush().context("flushing message")?;
    Ok(LEN_PREFIX_BYTES + msg.len() as u64)
}

/// Write one length-prefixed message whose body is `head ‖ tail`
/// without concatenating them first — the server's round-start path
/// uses this to share one weights-frame buffer across all workers
/// instead of cloning a whole-model byte vector per connection.
pub fn write_msg_parts<W: Write>(w: &mut W, head: &[u8], tail: &[u8]) -> Result<u64> {
    let total = head.len() + tail.len();
    let len = u32::try_from(total).context("message too large for a u32 length prefix")?;
    w.write_all(&len.to_le_bytes()).context("writing length prefix")?;
    w.write_all(head).context("writing message head")?;
    w.write_all(tail).context("writing message tail")?;
    w.flush().context("flushing message")?;
    Ok(LEN_PREFIX_BYTES + total as u64)
}

/// Read one length-prefixed message, rejecting bodies over `max_msg`
/// bytes. Returns the body and the total bytes consumed off the wire.
pub fn read_msg<R: Read>(r: &mut R, max_msg: usize) -> Result<(Vec<u8>, u64)> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix).context("reading length prefix")?;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max_msg {
        bail!("length prefix claims {len} bytes, over the {max_msg}-byte message cap");
    }
    if len == 0 {
        bail!("zero-length transport message");
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).with_context(|| format!("reading {len}-byte message body"))?;
    Ok((body, LEN_PREFIX_BYTES + len as u64))
}

/// [`read_msg`] with the wait split out for tracing: returns
/// `(body, wire_bytes, stall_us, read_us)` where `stall_us` is the time
/// blocked until the length prefix completed (the peer hadn't sent yet)
/// and `read_us` the time consuming the body (actual transfer). Costs
/// three clock reads per message; the transport drivers call it only
/// while a trace sink is attached, keeping the untraced hot path
/// syscall-identical to [`read_msg`].
pub fn read_msg_timed<R: Read>(r: &mut R, max_msg: usize) -> Result<(Vec<u8>, u64, u64, u64)> {
    let t0 = Instant::now();
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix).context("reading length prefix")?;
    let stall_us = t0.elapsed().as_micros() as u64;
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max_msg {
        bail!("length prefix claims {len} bytes, over the {max_msg}-byte message cap");
    }
    if len == 0 {
        bail!("zero-length transport message");
    }
    let t1 = Instant::now();
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).with_context(|| format!("reading {len}-byte message body"))?;
    let read_us = t1.elapsed().as_micros() as u64;
    Ok((body, LEN_PREFIX_BYTES + len as u64, stall_us, read_us))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_and_byte_accounting() {
        let mut buf = Vec::new();
        let n1 = write_msg(&mut buf, b"hello").unwrap();
        let n2 = write_msg(&mut buf, &[7u8; 300]).unwrap();
        assert_eq!(n1, 9);
        assert_eq!(n2, 304);
        assert_eq!(buf.len() as u64, n1 + n2);
        let mut r = Cursor::new(buf);
        let (m1, c1) = read_msg(&mut r, 1024).unwrap();
        assert_eq!((m1.as_slice(), c1), (b"hello".as_slice(), 9));
        let (m2, c2) = read_msg(&mut r, 1024).unwrap();
        assert_eq!((m2.len(), c2), (300, 304));
    }

    #[test]
    fn split_write_is_indistinguishable_from_whole_write() {
        let (head, tail) = (&[1u8, 2, 3][..], &[4u8, 5][..]);
        let mut whole = Vec::new();
        let n1 = write_msg(&mut whole, &[head, tail].concat()).unwrap();
        let mut split = Vec::new();
        let n2 = write_msg_parts(&mut split, head, tail).unwrap();
        assert_eq!(whole, split);
        assert_eq!(n1, n2);
        let (body, _) = read_msg(&mut Cursor::new(split), 1024).unwrap();
        assert_eq!(body, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn timed_read_matches_untimed_read() {
        let mut buf = Vec::new();
        write_msg(&mut buf, b"payload").unwrap();
        let (body, n, _stall, _read) = read_msg_timed(&mut Cursor::new(&buf), 1024).unwrap();
        assert_eq!(body, b"payload");
        assert_eq!(n, 11);
        // Same validation as the untimed path: oversize and zero-length
        // prefixes are rejected before allocation.
        let mut forged = u32::MAX.to_le_bytes().to_vec();
        forged.extend_from_slice(&[0; 8]);
        assert!(read_msg_timed(&mut Cursor::new(forged), 1024).is_err());
        assert!(read_msg_timed(&mut Cursor::new(0u32.to_le_bytes().to_vec()), 1024).is_err());
    }

    #[test]
    fn oversize_prefix_is_rejected_before_allocation() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let err = read_msg(&mut Cursor::new(buf), 1024).unwrap_err().to_string();
        assert!(err.contains("message cap"), "{err}");
    }

    #[test]
    fn truncation_and_empty_messages_fail() {
        // Body shorter than the prefix claims → read error, not a hang.
        let mut buf = Vec::new();
        buf.extend_from_slice(&100u32.to_le_bytes());
        buf.extend_from_slice(&[1, 2, 3]);
        assert!(read_msg(&mut Cursor::new(buf), 1024).is_err());
        // Zero-length messages are a protocol error.
        let buf = 0u32.to_le_bytes().to_vec();
        assert!(read_msg(&mut Cursor::new(buf), 1024).is_err());
        // Truncated prefix itself.
        assert!(read_msg(&mut Cursor::new(vec![1u8, 2]), 1024).is_err());
    }
}
