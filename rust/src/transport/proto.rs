//! The transport control grammar.
//!
//! Each length-prefixed message ([`crate::transport::framing`]) is one
//! tag byte followed by a tag-specific body. `FSGW` payload frames
//! (`crate::wire`) travel *inside* these messages verbatim — the
//! transport never re-encodes values, so the bytes the accumulator
//! absorbs are exactly the bytes the client produced.
//!
//! | tag | message     | body (all integers little-endian)                       |
//! |-----|-------------|---------------------------------------------------------|
//! | 1   | Hello       | `proto_version u8`                                      |
//! | 2   | RoundStart  | `round u64, round_seed u64, lr f32, codec_id u8, n u32, (slot u32, client u32)×n, weights frame…` |
//! | 3   | Upload      | `slot u32, loss f32, upload frame…`                     |
//! | 4   | RoundEnd    | `round u64, update frame…`                              |
//! | 5   | Abort       | `utf-8 reason…`                                         |
//! | 6   | Shutdown    | (empty)                                                 |
//! | 7   | SlotAssign  | `slot u32, client u32`                                  |
//!
//! Versioning: [`PROTO_VERSION`] is exchanged in `Hello` and bumped on
//! any change to this table (v2 added `SlotAssign`, the mid-round
//! retry/reassignment of a faulted worker's slot); servers drop peers
//! speaking another version. The `FSGW` frame grammar versions
//! independently (its own header byte).

use anyhow::{bail, Context, Result};

/// Transport protocol version (`Hello` handshake).
pub const PROTO_VERSION: u8 = 2;

const TAG_HELLO: u8 = 1;
const TAG_ROUND_START: u8 = 2;
const TAG_UPLOAD: u8 = 3;
const TAG_ROUND_END: u8 = 4;
const TAG_ABORT: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;
const TAG_SLOT_ASSIGN: u8 = 7;

/// One transport control message.
pub enum Msg {
    /// Client → server greeting (protocol version check).
    Hello { version: u8 },
    /// Server → client: this round's assignments. `assignments` pairs
    /// `(slot, client_id)`; `weights_frame` is the current model as a
    /// dense `FSGW` frame (always lossless `f32le`); `codec_id` names
    /// the codec clients must encode uploads with.
    RoundStart {
        round: u64,
        round_seed: u64,
        lr: f32,
        codec_id: u8,
        assignments: Vec<(u32, u32)>,
        weights_frame: Vec<u8>,
    },
    /// Client → server: one slot's upload frame plus its training loss
    /// (loss travels as raw f32 bits — bitwise exact).
    Upload { slot: u32, loss: f32, frame: Vec<u8> },
    /// Server → every client: the round's broadcast update frame.
    RoundEnd { round: u64, update_frame: Vec<u8> },
    /// Server → client: the round failed; the connection is done.
    Abort { reason: String },
    /// Server → client: training is over, disconnect cleanly.
    Shutdown,
    /// Server → client, mid-round: compute one additional slot — the
    /// retry/reassignment of a slot whose original worker faulted or
    /// disconnected. Uses the most recent `RoundStart`'s weights,
    /// round seed, lr, and codec; the client answers with a normal
    /// `Upload` for the slot.
    SlotAssign { slot: u32, client: u32 },
}

impl Msg {
    /// Short name for error messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::RoundStart { .. } => "round-start",
            Msg::Upload { .. } => "upload",
            Msg::RoundEnd { .. } => "round-end",
            Msg::Abort { .. } => "abort",
            Msg::Shutdown => "shutdown",
            Msg::SlotAssign { .. } => "slot-assign",
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        match self {
            Msg::Hello { version } => vec![TAG_HELLO, *version],
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                let mut out = Vec::with_capacity(26 + 8 * assignments.len() + weights_frame.len());
                out.push(TAG_ROUND_START);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&round_seed.to_le_bytes());
                out.extend_from_slice(&lr.to_le_bytes());
                out.push(*codec_id);
                out.extend_from_slice(&(assignments.len() as u32).to_le_bytes());
                for &(slot, client) in assignments {
                    out.extend_from_slice(&slot.to_le_bytes());
                    out.extend_from_slice(&client.to_le_bytes());
                }
                out.extend_from_slice(weights_frame);
                out
            }
            Msg::Upload { slot, loss, frame } => {
                let mut out = Vec::with_capacity(9 + frame.len());
                out.push(TAG_UPLOAD);
                out.extend_from_slice(&slot.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(frame);
                out
            }
            Msg::RoundEnd { round, update_frame } => {
                let mut out = Vec::with_capacity(9 + update_frame.len());
                out.push(TAG_ROUND_END);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(update_frame);
                out
            }
            Msg::Abort { reason } => {
                let mut out = Vec::with_capacity(1 + reason.len());
                out.push(TAG_ABORT);
                out.extend_from_slice(reason.as_bytes());
                out
            }
            Msg::Shutdown => vec![TAG_SHUTDOWN],
            Msg::SlotAssign { slot, client } => {
                let mut out = Vec::with_capacity(9);
                out.push(TAG_SLOT_ASSIGN);
                out.extend_from_slice(&slot.to_le_bytes());
                out.extend_from_slice(&client.to_le_bytes());
                out
            }
        }
    }

    /// Decode a message body. Consumes the buffer so frame payloads are
    /// split off without copying. Every length is validated before any
    /// indexing — malformed bytes error, never panic.
    pub fn decode(mut bytes: Vec<u8>) -> Result<Msg> {
        let Some(&tag) = bytes.first() else {
            bail!("empty transport message");
        };
        match tag {
            TAG_HELLO => {
                if bytes.len() != 2 {
                    bail!("hello message must be exactly 2 bytes, got {}", bytes.len());
                }
                Ok(Msg::Hello { version: bytes[1] })
            }
            TAG_ROUND_START => {
                const FIXED: usize = 1 + 8 + 8 + 4 + 1 + 4;
                if bytes.len() < FIXED {
                    bail!("round-start message truncated at {} bytes", bytes.len());
                }
                let round = u64::from_le_bytes(bytes[1..9].try_into().unwrap());
                let round_seed = u64::from_le_bytes(bytes[9..17].try_into().unwrap());
                let lr = f32::from_le_bytes(bytes[17..21].try_into().unwrap());
                let codec_id = bytes[21];
                let n = u32::from_le_bytes(bytes[22..26].try_into().unwrap()) as usize;
                let table = 8usize
                    .checked_mul(n)
                    .and_then(|t| t.checked_add(FIXED))
                    .context("round-start assignment count overflows")?;
                if bytes.len() < table {
                    bail!("round-start claims {n} assignments but is {} bytes", bytes.len());
                }
                let mut assignments = Vec::with_capacity(n);
                for i in 0..n {
                    let at = FIXED + 8 * i;
                    assignments.push((
                        u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()),
                        u32::from_le_bytes(bytes[at + 4..at + 8].try_into().unwrap()),
                    ));
                }
                let weights_frame = bytes.split_off(table);
                if weights_frame.is_empty() {
                    bail!("round-start message carries no weights frame");
                }
                Ok(Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame })
            }
            TAG_UPLOAD => {
                const FIXED: usize = 1 + 4 + 4;
                if bytes.len() <= FIXED {
                    bail!("upload message of {} bytes carries no frame", bytes.len());
                }
                let slot = u32::from_le_bytes(bytes[1..5].try_into().unwrap());
                let loss = f32::from_le_bytes(bytes[5..9].try_into().unwrap());
                let frame = bytes.split_off(FIXED);
                Ok(Msg::Upload { slot, loss, frame })
            }
            TAG_ROUND_END => {
                const FIXED: usize = 1 + 8;
                if bytes.len() <= FIXED {
                    bail!("round-end message of {} bytes carries no frame", bytes.len());
                }
                let round = u64::from_le_bytes(bytes[1..9].try_into().unwrap());
                let update_frame = bytes.split_off(FIXED);
                Ok(Msg::RoundEnd { round, update_frame })
            }
            TAG_ABORT => {
                let reason = String::from_utf8_lossy(&bytes[1..]).into_owned();
                Ok(Msg::Abort { reason })
            }
            TAG_SHUTDOWN => {
                if bytes.len() != 1 {
                    bail!("shutdown message must be exactly 1 byte, got {}", bytes.len());
                }
                Ok(Msg::Shutdown)
            }
            TAG_SLOT_ASSIGN => {
                if bytes.len() != 9 {
                    bail!("slot-assign message must be exactly 9 bytes, got {}", bytes.len());
                }
                Ok(Msg::SlotAssign {
                    slot: u32::from_le_bytes(bytes[1..5].try_into().unwrap()),
                    client: u32::from_le_bytes(bytes[5..9].try_into().unwrap()),
                })
            }
            other => bail!("unknown transport message tag {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) -> Msg {
        Msg::decode(msg.encode()).unwrap()
    }

    #[test]
    fn all_messages_roundtrip() {
        match roundtrip(Msg::Hello { version: 3 }) {
            Msg::Hello { version: 3 } => {}
            _ => panic!(),
        }
        let start = Msg::RoundStart {
            round: 7,
            round_seed: 0xDEAD_BEEF_CAFE_F00D,
            lr: 0.125,
            codec_id: 1,
            assignments: vec![(0, 42), (3, 7)],
            weights_frame: vec![9, 8, 7],
        };
        match roundtrip(start) {
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                assert_eq!(round, 7);
                assert_eq!(round_seed, 0xDEAD_BEEF_CAFE_F00D);
                assert_eq!(lr.to_bits(), 0.125f32.to_bits());
                assert_eq!(codec_id, 1);
                assert_eq!(assignments, vec![(0, 42), (3, 7)]);
                assert_eq!(weights_frame, vec![9, 8, 7]);
            }
            _ => panic!(),
        }
        match roundtrip(Msg::Upload { slot: 5, loss: -1.5, frame: vec![1, 2] }) {
            Msg::Upload { slot, loss, frame } => {
                assert_eq!((slot, frame), (5, vec![1, 2]));
                assert_eq!(loss.to_bits(), (-1.5f32).to_bits());
            }
            _ => panic!(),
        }
        match roundtrip(Msg::RoundEnd { round: 2, update_frame: vec![4] }) {
            Msg::RoundEnd { round: 2, update_frame } => assert_eq!(update_frame, vec![4]),
            _ => panic!(),
        }
        match roundtrip(Msg::Abort { reason: "bad frame".into() }) {
            Msg::Abort { reason } => assert_eq!(reason, "bad frame"),
            _ => panic!(),
        }
        assert!(matches!(roundtrip(Msg::Shutdown), Msg::Shutdown));
        match roundtrip(Msg::SlotAssign { slot: 9, client: 1234 }) {
            Msg::SlotAssign { slot, client } => assert_eq!((slot, client), (9, 1234)),
            _ => panic!(),
        }
    }

    #[test]
    fn malformed_messages_error_not_panic() {
        assert!(Msg::decode(Vec::new()).is_err());
        assert!(Msg::decode(vec![99]).is_err());
        assert!(Msg::decode(vec![TAG_HELLO]).is_err());
        assert!(Msg::decode(vec![TAG_UPLOAD, 0, 0, 0, 0]).is_err());
        assert!(Msg::decode(vec![TAG_ROUND_END, 1, 2]).is_err());
        assert!(Msg::decode(vec![TAG_SHUTDOWN, 0]).is_err());
        assert!(Msg::decode(vec![TAG_SLOT_ASSIGN, 0, 0, 0]).is_err());
        assert!(Msg::decode(vec![TAG_SLOT_ASSIGN; 11]).is_err());
        // round-start whose assignment count lies about the length
        let mut bad = Msg::RoundStart {
            round: 0,
            round_seed: 0,
            lr: 0.0,
            codec_id: 0,
            assignments: vec![(0, 0)],
            weights_frame: vec![1],
        }
        .encode();
        bad[22..26].copy_from_slice(&1000u32.to_le_bytes());
        assert!(Msg::decode(bad).is_err());
        // truncation at every prefix length must error, never panic
        let good = Msg::RoundStart {
            round: 1,
            round_seed: 2,
            lr: 0.5,
            codec_id: 0,
            assignments: vec![(1, 9)],
            weights_frame: vec![1, 2, 3, 4],
        }
        .encode();
        // Truncation anywhere before the weights frame must error,
        // never panic. (Cuts *inside* the trailing frame still decode
        // here — the FSGW parser rejects those downstream.)
        let frame_start = 26 + 8;
        for cut in 0..=frame_start {
            assert!(Msg::decode(good[..cut].to_vec()).is_err(), "prefix {cut} accepted");
        }
    }
}
