//! The transport control grammar.
//!
//! Each length-prefixed message ([`crate::transport::framing`]) is one
//! tag byte followed by a tag-specific body. `FSGW` payload frames
//! (`crate::wire`) travel *inside* these messages verbatim — the
//! transport never re-encodes values, so the bytes the accumulator
//! absorbs are exactly the bytes the client produced.
//!
//! | tag | message       | body (all integers little-endian)                       |
//! |-----|---------------|---------------------------------------------------------|
//! | 1   | Hello         | `proto_version u8`                                      |
//! | 2   | RoundStart    | `round u64, round_seed u64, lr f32, codec_id u8, n u32, (slot u32, client u32)×n, weights frame…` |
//! | 3   | Upload        | `slot u32, loss f32, upload frame…`                     |
//! | 4   | RoundEnd      | `round u64, update frame…`                              |
//! | 5   | Abort         | `utf-8 reason…`                                         |
//! | 6   | Shutdown      | (empty)                                                 |
//! | 7   | SlotAssign    | `slot u32, client u32`                                  |
//! | 8   | RelayHello    | `proto_version u8`                                      |
//! | 9   | SubtreeAssign | `round u64, round_seed u64, lr f32, codec_id u8, spec_kind u8, spec…, n u32, (slot u32, client u32, lambda f32)×n, weights frame…` |
//! | 10  | SubtreeUpload | `round u64, has_frame u8, n u32, (slot u32, outcome u8, retries u16, loss f32)×n, merged frame…` |
//!
//! `SubtreeAssign.spec` describes the round's upload shape so a relay
//! can build its own accumulator without a `ServerAggregator`:
//! `spec_kind 0` (sketch) is `rows u32, cols u32, dim u64, seed u64`;
//! `spec_kind 1` (dense) is `dim u64`. Assignment entries carry the
//! *global* slot id, the sampled client id, and that slot's aggregation
//! weight `λ` as raw f32 bits, so a relay folds downstream uploads with
//! exactly the weights the root would have used — weighted subtree sums
//! compose because the sketch (and the dense accumulator) is linear.
//!
//! `SubtreeUpload` reports every assigned slot exactly once, in
//! ascending slot order, with an `OUTCOME_*` code; the merged `FSGW`
//! frame (always lossless `f32le`) is present iff at least one slot
//! arrived (`has_frame = 1`), and covers exactly the arrived slots.
//!
//! ## v4: recursive trees, partial chains, chain re-offers
//!
//! The relay messages *nest*: the downstream side of a relay may itself
//! be a relay tier, so `RelayHello`/`SubtreeAssign`/`SubtreeUpload`
//! flow on interior links exactly as on the root link, and depth-N
//! trees compose from the same two shapes. Three semantic rules (no
//! byte-layout change) distinguish v4 from v3:
//!
//! - **Partial chains.** A relay closes its chain at its own quorum
//!   deadline and reports whatever arrived: `SubtreeUpload` is a
//!   per-slot outcome table plus a merged frame over exactly the
//!   arrived subset — never all-or-nothing. The `retries` field carries
//!   the subtree's total re-offer count for the slot so membership
//!   accounting composes across tiers.
//! - **Chain re-offers.** An upstream peer may send *more than one*
//!   `SubtreeAssign` for the same round on one connection — the
//!   mid-round re-assignment of a dead sibling's chain. A relay answers
//!   every `SubtreeAssign` with its own `SubtreeUpload`, in order.
//! - **Roll-ups.** An interior relay folds its children's merged frames
//!   (one accumulator shard per child) and concatenates their slot
//!   reports; outcome codes pass through verbatim.
//!
//! Versioning: [`PROTO_VERSION`] is exchanged in `Hello`/`RelayHello`
//! and bumped on any change to this table (v2 added `SlotAssign`, the
//! mid-round retry/reassignment of a faulted worker's slot; v3 added
//! the relay tier: `RelayHello`, `SubtreeAssign`, `SubtreeUpload`; v4
//! made the tier recursive and failure-tolerant as above — a v3 peer
//! would treat a repeated `SubtreeAssign` as a protocol error, so the
//! handshake keeps the tiers apart); servers drop peers speaking
//! another version. The `FSGW` frame grammar versions independently
//! (its own header byte).

use crate::compression::UploadSpec;
use anyhow::{bail, Context, Result};

/// Transport protocol version (`Hello`/`RelayHello` handshake).
pub const PROTO_VERSION: u8 = 4;

const TAG_HELLO: u8 = 1;
const TAG_ROUND_START: u8 = 2;
const TAG_UPLOAD: u8 = 3;
const TAG_ROUND_END: u8 = 4;
const TAG_ABORT: u8 = 5;
const TAG_SHUTDOWN: u8 = 6;
const TAG_SLOT_ASSIGN: u8 = 7;
const TAG_RELAY_HELLO: u8 = 8;
const TAG_SUBTREE_ASSIGN: u8 = 9;
const TAG_SUBTREE_UPLOAD: u8 = 10;

const SPEC_KIND_SKETCH: u8 = 0;
const SPEC_KIND_DENSE: u8 = 1;

/// `SubtreeUpload` outcome code: the slot's upload arrived and is
/// folded into the merged frame.
pub const OUTCOME_ARRIVED: u8 = 0;
/// Outcome code: dropped — the downstream peer sent garbage.
pub const OUTCOME_DROPPED_FAULTED: u8 = 1;
/// Outcome code: dropped — the downstream peer disconnected.
pub const OUTCOME_DROPPED_DISCONNECTED: u8 = 2;
/// Outcome code: dropped — the slot straggled past the round deadline.
pub const OUTCOME_DROPPED_DEADLINE: u8 = 3;

/// One rolled-up slot outcome inside a [`Msg::SubtreeUpload`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlotReport {
    /// Global slot id (as assigned by `SubtreeAssign`).
    pub slot: u32,
    /// One of the `OUTCOME_*` codes.
    pub outcome: u8,
    /// Downstream retries spent on the slot — the root merges these
    /// into its own membership accounting.
    pub retries: u16,
    /// Training loss for arrived slots (raw f32 bits — bitwise exact);
    /// 0.0 for dropped slots.
    pub loss: f32,
}

/// One transport control message.
pub enum Msg {
    /// Client → server greeting (protocol version check).
    Hello { version: u8 },
    /// Server → client: this round's assignments. `assignments` pairs
    /// `(slot, client_id)`; `weights_frame` is the current model as a
    /// dense `FSGW` frame (always lossless `f32le`); `codec_id` names
    /// the codec clients must encode uploads with.
    RoundStart {
        round: u64,
        round_seed: u64,
        lr: f32,
        codec_id: u8,
        assignments: Vec<(u32, u32)>,
        weights_frame: Vec<u8>,
    },
    /// Client → server: one slot's upload frame plus its training loss
    /// (loss travels as raw f32 bits — bitwise exact).
    Upload { slot: u32, loss: f32, frame: Vec<u8> },
    /// Server → every client: the round's broadcast update frame.
    RoundEnd { round: u64, update_frame: Vec<u8> },
    /// Server → client: the round failed; the connection is done.
    Abort { reason: String },
    /// Server → client: training is over, disconnect cleanly.
    Shutdown,
    /// Server → client, mid-round: compute one additional slot — the
    /// retry/reassignment of a slot whose original worker faulted or
    /// disconnected. Uses the most recent `RoundStart`'s weights,
    /// round seed, lr, and codec; the client answers with a normal
    /// `Upload` for the slot.
    SlotAssign { slot: u32, client: u32 },
    /// Relay → upstream server greeting: this peer is an aggregator
    /// relay, not a worker — it will answer each `SubtreeAssign` with
    /// one `SubtreeUpload` instead of per-slot `Upload`s.
    RelayHello { version: u8 },
    /// Server → relay: this round's subtree. `entries` are
    /// `(global_slot, client_id, lambda)` in ascending slot order;
    /// `spec` is the upload shape the relay must accumulate;
    /// `weights_frame` is the dense broadcast, forwarded downstream
    /// verbatim.
    SubtreeAssign {
        round: u64,
        round_seed: u64,
        lr: f32,
        codec_id: u8,
        spec: UploadSpec,
        entries: Vec<(u32, u32, f32)>,
        weights_frame: Vec<u8>,
    },
    /// Relay → server: the subtree's rolled-up round result. `reports`
    /// cover every assigned slot exactly once, in ascending slot order;
    /// `frame` is the λ-weighted merged `FSGW` frame over exactly the
    /// arrived slots (empty iff none arrived).
    SubtreeUpload { round: u64, reports: Vec<SlotReport>, frame: Vec<u8> },
}

impl Msg {
    /// Short name for error messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "hello",
            Msg::RoundStart { .. } => "round-start",
            Msg::Upload { .. } => "upload",
            Msg::RoundEnd { .. } => "round-end",
            Msg::Abort { .. } => "abort",
            Msg::Shutdown => "shutdown",
            Msg::SlotAssign { .. } => "slot-assign",
            Msg::RelayHello { .. } => "relay-hello",
            Msg::SubtreeAssign { .. } => "subtree-assign",
            Msg::SubtreeUpload { .. } => "subtree-upload",
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        match self {
            Msg::Hello { version } => vec![TAG_HELLO, *version],
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                let mut out = Vec::with_capacity(26 + 8 * assignments.len() + weights_frame.len());
                out.push(TAG_ROUND_START);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&round_seed.to_le_bytes());
                out.extend_from_slice(&lr.to_le_bytes());
                out.push(*codec_id);
                out.extend_from_slice(&(assignments.len() as u32).to_le_bytes());
                for &(slot, client) in assignments {
                    out.extend_from_slice(&slot.to_le_bytes());
                    out.extend_from_slice(&client.to_le_bytes());
                }
                out.extend_from_slice(weights_frame);
                out
            }
            Msg::Upload { slot, loss, frame } => {
                let mut out = Vec::with_capacity(9 + frame.len());
                out.push(TAG_UPLOAD);
                out.extend_from_slice(&slot.to_le_bytes());
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(frame);
                out
            }
            Msg::RoundEnd { round, update_frame } => {
                let mut out = Vec::with_capacity(9 + update_frame.len());
                out.push(TAG_ROUND_END);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(update_frame);
                out
            }
            Msg::Abort { reason } => {
                let mut out = Vec::with_capacity(1 + reason.len());
                out.push(TAG_ABORT);
                out.extend_from_slice(reason.as_bytes());
                out
            }
            Msg::Shutdown => vec![TAG_SHUTDOWN],
            Msg::SlotAssign { slot, client } => {
                let mut out = Vec::with_capacity(9);
                out.push(TAG_SLOT_ASSIGN);
                out.extend_from_slice(&slot.to_le_bytes());
                out.extend_from_slice(&client.to_le_bytes());
                out
            }
            Msg::RelayHello { version } => vec![TAG_RELAY_HELLO, *version],
            Msg::SubtreeAssign { round, round_seed, lr, codec_id, spec, entries, weights_frame } => {
                let mut out =
                    Vec::with_capacity(51 + 12 * entries.len() + weights_frame.len());
                out.push(TAG_SUBTREE_ASSIGN);
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&round_seed.to_le_bytes());
                out.extend_from_slice(&lr.to_le_bytes());
                out.push(*codec_id);
                match spec {
                    UploadSpec::Sketch { rows, cols, dim, seed } => {
                        out.push(SPEC_KIND_SKETCH);
                        out.extend_from_slice(&(*rows as u32).to_le_bytes());
                        out.extend_from_slice(&(*cols as u32).to_le_bytes());
                        out.extend_from_slice(&(*dim as u64).to_le_bytes());
                        out.extend_from_slice(&seed.to_le_bytes());
                    }
                    UploadSpec::Dense { dim } => {
                        out.push(SPEC_KIND_DENSE);
                        out.extend_from_slice(&(*dim as u64).to_le_bytes());
                    }
                }
                out.extend_from_slice(&(entries.len() as u32).to_le_bytes());
                for &(slot, client, lambda) in entries {
                    out.extend_from_slice(&slot.to_le_bytes());
                    out.extend_from_slice(&client.to_le_bytes());
                    out.extend_from_slice(&lambda.to_le_bytes());
                }
                out.extend_from_slice(weights_frame);
                out
            }
            Msg::SubtreeUpload { round, reports, frame } => {
                let mut out = Vec::with_capacity(14 + 11 * reports.len() + frame.len());
                out.push(TAG_SUBTREE_UPLOAD);
                out.extend_from_slice(&round.to_le_bytes());
                out.push(u8::from(!frame.is_empty()));
                out.extend_from_slice(&(reports.len() as u32).to_le_bytes());
                for r in reports {
                    out.extend_from_slice(&r.slot.to_le_bytes());
                    out.push(r.outcome);
                    out.extend_from_slice(&r.retries.to_le_bytes());
                    out.extend_from_slice(&r.loss.to_le_bytes());
                }
                out.extend_from_slice(frame);
                out
            }
        }
    }

    /// Decode a message body. Consumes the buffer so frame payloads are
    /// split off without copying. Every length is validated before any
    /// indexing — malformed bytes error, never panic.
    pub fn decode(mut bytes: Vec<u8>) -> Result<Msg> {
        let Some(&tag) = bytes.first() else {
            bail!("empty transport message");
        };
        match tag {
            TAG_HELLO => {
                if bytes.len() != 2 {
                    bail!("hello message must be exactly 2 bytes, got {}", bytes.len());
                }
                Ok(Msg::Hello { version: bytes[1] })
            }
            TAG_ROUND_START => {
                const FIXED: usize = 1 + 8 + 8 + 4 + 1 + 4;
                if bytes.len() < FIXED {
                    bail!("round-start message truncated at {} bytes", bytes.len());
                }
                let round = u64::from_le_bytes(bytes[1..9].try_into().unwrap());
                let round_seed = u64::from_le_bytes(bytes[9..17].try_into().unwrap());
                let lr = f32::from_le_bytes(bytes[17..21].try_into().unwrap());
                let codec_id = bytes[21];
                let n = u32::from_le_bytes(bytes[22..26].try_into().unwrap()) as usize;
                let table = 8usize
                    .checked_mul(n)
                    .and_then(|t| t.checked_add(FIXED))
                    .context("round-start assignment count overflows")?;
                if bytes.len() < table {
                    bail!("round-start claims {n} assignments but is {} bytes", bytes.len());
                }
                let mut assignments = Vec::with_capacity(n);
                for i in 0..n {
                    let at = FIXED + 8 * i;
                    assignments.push((
                        u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()),
                        u32::from_le_bytes(bytes[at + 4..at + 8].try_into().unwrap()),
                    ));
                }
                let weights_frame = bytes.split_off(table);
                if weights_frame.is_empty() {
                    bail!("round-start message carries no weights frame");
                }
                Ok(Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame })
            }
            TAG_UPLOAD => {
                const FIXED: usize = 1 + 4 + 4;
                if bytes.len() <= FIXED {
                    bail!("upload message of {} bytes carries no frame", bytes.len());
                }
                let slot = u32::from_le_bytes(bytes[1..5].try_into().unwrap());
                let loss = f32::from_le_bytes(bytes[5..9].try_into().unwrap());
                let frame = bytes.split_off(FIXED);
                Ok(Msg::Upload { slot, loss, frame })
            }
            TAG_ROUND_END => {
                const FIXED: usize = 1 + 8;
                if bytes.len() <= FIXED {
                    bail!("round-end message of {} bytes carries no frame", bytes.len());
                }
                let round = u64::from_le_bytes(bytes[1..9].try_into().unwrap());
                let update_frame = bytes.split_off(FIXED);
                Ok(Msg::RoundEnd { round, update_frame })
            }
            TAG_ABORT => {
                let reason = String::from_utf8_lossy(&bytes[1..]).into_owned();
                Ok(Msg::Abort { reason })
            }
            TAG_SHUTDOWN => {
                if bytes.len() != 1 {
                    bail!("shutdown message must be exactly 1 byte, got {}", bytes.len());
                }
                Ok(Msg::Shutdown)
            }
            TAG_SLOT_ASSIGN => {
                if bytes.len() != 9 {
                    bail!("slot-assign message must be exactly 9 bytes, got {}", bytes.len());
                }
                Ok(Msg::SlotAssign {
                    slot: u32::from_le_bytes(bytes[1..5].try_into().unwrap()),
                    client: u32::from_le_bytes(bytes[5..9].try_into().unwrap()),
                })
            }
            TAG_RELAY_HELLO => {
                if bytes.len() != 2 {
                    bail!("relay-hello message must be exactly 2 bytes, got {}", bytes.len());
                }
                Ok(Msg::RelayHello { version: bytes[1] })
            }
            TAG_SUBTREE_ASSIGN => {
                const FIXED: usize = 1 + 8 + 8 + 4 + 1 + 1;
                if bytes.len() < FIXED {
                    bail!("subtree-assign message truncated at {} bytes", bytes.len());
                }
                let round = u64::from_le_bytes(bytes[1..9].try_into().unwrap());
                let round_seed = u64::from_le_bytes(bytes[9..17].try_into().unwrap());
                let lr = f32::from_le_bytes(bytes[17..21].try_into().unwrap());
                let codec_id = bytes[21];
                let spec_len = match bytes[22] {
                    SPEC_KIND_SKETCH => 24,
                    SPEC_KIND_DENSE => 8,
                    other => bail!("unknown subtree-assign spec kind {other}"),
                };
                let count_at = FIXED + spec_len;
                if bytes.len() < count_at + 4 {
                    bail!("subtree-assign message truncated at {} bytes", bytes.len());
                }
                let spec = if bytes[22] == SPEC_KIND_SKETCH {
                    UploadSpec::Sketch {
                        rows: u32::from_le_bytes(bytes[23..27].try_into().unwrap()) as usize,
                        cols: u32::from_le_bytes(bytes[27..31].try_into().unwrap()) as usize,
                        dim: u64::from_le_bytes(bytes[31..39].try_into().unwrap()) as usize,
                        seed: u64::from_le_bytes(bytes[39..47].try_into().unwrap()),
                    }
                } else {
                    UploadSpec::Dense {
                        dim: u64::from_le_bytes(bytes[23..31].try_into().unwrap()) as usize,
                    }
                };
                let n = u32::from_le_bytes(bytes[count_at..count_at + 4].try_into().unwrap())
                    as usize;
                let table = 12usize
                    .checked_mul(n)
                    .and_then(|t| t.checked_add(count_at + 4))
                    .context("subtree-assign entry count overflows")?;
                if bytes.len() < table {
                    bail!("subtree-assign claims {n} entries but is {} bytes", bytes.len());
                }
                let mut entries = Vec::with_capacity(n);
                for i in 0..n {
                    let at = count_at + 4 + 12 * i;
                    entries.push((
                        u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()),
                        u32::from_le_bytes(bytes[at + 4..at + 8].try_into().unwrap()),
                        f32::from_le_bytes(bytes[at + 8..at + 12].try_into().unwrap()),
                    ));
                }
                let weights_frame = bytes.split_off(table);
                if weights_frame.is_empty() {
                    bail!("subtree-assign message carries no weights frame");
                }
                Ok(Msg::SubtreeAssign {
                    round,
                    round_seed,
                    lr,
                    codec_id,
                    spec,
                    entries,
                    weights_frame,
                })
            }
            TAG_SUBTREE_UPLOAD => {
                const FIXED: usize = 1 + 8 + 1 + 4;
                if bytes.len() < FIXED {
                    bail!("subtree-upload message truncated at {} bytes", bytes.len());
                }
                let round = u64::from_le_bytes(bytes[1..9].try_into().unwrap());
                let has_frame = match bytes[9] {
                    0 => false,
                    1 => true,
                    other => bail!("subtree-upload frame flag must be 0 or 1, got {other}"),
                };
                let n = u32::from_le_bytes(bytes[10..14].try_into().unwrap()) as usize;
                let table = 11usize
                    .checked_mul(n)
                    .and_then(|t| t.checked_add(FIXED))
                    .context("subtree-upload report count overflows")?;
                if bytes.len() < table {
                    bail!("subtree-upload claims {n} reports but is {} bytes", bytes.len());
                }
                let mut reports = Vec::with_capacity(n);
                for i in 0..n {
                    let at = FIXED + 11 * i;
                    reports.push(SlotReport {
                        slot: u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()),
                        outcome: bytes[at + 4],
                        retries: u16::from_le_bytes(bytes[at + 5..at + 7].try_into().unwrap()),
                        loss: f32::from_le_bytes(bytes[at + 7..at + 11].try_into().unwrap()),
                    });
                }
                let frame = bytes.split_off(table);
                if has_frame && frame.is_empty() {
                    bail!("subtree-upload declares a merged frame but carries none");
                }
                if !has_frame && !frame.is_empty() {
                    bail!(
                        "subtree-upload declares no merged frame but carries {} bytes",
                        frame.len()
                    );
                }
                Ok(Msg::SubtreeUpload { round, reports, frame })
            }
            other => bail!("unknown transport message tag {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Msg) -> Msg {
        Msg::decode(msg.encode()).unwrap()
    }

    #[test]
    fn all_messages_roundtrip() {
        match roundtrip(Msg::Hello { version: PROTO_VERSION }) {
            Msg::Hello { version } => assert_eq!(version, PROTO_VERSION),
            _ => panic!(),
        }
        let start = Msg::RoundStart {
            round: 7,
            round_seed: 0xDEAD_BEEF_CAFE_F00D,
            lr: 0.125,
            codec_id: 1,
            assignments: vec![(0, 42), (3, 7)],
            weights_frame: vec![9, 8, 7],
        };
        match roundtrip(start) {
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                assert_eq!(round, 7);
                assert_eq!(round_seed, 0xDEAD_BEEF_CAFE_F00D);
                assert_eq!(lr.to_bits(), 0.125f32.to_bits());
                assert_eq!(codec_id, 1);
                assert_eq!(assignments, vec![(0, 42), (3, 7)]);
                assert_eq!(weights_frame, vec![9, 8, 7]);
            }
            _ => panic!(),
        }
        match roundtrip(Msg::Upload { slot: 5, loss: -1.5, frame: vec![1, 2] }) {
            Msg::Upload { slot, loss, frame } => {
                assert_eq!((slot, frame), (5, vec![1, 2]));
                assert_eq!(loss.to_bits(), (-1.5f32).to_bits());
            }
            _ => panic!(),
        }
        match roundtrip(Msg::RoundEnd { round: 2, update_frame: vec![4] }) {
            Msg::RoundEnd { round: 2, update_frame } => assert_eq!(update_frame, vec![4]),
            _ => panic!(),
        }
        match roundtrip(Msg::Abort { reason: "bad frame".into() }) {
            Msg::Abort { reason } => assert_eq!(reason, "bad frame"),
            _ => panic!(),
        }
        assert!(matches!(roundtrip(Msg::Shutdown), Msg::Shutdown));
        match roundtrip(Msg::SlotAssign { slot: 9, client: 1234 }) {
            Msg::SlotAssign { slot, client } => assert_eq!((slot, client), (9, 1234)),
            _ => panic!(),
        }
        match roundtrip(Msg::RelayHello { version: PROTO_VERSION }) {
            Msg::RelayHello { version } => assert_eq!(version, PROTO_VERSION),
            _ => panic!(),
        }
    }

    #[test]
    fn relay_messages_roundtrip() {
        let assign = Msg::SubtreeAssign {
            round: 11,
            round_seed: 0x0123_4567_89AB_CDEF,
            lr: 0.25,
            codec_id: 1,
            spec: UploadSpec::Sketch { rows: 5, cols: 1024, dim: 30_000, seed: 0xD5 },
            entries: vec![(0, 42, 1.0), (2, 7, 3.5)],
            weights_frame: vec![9, 8, 7],
        };
        match roundtrip(assign) {
            Msg::SubtreeAssign { round, round_seed, lr, codec_id, spec, entries, weights_frame } => {
                assert_eq!(round, 11);
                assert_eq!(round_seed, 0x0123_4567_89AB_CDEF);
                assert_eq!(lr.to_bits(), 0.25f32.to_bits());
                assert_eq!(codec_id, 1);
                assert_eq!(
                    spec,
                    UploadSpec::Sketch { rows: 5, cols: 1024, dim: 30_000, seed: 0xD5 }
                );
                assert_eq!(entries.len(), 2);
                assert_eq!((entries[0].0, entries[0].1), (0, 42));
                assert_eq!(entries[0].2.to_bits(), 1.0f32.to_bits());
                assert_eq!((entries[1].0, entries[1].1), (2, 7));
                assert_eq!(entries[1].2.to_bits(), 3.5f32.to_bits());
                assert_eq!(weights_frame, vec![9, 8, 7]);
            }
            _ => panic!(),
        }
        // A dense-spec assignment with an empty subtree (the relay has
        // no chain this round) still needs a weights frame.
        let empty = Msg::SubtreeAssign {
            round: 1,
            round_seed: 2,
            lr: 0.5,
            codec_id: 0,
            spec: UploadSpec::Dense { dim: 64 },
            entries: vec![],
            weights_frame: vec![1],
        };
        match roundtrip(empty) {
            Msg::SubtreeAssign { spec, entries, weights_frame, .. } => {
                assert_eq!(spec, UploadSpec::Dense { dim: 64 });
                assert!(entries.is_empty());
                assert_eq!(weights_frame, vec![1]);
            }
            _ => panic!(),
        }
        let up = Msg::SubtreeUpload {
            round: 11,
            reports: vec![
                SlotReport { slot: 0, outcome: OUTCOME_ARRIVED, retries: 0, loss: 1.5 },
                SlotReport { slot: 2, outcome: OUTCOME_DROPPED_DISCONNECTED, retries: 2, loss: 0.0 },
            ],
            frame: vec![4, 5, 6],
        };
        match roundtrip(up) {
            Msg::SubtreeUpload { round, reports, frame } => {
                assert_eq!(round, 11);
                assert_eq!(reports.len(), 2);
                assert_eq!(reports[0].slot, 0);
                assert_eq!(reports[0].outcome, OUTCOME_ARRIVED);
                assert_eq!(reports[0].loss.to_bits(), 1.5f32.to_bits());
                assert_eq!(reports[1].slot, 2);
                assert_eq!(reports[1].outcome, OUTCOME_DROPPED_DISCONNECTED);
                assert_eq!(reports[1].retries, 2);
                assert_eq!(frame, vec![4, 5, 6]);
            }
            _ => panic!(),
        }
        // Zero-participant subtree: all-dropped reports, no frame.
        let none = Msg::SubtreeUpload {
            round: 3,
            reports: vec![SlotReport {
                slot: 1,
                outcome: OUTCOME_DROPPED_FAULTED,
                retries: 0,
                loss: 0.0,
            }],
            frame: vec![],
        };
        match roundtrip(none) {
            Msg::SubtreeUpload { reports, frame, .. } => {
                assert_eq!(reports.len(), 1);
                assert!(frame.is_empty());
            }
            _ => panic!(),
        }
    }

    #[test]
    fn malformed_messages_error_not_panic() {
        assert!(Msg::decode(Vec::new()).is_err());
        assert!(Msg::decode(vec![99]).is_err());
        assert!(Msg::decode(vec![TAG_HELLO]).is_err());
        assert!(Msg::decode(vec![TAG_UPLOAD, 0, 0, 0, 0]).is_err());
        assert!(Msg::decode(vec![TAG_ROUND_END, 1, 2]).is_err());
        assert!(Msg::decode(vec![TAG_SHUTDOWN, 0]).is_err());
        assert!(Msg::decode(vec![TAG_SLOT_ASSIGN, 0, 0, 0]).is_err());
        assert!(Msg::decode(vec![TAG_SLOT_ASSIGN; 11]).is_err());
        // round-start whose assignment count lies about the length
        let mut bad = Msg::RoundStart {
            round: 0,
            round_seed: 0,
            lr: 0.0,
            codec_id: 0,
            assignments: vec![(0, 0)],
            weights_frame: vec![1],
        }
        .encode();
        bad[22..26].copy_from_slice(&1000u32.to_le_bytes());
        assert!(Msg::decode(bad).is_err());
        // truncation at every prefix length must error, never panic
        let good = Msg::RoundStart {
            round: 1,
            round_seed: 2,
            lr: 0.5,
            codec_id: 0,
            assignments: vec![(1, 9)],
            weights_frame: vec![1, 2, 3, 4],
        }
        .encode();
        // Truncation anywhere before the weights frame must error,
        // never panic. (Cuts *inside* the trailing frame still decode
        // here — the FSGW parser rejects those downstream.)
        let frame_start = 26 + 8;
        for cut in 0..=frame_start {
            assert!(Msg::decode(good[..cut].to_vec()).is_err(), "prefix {cut} accepted");
        }
    }

    #[test]
    fn malformed_relay_messages_error_not_panic() {
        assert!(Msg::decode(vec![TAG_RELAY_HELLO]).is_err());
        assert!(Msg::decode(vec![TAG_RELAY_HELLO, 3, 0]).is_err());
        // subtree-assign: truncation anywhere before the weights frame
        let good = Msg::SubtreeAssign {
            round: 1,
            round_seed: 2,
            lr: 0.5,
            codec_id: 0,
            spec: UploadSpec::Sketch { rows: 3, cols: 128, dim: 200, seed: 11 },
            entries: vec![(0, 9, 1.0)],
            weights_frame: vec![1, 2, 3, 4],
        }
        .encode();
        let frame_start = 23 + 24 + 4 + 12;
        for cut in 0..=frame_start {
            assert!(Msg::decode(good[..cut].to_vec()).is_err(), "prefix {cut} accepted");
        }
        // unknown spec kind byte
        let mut bad = good.clone();
        bad[22] = 9;
        assert!(Msg::decode(bad).is_err());
        // entry count lying about the length
        let mut bad = good.clone();
        bad[23 + 24..23 + 24 + 4].copy_from_slice(&1000u32.to_le_bytes());
        assert!(Msg::decode(bad).is_err());
        // subtree-upload: truncation through the report table
        let good = Msg::SubtreeUpload {
            round: 1,
            reports: vec![SlotReport { slot: 0, outcome: OUTCOME_ARRIVED, retries: 0, loss: 0.5 }],
            frame: vec![1, 2, 3, 4],
        }
        .encode();
        let frame_start = 14 + 11;
        for cut in 0..frame_start {
            assert!(Msg::decode(good[..cut].to_vec()).is_err(), "prefix {cut} accepted");
        }
        // exact table length with has_frame=1 but no frame bytes
        assert!(Msg::decode(good[..frame_start].to_vec()).is_err());
        // frame flag must be 0 or 1
        let mut bad = good.clone();
        bad[9] = 7;
        assert!(Msg::decode(bad).is_err());
        // has_frame=0 with trailing bytes is a violation
        let mut bad = good;
        bad[9] = 0;
        assert!(Msg::decode(bad).is_err());
        // report count lying about the length
        let mut bad = Msg::SubtreeUpload { round: 1, reports: vec![], frame: vec![] }.encode();
        bad[10..14].copy_from_slice(&7u32.to_le_bytes());
        assert!(Msg::decode(bad).is_err());
    }
}
