//! The transport client: drive any [`ClientCompute`] over a socket.
//!
//! A joined worker is the network mirror of one of the round engine's
//! worker threads: it receives the current weights and a list of
//! `(slot, client_id)` assignments each round, runs the strategy's
//! client compute for each assignment *in order* (the server relies on
//! per-connection upload order), and ships each upload frame as soon as
//! it is computed — which is what lets the server absorb streaming
//! instead of waiting for the cohort.
//!
//! Clients stay stateless across rounds (FetchSGD's whole point): the
//! model arrives fresh every `RoundStart` as a lossless dense frame, so
//! a worker can join, crash, and rejoin without any resync protocol.
//! Mid-round the server may hand over a `SlotAssign` — the
//! retry/reassignment of a slot whose original worker faulted — which
//! is computed against the same round state and uploaded like any
//! assigned slot.

use anyhow::{bail, Context, Result};
use std::time::Duration;

use crate::compression::ClientCompute;
use crate::data::FedDataset;
use crate::runtime::artifact::TaskArtifacts;
use crate::transport::framing::{read_msg, write_msg, DEFAULT_MAX_MSG_BYTES};
use crate::transport::proto::{Msg, PROTO_VERSION};
use crate::transport::{Conn, Endpoint};
use crate::wire::{codec_by_id, decode_dense_frame, decode_update, encode_upload};

/// Client knobs.
pub struct JoinOptions {
    /// Read deadline while waiting for the server (None = block; the
    /// server controls round pacing, so the default is patient).
    pub read_timeout: Option<Duration>,
    /// Per-message size cap (mirrors the server's).
    pub max_msg: usize,
    /// How many times a lost connection is re-dialed before [`join`]
    /// gives up. A connection that sees a round through to its
    /// broadcast resets the counter — the budget bounds *consecutive*
    /// failures, not lifetime ones, so a long-lived worker on a flaky
    /// link doesn't slowly exhaust it. 0 (the default) keeps the old
    /// fail-fast behavior tests rely on.
    pub reconnect_attempts: usize,
    /// Backoff before the first reconnect attempt, in milliseconds;
    /// doubles per consecutive failure, capped at 10 s.
    pub reconnect_backoff_ms: u64,
}

impl Default for JoinOptions {
    fn default() -> Self {
        JoinOptions {
            read_timeout: None,
            max_msg: DEFAULT_MAX_MSG_BYTES,
            reconnect_attempts: 0,
            reconnect_backoff_ms: 200,
        }
    }
}

/// Exponential reconnect backoff: `base · 2^(attempt-1)`, exponent
/// capped so the shift cannot overflow, the result capped at 10 s.
/// Shared with the relay tier's upstream reconnect loop.
pub(crate) fn backoff_ms(base: u64, attempt: usize) -> u64 {
    base.saturating_mul(1u64 << attempt.saturating_sub(1).min(6)).min(10_000)
}

/// What a worker did over its connection's lifetime.
#[derive(Clone, Debug, Default)]
pub struct JoinSummary {
    /// Rounds this worker saw through to the broadcast.
    pub rounds: usize,
    /// Total slot uploads sent.
    pub uploads: usize,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// The per-round state a worker keeps between `RoundStart` and
/// `RoundEnd`, so a mid-round `SlotAssign` (retry/reassignment of
/// another worker's slot) can be computed without any resync.
struct RoundState {
    round: u64,
    round_seed: u64,
    lr: f32,
    codec: &'static dyn crate::wire::Codec,
    w: Vec<f32>,
}

/// Compute one slot against the current round state and upload it.
#[allow(clippy::too_many_arguments)]
fn run_slot(
    conn: &mut Conn,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    st: &RoundState,
    slot: u32,
    client_id: u32,
    sum: &mut JoinSummary,
) -> Result<()> {
    let c = client_id as usize;
    let batch = dataset.client_batch(c, st.round_seed);
    let stacked = client
        .wants_stacked_batches()
        .map(|k| dataset.client_batches_stacked(c, k, st.round_seed));
    let res = client
        .client_round(artifacts, &st.w, &batch, c, stacked, st.lr)
        .with_context(|| format!("client {c} (slot {slot}, round {})", st.round))?;
    let frame = encode_upload(&res.upload, st.codec);
    let msg = Msg::Upload { slot, loss: res.loss, frame };
    sum.bytes_sent += write_msg(conn, &msg.encode())?;
    sum.uploads += 1;
    Ok(())
}

/// Connect to a round server and serve client compute until the server
/// says `Shutdown`. With `reconnect_attempts = 0` (the default) any
/// protocol violation, aborted round, or dropped connection errors out
/// loudly — what tests want. With a budget, a lost connection is
/// re-dialed under bounded exponential backoff (the worker is stateless
/// across rounds, so rejoining needs no resync protocol); the budget
/// bounds consecutive failures and refills whenever a connection
/// completes a round.
pub fn join(
    ep: &Endpoint,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    opts: &JoinOptions,
) -> Result<JoinSummary> {
    let mut sum = JoinSummary::default();
    let mut attempt = 0usize;
    loop {
        let rounds_before = sum.rounds;
        match join_once(ep, client, dataset, artifacts, opts, &mut sum) {
            Ok(()) => return Ok(sum),
            Err(e) => {
                if sum.rounds > rounds_before {
                    // This connection made progress; its failure starts
                    // a fresh consecutive-failure streak.
                    attempt = 0;
                }
                if attempt >= opts.reconnect_attempts {
                    return Err(e);
                }
                attempt += 1;
                let wait = backoff_ms(opts.reconnect_backoff_ms, attempt);
                eprintln!(
                    "[join] connection lost ({e:#}); reconnecting in {wait} ms \
                     (attempt {attempt}/{})",
                    opts.reconnect_attempts
                );
                std::thread::sleep(Duration::from_millis(wait));
            }
        }
    }
}

/// One connection lifetime: dial, hello, serve rounds until `Shutdown`
/// (clean exit) or any error. Progress accumulates into `sum` either
/// way, so a reconnecting worker's summary spans connections.
fn join_once(
    ep: &Endpoint,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    opts: &JoinOptions,
    sum: &mut JoinSummary,
) -> Result<()> {
    let mut conn = Conn::connect(ep)?;
    conn.set_timeouts(opts.read_timeout, opts.read_timeout)?;
    sum.bytes_sent += write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode())?;
    let mut current: Option<RoundState> = None;
    loop {
        let (bytes, n) = read_msg(&mut conn, opts.max_msg).context("waiting for server")?;
        sum.bytes_received += n;
        match Msg::decode(bytes)? {
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                let codec = codec_by_id(codec_id).context("round-start codec")?;
                let w = decode_dense_frame(&weights_frame).context("round-start weights")?;
                let st = RoundState { round, round_seed, lr, codec, w };
                for (slot, cid) in assignments {
                    run_slot(&mut conn, client, dataset, artifacts, &st, slot, cid, sum)?;
                }
                current = Some(st);
            }
            Msg::SlotAssign { slot, client: client_id } => {
                let st = current
                    .as_ref()
                    .context("slot-assign before any round-start on this connection")?;
                run_slot(&mut conn, client, dataset, artifacts, st, slot, client_id, sum)?;
            }
            Msg::RoundEnd { round, update_frame } => {
                // Validate the broadcast like any deployment would; the
                // next RoundStart carries fresh weights, so there is no
                // local model to patch.
                decode_update(&update_frame)
                    .with_context(|| format!("broadcast frame, round {round}"))?;
                sum.rounds += 1;
            }
            Msg::Shutdown => break,
            Msg::Abort { reason } => bail!("server aborted: {reason}"),
            other => bail!("unexpected {} message from server", other.kind_name()),
        }
    }
    Ok(())
}

/// Join a served training run from a `TrainConfig` — the worker half of
/// `fetchsgd serve` (`fetchsgd join`). Builds the strategy's client
/// compute, the dataset, and the AOT artifacts exactly as `train`
/// does, then drives them over `cfg.transport`.
pub fn join_training(cfg: &crate::config::TrainConfig) -> Result<JoinSummary> {
    use crate::coordinator::build_strategy;
    use crate::model::build_dataset;
    use crate::runtime::artifact::{Manifest, TaskArtifacts};
    use crate::runtime::Runtime;

    let spec = cfg
        .transport
        .as_deref()
        .context("join mode needs a transport endpoint (transport=tcp:HOST:PORT | uds:/path)")?;
    let ep = Endpoint::parse(spec)?;
    let runtime = std::sync::Arc::new(Runtime::cpu().context("PJRT runtime")?);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let artifacts = TaskArtifacts::new(runtime, &manifest, &cfg.task)?;
    let (client, _agg) = build_strategy(cfg, &artifacts)?;
    let dataset = build_dataset(&artifacts.manifest, &cfg.scale)?;
    let opts = JoinOptions {
        // One shared formula with serve_training — the caps on the two
        // sides of the socket cannot drift apart.
        max_msg: crate::transport::effective_max_msg(cfg, artifacts.manifest.dim)?,
        reconnect_attempts: cfg.reconnect_attempts,
        reconnect_backoff_ms: cfg.reconnect_backoff_ms,
        ..Default::default()
    };
    eprintln!("[join] connecting to {ep} as a {} worker", client.name());
    join(&ep, client.as_ref(), dataset.as_ref(), &artifacts, &opts)
}

#[cfg(test)]
mod tests {
    use super::backoff_ms;

    #[test]
    fn reconnect_backoff_doubles_and_caps() {
        assert_eq!(backoff_ms(200, 1), 200);
        assert_eq!(backoff_ms(200, 2), 400);
        assert_eq!(backoff_ms(200, 3), 800);
        assert_eq!(backoff_ms(200, 6), 6_400);
        // 200 · 2⁶ = 12 800 → capped at 10 s.
        assert_eq!(backoff_ms(200, 7), 10_000);
        // Huge attempt counts neither overflow the shift nor the cap.
        assert_eq!(backoff_ms(200, 1_000), 10_000);
        assert_eq!(backoff_ms(u64::MAX, 7), 10_000);
        assert_eq!(backoff_ms(0, 5), 0);
    }
}
