//! The transport client: drive any [`ClientCompute`] over a socket.
//!
//! A joined worker is the network mirror of one of the round engine's
//! worker threads: it receives the current weights and a list of
//! `(slot, client_id)` assignments each round, runs the strategy's
//! client compute for each assignment *in order* (the server relies on
//! per-connection upload order), and ships each upload frame as soon as
//! it is computed — which is what lets the server absorb streaming
//! instead of waiting for the cohort.
//!
//! Clients stay stateless across rounds (FetchSGD's whole point): the
//! model arrives fresh every `RoundStart` as a lossless dense frame, so
//! a worker can join, crash, and rejoin without any resync protocol.
//! Mid-round the server may hand over a `SlotAssign` — the
//! retry/reassignment of a slot whose original worker faulted — which
//! is computed against the same round state and uploaded like any
//! assigned slot.
//!
//! # Reconnect schedule
//!
//! A lost connection is re-dialed under [`ReconnectSchedule`], the
//! bounded-exponential backoff shared with the relay tier's upstream
//! loop ([`crate::relay`]). The schedule is pinned, not approximate:
//! the n-th *consecutive* failure waits `reconnect_backoff_ms ·
//! 2^(n-1)` milliseconds, capped at
//! [`RECONNECT_BACKOFF_CAP_MS`] (10 s), and the budget
//! (`reconnect_attempts`) bounds consecutive failures — a connection
//! that sees any round through to its broadcast resets the streak, so
//! a long-lived worker on a flaky link never slowly exhausts it.
//! `reconnect_attempts = 0` keeps the fail-fast behavior tests rely
//! on. Both knobs are settable from the CLI (`fetchsgd join
//! reconnect_attempts=N reconnect_backoff_ms=T`).

use anyhow::{bail, Context, Result};
use std::time::Duration;

use crate::compression::ClientCompute;
use crate::data::FedDataset;
use crate::runtime::artifact::TaskArtifacts;
use crate::transport::framing::{read_msg, write_msg, DEFAULT_MAX_MSG_BYTES};
use crate::transport::proto::{Msg, PROTO_VERSION};
use crate::transport::{Conn, Endpoint};
use crate::wire::{codec_by_id, decode_dense_frame, decode_update, encode_upload};

/// Client knobs.
pub struct JoinOptions {
    /// Read deadline while waiting for the server (None = block; the
    /// server controls round pacing, so the default is patient).
    pub read_timeout: Option<Duration>,
    /// Per-message size cap (mirrors the server's).
    pub max_msg: usize,
    /// How many times a lost connection is re-dialed before [`join`]
    /// gives up. A connection that sees a round through to its
    /// broadcast resets the counter — the budget bounds *consecutive*
    /// failures, not lifetime ones, so a long-lived worker on a flaky
    /// link doesn't slowly exhaust it. 0 (the default) keeps the old
    /// fail-fast behavior tests rely on.
    pub reconnect_attempts: usize,
    /// Backoff before the first reconnect attempt, in milliseconds;
    /// doubles per consecutive failure, capped at 10 s.
    pub reconnect_backoff_ms: u64,
}

impl Default for JoinOptions {
    fn default() -> Self {
        JoinOptions {
            read_timeout: None,
            max_msg: DEFAULT_MAX_MSG_BYTES,
            reconnect_attempts: 0,
            reconnect_backoff_ms: 200,
        }
    }
}

/// Hard ceiling on one reconnect delay: no consecutive-failure streak
/// waits longer than this between re-dials, whatever the base.
pub const RECONNECT_BACKOFF_CAP_MS: u64 = 10_000;

/// Exponential reconnect backoff: `base · 2^(attempt-1)`, exponent
/// capped so the shift cannot overflow, the result capped at
/// [`RECONNECT_BACKOFF_CAP_MS`].
pub(crate) fn backoff_ms(base: u64, attempt: usize) -> u64 {
    base.saturating_mul(1u64 << attempt.saturating_sub(1).min(6)).min(RECONNECT_BACKOFF_CAP_MS)
}

/// The bounded-exponential reconnect schedule (see module docs) —
/// one testable object shared by [`join`] and the relay tier's
/// upstream loop, so the two reconnect paths cannot drift apart.
#[derive(Clone, Debug)]
pub struct ReconnectSchedule {
    base_ms: u64,
    budget: usize,
    attempt: usize,
}

impl ReconnectSchedule {
    /// `base_ms` seeds the first delay; `budget` bounds *consecutive*
    /// failures (0 = fail on the first loss).
    pub fn new(base_ms: u64, budget: usize) -> ReconnectSchedule {
        ReconnectSchedule { base_ms, budget, attempt: 0 }
    }

    /// Record round progress: the connection that just failed saw at
    /// least one round through, so the next failure starts a fresh
    /// consecutive-failure streak.
    pub fn progress(&mut self) {
        self.attempt = 0;
    }

    /// Charge one connection failure. `Some(delay)` = sleep then
    /// re-dial; `None` = the consecutive-failure budget is exhausted,
    /// give up and surface the error.
    pub fn next_delay(&mut self) -> Option<Duration> {
        if self.attempt >= self.budget {
            return None;
        }
        self.attempt += 1;
        Some(Duration::from_millis(backoff_ms(self.base_ms, self.attempt)))
    }

    /// Consecutive failures charged since the last reset.
    pub fn attempt(&self) -> usize {
        self.attempt
    }

    /// The configured consecutive-failure budget.
    pub fn budget(&self) -> usize {
        self.budget
    }
}

/// What a worker did over its connection's lifetime.
#[derive(Clone, Debug, Default)]
pub struct JoinSummary {
    /// Rounds this worker saw through to the broadcast.
    pub rounds: usize,
    /// Total slot uploads sent.
    pub uploads: usize,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// The per-round state a worker keeps between `RoundStart` and
/// `RoundEnd`, so a mid-round `SlotAssign` (retry/reassignment of
/// another worker's slot) can be computed without any resync.
struct RoundState {
    round: u64,
    round_seed: u64,
    lr: f32,
    codec: &'static dyn crate::wire::Codec,
    w: Vec<f32>,
}

/// Compute one slot against the current round state and upload it.
#[allow(clippy::too_many_arguments)]
fn run_slot(
    conn: &mut Conn,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    st: &RoundState,
    slot: u32,
    client_id: u32,
    sum: &mut JoinSummary,
) -> Result<()> {
    let c = client_id as usize;
    let batch = dataset.client_batch(c, st.round_seed);
    let stacked = client
        .wants_stacked_batches()
        .map(|k| dataset.client_batches_stacked(c, k, st.round_seed));
    let res = client
        .client_round(artifacts, &st.w, &batch, c, stacked, st.lr)
        .with_context(|| format!("client {c} (slot {slot}, round {})", st.round))?;
    let frame = encode_upload(&res.upload, st.codec);
    let msg = Msg::Upload { slot, loss: res.loss, frame };
    sum.bytes_sent += write_msg(conn, &msg.encode())?;
    sum.uploads += 1;
    Ok(())
}

/// Connect to a round server and serve client compute until the server
/// says `Shutdown`. With `reconnect_attempts = 0` (the default) any
/// protocol violation, aborted round, or dropped connection errors out
/// loudly — what tests want. With a budget, a lost connection is
/// re-dialed under bounded exponential backoff (the worker is stateless
/// across rounds, so rejoining needs no resync protocol); the budget
/// bounds consecutive failures and refills whenever a connection
/// completes a round.
pub fn join(
    ep: &Endpoint,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    opts: &JoinOptions,
) -> Result<JoinSummary> {
    let mut sum = JoinSummary::default();
    let mut sched = ReconnectSchedule::new(opts.reconnect_backoff_ms, opts.reconnect_attempts);
    loop {
        let rounds_before = sum.rounds;
        match join_once(ep, client, dataset, artifacts, opts, &mut sum) {
            Ok(()) => return Ok(sum),
            Err(e) => {
                if sum.rounds > rounds_before {
                    sched.progress();
                }
                let Some(wait) = sched.next_delay() else {
                    return Err(e);
                };
                eprintln!(
                    "[join] connection lost ({e:#}); reconnecting in {} ms (attempt {}/{})",
                    wait.as_millis(),
                    sched.attempt(),
                    sched.budget()
                );
                std::thread::sleep(wait);
            }
        }
    }
}

/// One connection lifetime: dial, hello, serve rounds until `Shutdown`
/// (clean exit) or any error. Progress accumulates into `sum` either
/// way, so a reconnecting worker's summary spans connections.
fn join_once(
    ep: &Endpoint,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    opts: &JoinOptions,
    sum: &mut JoinSummary,
) -> Result<()> {
    let mut conn = Conn::connect(ep)?;
    conn.set_timeouts(opts.read_timeout, opts.read_timeout)?;
    sum.bytes_sent += write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode())?;
    let mut current: Option<RoundState> = None;
    loop {
        let (bytes, n) = read_msg(&mut conn, opts.max_msg).context("waiting for server")?;
        sum.bytes_received += n;
        match Msg::decode(bytes)? {
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                let codec = codec_by_id(codec_id).context("round-start codec")?;
                let w = decode_dense_frame(&weights_frame).context("round-start weights")?;
                let st = RoundState { round, round_seed, lr, codec, w };
                for (slot, cid) in assignments {
                    run_slot(&mut conn, client, dataset, artifacts, &st, slot, cid, sum)?;
                }
                current = Some(st);
            }
            Msg::SlotAssign { slot, client: client_id } => {
                let st = current
                    .as_ref()
                    .context("slot-assign before any round-start on this connection")?;
                run_slot(&mut conn, client, dataset, artifacts, st, slot, client_id, sum)?;
            }
            Msg::RoundEnd { round, update_frame } => {
                // Validate the broadcast like any deployment would; the
                // next RoundStart carries fresh weights, so there is no
                // local model to patch.
                decode_update(&update_frame)
                    .with_context(|| format!("broadcast frame, round {round}"))?;
                sum.rounds += 1;
            }
            Msg::Shutdown => break,
            Msg::Abort { reason } => bail!("server aborted: {reason}"),
            other => bail!("unexpected {} message from server", other.kind_name()),
        }
    }
    Ok(())
}

/// Join a served training run from a `TrainConfig` — the worker half of
/// `fetchsgd serve` (`fetchsgd join`). Builds the strategy's client
/// compute, the dataset, and the AOT artifacts exactly as `train`
/// does, then drives them over `cfg.transport`.
pub fn join_training(cfg: &crate::config::TrainConfig) -> Result<JoinSummary> {
    use crate::coordinator::build_strategy;
    use crate::model::build_dataset;
    use crate::runtime::artifact::{Manifest, TaskArtifacts};
    use crate::runtime::Runtime;

    let spec = cfg
        .transport
        .as_deref()
        .context("join mode needs a transport endpoint (transport=tcp:HOST:PORT | uds:/path)")?;
    let ep = Endpoint::parse(spec)?;
    let runtime = std::sync::Arc::new(Runtime::cpu().context("PJRT runtime")?);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let artifacts = TaskArtifacts::new(runtime, &manifest, &cfg.task)?;
    let (client, _agg) = build_strategy(cfg, &artifacts)?;
    let dataset = build_dataset(&artifacts.manifest, &cfg.scale)?;
    let opts = JoinOptions {
        // One shared formula with serve_training — the caps on the two
        // sides of the socket cannot drift apart.
        max_msg: crate::transport::effective_max_msg(cfg, artifacts.manifest.dim)?,
        reconnect_attempts: cfg.reconnect_attempts,
        reconnect_backoff_ms: cfg.reconnect_backoff_ms,
        ..Default::default()
    };
    eprintln!("[join] connecting to {ep} as a {} worker", client.name());
    join(&ep, client.as_ref(), dataset.as_ref(), &artifacts, &opts)
}

#[cfg(test)]
mod tests {
    use super::{backoff_ms, ReconnectSchedule, RECONNECT_BACKOFF_CAP_MS};
    use std::time::Duration;

    #[test]
    fn reconnect_backoff_doubles_and_caps() {
        assert_eq!(backoff_ms(200, 1), 200);
        assert_eq!(backoff_ms(200, 2), 400);
        assert_eq!(backoff_ms(200, 3), 800);
        assert_eq!(backoff_ms(200, 6), 6_400);
        // 200 · 2⁶ = 12 800 → capped at 10 s.
        assert_eq!(backoff_ms(200, 7), RECONNECT_BACKOFF_CAP_MS);
        // Huge attempt counts neither overflow the shift nor the cap.
        assert_eq!(backoff_ms(200, 1_000), RECONNECT_BACKOFF_CAP_MS);
        assert_eq!(backoff_ms(u64::MAX, 7), RECONNECT_BACKOFF_CAP_MS);
        assert_eq!(backoff_ms(0, 5), 0);
    }

    /// Pins the documented schedule end to end: bounded-exponential
    /// delays, budget over *consecutive* failures only (round progress
    /// resets the streak), exhaustion is sticky, 0 = fail fast.
    #[test]
    fn reconnect_schedule_resets_on_progress_and_exhausts() {
        let mut s = ReconnectSchedule::new(200, 3);
        assert_eq!(s.next_delay(), Some(Duration::from_millis(200)));
        assert_eq!(s.next_delay(), Some(Duration::from_millis(400)));
        assert_eq!(s.attempt(), 2);
        // A round completed on the re-dialed connection: the streak
        // restarts from the base delay with the full budget.
        s.progress();
        assert_eq!(s.attempt(), 0);
        assert_eq!(s.next_delay(), Some(Duration::from_millis(200)));
        assert_eq!(s.next_delay(), Some(Duration::from_millis(400)));
        assert_eq!(s.next_delay(), Some(Duration::from_millis(800)));
        assert_eq!(s.next_delay(), None);
        assert_eq!(s.next_delay(), None);
        // Zero budget = the old fail-fast default.
        assert_eq!(ReconnectSchedule::new(200, 0).next_delay(), None);
        // A huge base still respects the hard cap.
        let mut big = ReconnectSchedule::new(u64::MAX, 1);
        assert_eq!(
            big.next_delay(),
            Some(Duration::from_millis(RECONNECT_BACKOFF_CAP_MS))
        );
    }
}
