//! The transport client: drive any [`ClientCompute`] over a socket.
//!
//! A joined worker is the network mirror of one of the round engine's
//! worker threads: it receives the current weights and a list of
//! `(slot, client_id)` assignments each round, runs the strategy's
//! client compute for each assignment *in order* (the server relies on
//! per-connection upload order), and ships each upload frame as soon as
//! it is computed — which is what lets the server absorb streaming
//! instead of waiting for the cohort.
//!
//! Clients stay stateless across rounds (FetchSGD's whole point): the
//! model arrives fresh every `RoundStart` as a lossless dense frame, so
//! a worker can join, crash, and rejoin without any resync protocol.
//! Mid-round the server may hand over a `SlotAssign` — the
//! retry/reassignment of a slot whose original worker faulted — which
//! is computed against the same round state and uploaded like any
//! assigned slot.

use anyhow::{bail, Context, Result};
use std::time::Duration;

use crate::compression::ClientCompute;
use crate::data::FedDataset;
use crate::runtime::artifact::TaskArtifacts;
use crate::transport::framing::{read_msg, write_msg, DEFAULT_MAX_MSG_BYTES};
use crate::transport::proto::{Msg, PROTO_VERSION};
use crate::transport::{Conn, Endpoint};
use crate::wire::{codec_by_id, decode_dense_frame, decode_update, encode_upload};

/// Client knobs.
pub struct JoinOptions {
    /// Read deadline while waiting for the server (None = block; the
    /// server controls round pacing, so the default is patient).
    pub read_timeout: Option<Duration>,
    /// Per-message size cap (mirrors the server's).
    pub max_msg: usize,
}

impl Default for JoinOptions {
    fn default() -> Self {
        JoinOptions { read_timeout: None, max_msg: DEFAULT_MAX_MSG_BYTES }
    }
}

/// What a worker did over its connection's lifetime.
#[derive(Clone, Debug, Default)]
pub struct JoinSummary {
    /// Rounds this worker saw through to the broadcast.
    pub rounds: usize,
    /// Total slot uploads sent.
    pub uploads: usize,
    pub bytes_sent: u64,
    pub bytes_received: u64,
}

/// The per-round state a worker keeps between `RoundStart` and
/// `RoundEnd`, so a mid-round `SlotAssign` (retry/reassignment of
/// another worker's slot) can be computed without any resync.
struct RoundState {
    round: u64,
    round_seed: u64,
    lr: f32,
    codec: &'static dyn crate::wire::Codec,
    w: Vec<f32>,
}

/// Compute one slot against the current round state and upload it.
#[allow(clippy::too_many_arguments)]
fn run_slot(
    conn: &mut Conn,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    st: &RoundState,
    slot: u32,
    client_id: u32,
    sum: &mut JoinSummary,
) -> Result<()> {
    let c = client_id as usize;
    let batch = dataset.client_batch(c, st.round_seed);
    let stacked = client
        .wants_stacked_batches()
        .map(|k| dataset.client_batches_stacked(c, k, st.round_seed));
    let res = client
        .client_round(artifacts, &st.w, &batch, c, stacked, st.lr)
        .with_context(|| format!("client {c} (slot {slot}, round {})", st.round))?;
    let frame = encode_upload(&res.upload, st.codec);
    let msg = Msg::Upload { slot, loss: res.loss, frame };
    sum.bytes_sent += write_msg(conn, &msg.encode())?;
    sum.uploads += 1;
    Ok(())
}

/// Connect to a round server and serve client compute until the server
/// says `Shutdown`. Errors on protocol violations, aborted rounds, and
/// dropped connections — a deployment would wrap this in a reconnect
/// loop; tests want the loud failure.
pub fn join(
    ep: &Endpoint,
    client: &dyn ClientCompute,
    dataset: &dyn FedDataset,
    artifacts: &TaskArtifacts,
    opts: &JoinOptions,
) -> Result<JoinSummary> {
    let mut conn = Conn::connect(ep)?;
    conn.set_timeouts(opts.read_timeout, opts.read_timeout)?;
    let hello = write_msg(&mut conn, &Msg::Hello { version: PROTO_VERSION }.encode())?;
    let mut sum = JoinSummary { bytes_sent: hello, ..Default::default() };
    let mut current: Option<RoundState> = None;
    loop {
        let (bytes, n) = read_msg(&mut conn, opts.max_msg).context("waiting for server")?;
        sum.bytes_received += n;
        match Msg::decode(bytes)? {
            Msg::RoundStart { round, round_seed, lr, codec_id, assignments, weights_frame } => {
                let codec = codec_by_id(codec_id).context("round-start codec")?;
                let w = decode_dense_frame(&weights_frame).context("round-start weights")?;
                let st = RoundState { round, round_seed, lr, codec, w };
                for (slot, cid) in assignments {
                    run_slot(&mut conn, client, dataset, artifacts, &st, slot, cid, &mut sum)?;
                }
                current = Some(st);
            }
            Msg::SlotAssign { slot, client: client_id } => {
                let st = current
                    .as_ref()
                    .context("slot-assign before any round-start on this connection")?;
                run_slot(&mut conn, client, dataset, artifacts, st, slot, client_id, &mut sum)?;
            }
            Msg::RoundEnd { round, update_frame } => {
                // Validate the broadcast like any deployment would; the
                // next RoundStart carries fresh weights, so there is no
                // local model to patch.
                decode_update(&update_frame)
                    .with_context(|| format!("broadcast frame, round {round}"))?;
                sum.rounds += 1;
            }
            Msg::Shutdown => break,
            Msg::Abort { reason } => bail!("server aborted: {reason}"),
            other => bail!("unexpected {} message from server", other.kind_name()),
        }
    }
    Ok(sum)
}

/// Join a served training run from a `TrainConfig` — the worker half of
/// `fetchsgd serve` (`fetchsgd join`). Builds the strategy's client
/// compute, the dataset, and the AOT artifacts exactly as `train`
/// does, then drives them over `cfg.transport`.
pub fn join_training(cfg: &crate::config::TrainConfig) -> Result<JoinSummary> {
    use crate::coordinator::build_strategy;
    use crate::model::build_dataset;
    use crate::runtime::artifact::{Manifest, TaskArtifacts};
    use crate::runtime::Runtime;

    let spec = cfg
        .transport
        .as_deref()
        .context("join mode needs a transport endpoint (transport=tcp:HOST:PORT | uds:/path)")?;
    let ep = Endpoint::parse(spec)?;
    let runtime = std::sync::Arc::new(Runtime::cpu().context("PJRT runtime")?);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let artifacts = TaskArtifacts::new(runtime, &manifest, &cfg.task)?;
    let (client, _agg) = build_strategy(cfg, &artifacts)?;
    let dataset = build_dataset(&artifacts.manifest, &cfg.scale)?;
    let opts = JoinOptions {
        // One shared formula with serve_training — the caps on the two
        // sides of the socket cannot drift apart.
        max_msg: crate::transport::effective_max_msg(cfg, artifacts.manifest.dim)?,
        ..Default::default()
    };
    eprintln!("[join] connecting to {ep} as a {} worker", client.name());
    join(&ep, client.as_ref(), dataset.as_ref(), &artifacts, &opts)
}
