//! The round server: accept worker connections, fan participant slots
//! out, stream upload frames into the shard accumulator pool as they
//! arrive, broadcast the round update.
//!
//! See the module docs ([`crate::transport`]) for the determinism and
//! fault-containment contracts. The shapes worth knowing here:
//!
//! - One [`RoundServer`] lives across rounds. It owns a
//!   [`RoundPipeline`] — the *same* aggregation machinery the
//!   in-process engine drives — whose shard accumulator pool is reused
//!   round to round, and its worker connections persist until a fault
//!   or [`RoundServer::shutdown`].
//! - [`RoundServer::run_round`] is one full server round:
//!   `begin_round → RoundStart to each worker → concurrent reads
//!   streaming into the pipeline's `RoundInFlight` → row-strip reduce →
//!   finish → RoundEnd broadcast → apply the *decoded* update`,
//!   mirroring the trainer's wire mode exactly. Readers offer frames
//!   straight from the transport read buffer (`offer_frame_bytes`) —
//!   an in-shard-order arrival is folded without copying the payload,
//!   and only truly-early frames are parked as owned bytes. The
//!   in-flight round shards its lock, so readers delivering to
//!   different shards absorb concurrently; contention that remains
//!   shows up in [`RoundStats::absorb_stalls`].
//! - Under the default strict [`QuorumPolicy`], any fault — bad frame,
//!   bad slot, stalled peer (read deadline), oversize prefix,
//!   disconnect — fails the round loudly: connections are dropped
//!   (workers get a best-effort `Abort`), the partially filled
//!   accumulators are discarded, and the server is immediately ready
//!   for the next round with fresh connections.
//! - Under a tolerant quorum policy the round *survives* faults: a
//!   faulted or disconnected worker's unserved slots are re-offered to
//!   healthy connections (`SlotAssign`, up to `max_slot_retries` per
//!   slot), a straggler past the round deadline is dropped rather than
//!   aborting the round, and once every slot is settled the round
//!   closes at quorum via `RoundPipeline::finalize_partial` — the
//!   aggregation weights renormalized over the slots that actually
//!   arrived, bitwise identical to any other driver ending with the
//!   same membership set.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::UnixListener;
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cohort::{DropReason, QuorumPolicy, RoundMembership, SlotOutcome};
use crate::compression::aggregate::{PipelineOptions, RoundInFlight, RoundPipeline};
use crate::compression::{ServerAggregator, UploadSpec};
use crate::trace::{
    ms_since, ConnIo, ConnTrace, Histogram, Phase, RoundTiming, SlotEvent, TraceSink,
};
use crate::transport::framing::{
    read_msg, read_msg_timed, write_msg, write_msg_parts, DEFAULT_MAX_MSG_BYTES,
};
use crate::transport::proto::{
    Msg, SlotReport, OUTCOME_ARRIVED, OUTCOME_DROPPED_DEADLINE, OUTCOME_DROPPED_DISCONNECTED,
    OUTCOME_DROPPED_FAULTED, PROTO_VERSION,
};
use crate::transport::{Conn, Endpoint};
use crate::wire::{decode_update, encode_dense_frame, encode_update, Body, Codec, Frame, F32LE};

/// Server knobs. Defaults suit a loopback deployment; raise the
/// deadlines for real networks.
pub struct ServeOptions {
    /// Worker connections the server waits for (each serves one or more
    /// participant slots per round).
    pub workers: usize,
    /// Value codec for upload and update frames (weights broadcasts are
    /// always lossless `f32le` so transport never perturbs the model).
    pub codec: &'static dyn Codec,
    /// Per-connection read/write deadline. A peer that stalls longer
    /// than this mid-round faults its connection instead of wedging the
    /// round.
    pub read_timeout: Duration,
    /// How long to wait for the worker pool to fill at round start.
    pub accept_timeout: Duration,
    /// Per-message size cap (forged length prefixes are rejected
    /// against this before any allocation).
    pub max_msg: usize,
    /// Worker threads for the round pipeline's row-strip shard
    /// reduction (0 = all cores). Purely a throughput knob — the merged
    /// bits are identical at any value.
    pub reduce_parallelism: usize,
    /// Partial-participation policy. [`QuorumPolicy::strict`] (the
    /// default) keeps the pre-cohort behavior: any fault fails the
    /// round. A tolerant policy re-offers a faulted or disconnected
    /// worker's slots to healthy connections (`SlotAssign`, up to
    /// `max_slot_retries` per slot), drops stragglers once the round
    /// deadline fires, and closes the round at quorum with the
    /// aggregation weights renormalized over the arrived subset.
    pub quorum: QuorumPolicy,
    /// Accumulator shards for the round pipeline. 0 (the default) =
    /// auto-size from `reduce_parallelism`. A flat server that must be
    /// bitwise comparable to a relay tree sets this to the tree's relay
    /// count, matching its fold order (see [`crate::relay`]).
    pub shards: usize,
    /// Tiered shard-reduce layout for a flat server that must be
    /// bitwise comparable to a *multi-level* relay tree: the fan-out at
    /// each tier from the root down (e.g. `[2, 2]` for a depth-3 tree
    /// of 2 relays x 2 children). Empty (the default) = ordinary flat
    /// reduce. Pins the shard count to the product of the fan-outs and
    /// reassociates the shard reduce to the tree's fold order (see
    /// [`crate::compression::aggregate::reduce_shards_tree`]). Ignored
    /// in relay mode — a relay-mode root always reduces one shard per
    /// child, whatever hangs below them.
    pub shard_tiers: Vec<usize>,
    /// Number of downstream *relays* this server aggregates over
    /// instead of direct workers. 0 (the default) = flat serving. When
    /// set, `workers` is ignored: the server accepts `relay-hello`
    /// peers, hands each one a slot chain (`subtree-assign`), absorbs
    /// one merged lossless frame per relay, and the shard layout is
    /// pinned to the relay count so the tree's fold order reproduces
    /// the flat server's bits.
    pub relay_children: usize,
    /// Opt-in self-sizing of the round pipeline's shard layout from
    /// lock-stall history (see
    /// [`crate::compression::aggregate::PipelineOptions::adaptive_shards`]).
    /// Ignored whenever `shards`, `shard_tiers`, or `relay_children`
    /// pins the layout; off by default because the shard count is the
    /// reduction tree — runs meant to be bitwise-comparable across
    /// machines or topologies must keep it off.
    pub adaptive_shards: bool,
    /// Opt-in shard→core pinning for the reduce workers (see
    /// [`crate::compression::aggregate::PipelineOptions::pin_shards`]).
    /// Placement hint only; never changes bits.
    pub pin_shards: bool,
    /// Structured trace sink for this tier (`tier: "root"`; see
    /// [`crate::trace`]): phase spans, per-slot timelines, per-connection
    /// IO splits, and arrival histograms. `None` (the default) keeps the
    /// round's hot paths free of per-upload clock reads — only the
    /// handful of per-round span Instants remain.
    pub trace: Option<Arc<TraceSink>>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            workers: 1,
            codec: &F32LE,
            read_timeout: Duration::from_secs(30),
            accept_timeout: Duration::from_secs(30),
            max_msg: DEFAULT_MAX_MSG_BYTES,
            reduce_parallelism: 0,
            quorum: QuorumPolicy::strict(),
            shards: 0,
            shard_tiers: Vec::new(),
            relay_children: 0,
            adaptive_shards: false,
            pin_shards: false,
            trace: None,
        }
    }
}

/// Per-round inputs (the caller owns selection, sizing, and the lr
/// schedule — everything the trainer owns in-process).
pub struct RoundParams<'a> {
    pub round: u64,
    /// Seed clients use to draw this round's batches.
    pub round_seed: u64,
    pub lr: f32,
    /// Participant client ids, in slot order.
    pub participants: &'a [usize],
    /// Participants' local dataset sizes, in slot order (drives
    /// `ServerAggregator::begin_round` weights).
    pub client_sizes: &'a [f32],
}

/// What one transport round produced.
pub struct RoundStats {
    /// Per-slot client training loss, in slot order (0.0 for dropped
    /// slots).
    pub losses: Vec<f32>,
    /// Mean loss over the arrived slots, reduced in slot order
    /// (scheduling-invariant).
    pub mean_loss: f64,
    /// Slots whose upload was absorbed this round.
    pub participants: usize,
    /// Planned slots dropped (fault / disconnect / deadline, after
    /// retries).
    pub dropped_slots: usize,
    /// Slots that needed at least one retry or reassignment.
    pub retried_slots: usize,
    pub update_nnz: usize,
    /// Idealized (footnote-5) payload bytes of one upload (sampled from
    /// the lowest delivered slot — all of a strategy's uploads are the
    /// same size).
    pub upload_bytes_per_client: u64,
    /// Idealized payload bytes of the broadcast update.
    pub download_bytes_per_client: u64,
    /// Measured `FSGW` frame bytes of one upload.
    pub wire_upload_bytes_per_client: u64,
    /// Measured `FSGW` frame bytes of the broadcast update.
    pub wire_download_bytes_per_client: u64,
    /// Total measured on-the-wire bytes this round, both directions:
    /// every round-start (weights + assignments), upload, and round-end
    /// message including length prefixes and control headers — the
    /// number a packet capture would report.
    pub transport_bytes: u64,
    /// Times a reader found its target shard's absorb lock held and had
    /// to block. Zero on an uncontended round; a persistently high
    /// count means uploads are piling onto few shards.
    pub absorb_stalls: u64,
    /// Frame bytes copied out of the transport read buffer because the
    /// upload arrived ahead of an earlier slot on its shard. Zero when
    /// every arrival took the zero-copy path.
    pub parked_bytes: u64,
    /// Shard accumulators the round pipeline ran with (fixed layout
    /// unless `adaptive_shards` resized it; see
    /// [`crate::compression::aggregate::AbsorbStats::chosen_shards`]).
    pub chosen_shards: u64,
    /// Wall-clock phase timing of this round. `absorb_ms` is the
    /// upload-wait span (reader scope); `compute_ms` stays 0 — a round
    /// server's compute is remote. Always measured (a few per-round
    /// clock reads, never per-upload).
    pub timing: RoundTiming,
    /// Upload-arrival latencies (µs since round start), recorded only
    /// while a trace sink is attached; empty otherwise.
    pub arrivals: Histogram,
}

enum ListenerKind {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

/// A round server bound to one endpoint. See module docs.
pub struct RoundServer {
    listener: ListenerKind,
    opts: ServeOptions,
    conns: Vec<Conn>,
    /// The shared round-aggregation pipeline (same machinery the
    /// in-process engine drives): shard layout, reusable accumulator
    /// pool, absorb-on-arrival, row-strip parallel reduce.
    pipeline: RoundPipeline,
    /// Live count of uploads absorbed this round — the streaming-absorb
    /// probe (`absorbed_probe`), updated as frames fold in.
    absorbed: Arc<AtomicUsize>,
    #[cfg(unix)]
    uds_path: Option<PathBuf>,
}

impl RoundServer {
    /// Bind a listener (TCP port 0 = ephemeral; a stale UDS socket file
    /// is removed first).
    pub fn bind(ep: &Endpoint, opts: ServeOptions) -> Result<RoundServer> {
        if opts.workers == 0 && opts.relay_children == 0 {
            bail!("ServeOptions.workers must be >= 1");
        }
        let listener = match ep {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr.as_str())
                    .with_context(|| format!("binding tcp:{addr}"))?;
                l.set_nonblocking(true).context("listener nonblocking")?;
                ListenerKind::Tcp(l)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                if path.exists() {
                    std::fs::remove_file(path)
                        .with_context(|| format!("removing stale socket {}", path.display()))?;
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding uds:{}", path.display()))?;
                l.set_nonblocking(true).context("listener nonblocking")?;
                ListenerKind::Unix(l)
            }
        };
        // A relay-mode root pins the shard layout to the relay count —
        // one shard chain per relay — so the tree's two-level fold
        // reassociates to exactly the flat fold over the same slots.
        let shard_override =
            if opts.relay_children > 0 { opts.relay_children } else { opts.shards };
        let reduce_tiers =
            if opts.relay_children > 0 { Vec::new() } else { opts.shard_tiers.clone() };
        // The adaptive sizer only engages when nothing pins the layout
        // (the pipeline enforces the same rule; gating here too keeps
        // the ServeOptions semantics explicit).
        let adaptive_shards = opts.adaptive_shards && shard_override == 0 && reduce_tiers.is_empty();
        let pipeline = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: opts.reduce_parallelism,
            shard_override,
            reduce_tiers,
            adaptive_shards,
            pin_shards: opts.pin_shards,
        });
        Ok(RoundServer {
            listener,
            opts,
            conns: Vec::new(),
            pipeline,
            absorbed: Arc::new(AtomicUsize::new(0)),
            #[cfg(unix)]
            uds_path: match ep {
                Endpoint::Unix(p) => Some(p.clone()),
                _ => None,
            },
        })
    }

    /// The endpoint actually bound (resolves TCP port 0).
    pub fn local_endpoint(&self) -> Result<Endpoint> {
        match &self.listener {
            ListenerKind::Tcp(l) => {
                Ok(Endpoint::Tcp(l.local_addr().context("local_addr")?.to_string()))
            }
            #[cfg(unix)]
            ListenerKind::Unix(_) => {
                let path = self.uds_path.clone().context("uds path missing")?;
                Ok(Endpoint::Unix(path))
            }
        }
    }

    /// Currently connected workers.
    pub fn connected(&self) -> usize {
        self.conns.len()
    }

    /// Shared live counter of uploads absorbed in the current round —
    /// lets tests (and dashboards) observe streaming absorption while
    /// stragglers are still out.
    pub fn absorbed_probe(&self) -> Arc<AtomicUsize> {
        Arc::clone(&self.absorbed)
    }

    /// The number of downstream peers a round needs: relays in relay
    /// mode, workers otherwise.
    fn want_peers(&self) -> usize {
        if self.opts.relay_children > 0 {
            self.opts.relay_children
        } else {
            self.opts.workers
        }
    }

    /// Accept + handshake until the downstream pool is full (workers in
    /// flat mode, relays in relay mode). Connections that fail the
    /// hello handshake (bad version, wrong tier, garbage, stall) are
    /// dropped and accepting continues until the deadline.
    pub fn ensure_workers(&mut self) -> Result<()> {
        let want = self.want_peers();
        let relay = self.opts.relay_children > 0;
        let deadline = Instant::now() + self.opts.accept_timeout;
        while self.conns.len() < want {
            if Instant::now() >= deadline {
                bail!(
                    "timed out waiting for worker connections ({}/{} connected)",
                    self.conns.len(),
                    want
                );
            }
            let mut conn = self.accept_one(deadline)?;
            // Bound each handshake by the *remaining* pool deadline: a
            // stream of silent connectors burns its own clock, not an
            // unbounded read_timeout per peer.
            let remaining = deadline.saturating_duration_since(Instant::now());
            let hs = self.opts.read_timeout.min(remaining).max(Duration::from_millis(10));
            let _ = conn.set_timeouts(Some(hs), Some(hs));
            match handshake(&mut conn, self.opts.max_msg, relay) {
                Ok(()) => {
                    let t = self.opts.read_timeout;
                    conn.set_timeouts(Some(t), Some(t))?;
                    self.conns.push(conn);
                }
                Err(_) => {
                    let abort = Msg::Abort { reason: "handshake failed".into() }.encode();
                    let _ = write_msg(&mut conn, &abort);
                    conn.shutdown();
                }
            }
        }
        Ok(())
    }

    fn accept_one(&self, deadline: Instant) -> Result<Conn> {
        loop {
            let accepted = match &self.listener {
                ListenerKind::Tcp(l) => l.accept().map(|(s, _)| Conn::from_tcp(s)),
                #[cfg(unix)]
                ListenerKind::Unix(l) => l.accept().map(|(s, _)| Conn::from_unix(s)),
            };
            match accepted {
                Ok(conn) => {
                    conn.set_blocking()?;
                    let t = self.opts.read_timeout;
                    conn.set_timeouts(Some(t), Some(t))?;
                    return Ok(conn);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out waiting for worker connections ({}/{} connected)",
                            self.conns.len(),
                            self.want_peers()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            }
        }
    }

    /// One full server round. On any fault the round's connections are
    /// dropped (best-effort `Abort` first) and the error returned; the
    /// server — scratch pool, listener, probe — stays reusable.
    pub fn run_round(
        &mut self,
        agg: &mut dyn ServerAggregator,
        p: &RoundParams<'_>,
        w: &mut [f32],
    ) -> Result<RoundStats> {
        let slots = p.participants.len();
        if slots == 0 {
            bail!("round {} has no participants", p.round);
        }
        if p.client_sizes.len() != slots {
            bail!("{} participants but {} client sizes", slots, p.client_sizes.len());
        }
        self.ensure_workers()?;
        if self.opts.relay_children > 0 {
            return self.run_round_relay(agg, p, w);
        }
        let trace = self.opts.trace.clone();
        let round_t0 = Instant::now();
        let round_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let nconns = self.conns.len();
        let policy = self.opts.quorum.clone();
        let deadline = policy.round_deadline().map(|d| Instant::now() + d);
        // A previous round's deadline may have left a shortened socket
        // timeout on a surviving connection; restore the configured one.
        for conn in &self.conns {
            let t = self.opts.read_timeout;
            let _ = conn.set_timeouts(Some(t), Some(t));
        }
        let lambdas = agg.begin_round(p.client_sizes);
        let spec = agg.upload_spec();
        self.absorbed.store(0, Ordering::SeqCst);

        // Slot → worker layout: round-robin, like slots over shards.
        // Which worker computes a slot never affects the result (client
        // compute is a pure function and absorb order is enforced by
        // the round pipeline's in-flight state), so this is purely load
        // balancing.
        let mut assignments: Vec<Vec<(u32, u32)>> = vec![Vec::new(); nconns];
        for (slot, &c) in p.participants.iter().enumerate() {
            let client = u32::try_from(c).context("client id exceeds u32")?;
            assignments[slot % nconns].push((slot as u32, client));
        }

        let mut transport_bytes = 0u64;
        let w_frame = encode_dense_frame(w, &F32LE);
        let mut start_err = None;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            // Encode the fixed part with an empty frame and splice the
            // shared weights buffer in at write time — the whole-model
            // bytes are never cloned per worker.
            let head = Msg::RoundStart {
                round: p.round,
                round_seed: p.round_seed,
                lr: p.lr,
                codec_id: self.opts.codec.id(),
                assignments: assignments[i].clone(),
                weights_frame: Vec::new(),
            }
            .encode();
            match write_msg_parts(conn, &head, &w_frame) {
                Ok(n) => transport_bytes += n,
                Err(e) => {
                    start_err = Some(e.context(format!("sending round-start to worker {i}")));
                    break;
                }
            }
        }
        if let Some(e) = start_err {
            self.abort_round("round-start delivery failed");
            return Err(e);
        }
        if let Some(t) = &trace {
            t.span(p.round, Phase::Plan, round_start_us, t.now_us());
        }

        // Concurrent upload readers: one thread per connection, all
        // streaming into one ordered in-flight round. Absorption
        // happens as frames arrive — the only synchronization is the
        // target shard's own lock (readers delivering to different
        // shards fold concurrently), never a cohort barrier. Under a
        // tolerant quorum
        // policy the readers double as the retry service: a faulted
        // connection's unserved slots land in a shared orphan queue,
        // and healthy readers that finish their own assignments pull
        // from it, re-offering each slot over their own connection
        // (`SlotAssign`) until it arrives, its retry budget is spent,
        // or the round deadline fires.
        let mut absorber = match self.pipeline.begin(&spec, lambdas) {
            Ok(a) => a,
            Err(e) => {
                self.abort_round("round pipeline setup failed");
                return Err(e);
            }
        };
        if let Some(t) = &trace {
            absorber.attach_trace(Arc::clone(t), p.round);
        }
        let absorber = absorber;
        let failed = AtomicBool::new(false);
        // Strict policy = pre-cohort fail-fast: one fault dooms the
        // round, so other readers stop at their next message boundary.
        let fail_fast = policy.is_strict();
        let max_retries = policy.max_slot_retries();
        let probe = Arc::clone(&self.absorbed);
        let max_msg = self.opts.max_msg;
        let read_timeout = self.opts.read_timeout;

        /// Slot-resolution ledger shared by all readers: every planned
        /// slot ends up arrived (a reader's `pairs`) or in `dropped`.
        struct RetryState {
            /// Orphaned (slot, client) pairs awaiting reassignment.
            queue: VecDeque<(u32, u32)>,
            /// Retries charged per slot.
            retries: Vec<usize>,
            dropped: Vec<(u32, DropReason)>,
            /// Slots not yet arrived or dropped.
            outstanding: usize,
        }
        let retry = Mutex::new(RetryState {
            queue: VecDeque::new(),
            retries: vec![0; slots],
            dropped: Vec::new(),
            outstanding: slots,
        });
        // Resolve a faulted connection's unserved slots: queue for
        // reassignment while budget and clock allow, drop otherwise.
        let orphan = |rest: &[(u32, u32)], reason: DropReason| {
            let mut st = retry.lock().expect("retry state poisoned");
            let past_deadline = deadline.is_some_and(|dl| Instant::now() >= dl);
            for &(slot, client) in rest {
                if reason != DropReason::Deadline
                    && !past_deadline
                    && st.retries[slot as usize] < max_retries
                {
                    st.queue.push_back((slot, client));
                } else {
                    st.dropped.push((slot, reason));
                    st.outstanding -= 1;
                }
            }
        };

        struct ConnRead {
            /// (slot, loss) in this connection's upload order
            /// (reassigned slots included).
            pairs: Vec<(usize, f32)>,
            bytes_in: u64,
            /// `SlotAssign` bytes written during the retry phase.
            bytes_out: u64,
            /// (slot, frame bytes, idealized payload bytes) of the
            /// lowest slot this connection carried — all of a
            /// strategy's uploads are the same size, and sampling the
            /// lowest *delivered* slot keeps the accounting real when
            /// slot 0 drops out of a quorum round.
            byte_sample: Option<(usize, u64, u64)>,
            /// IO time split accumulated across this connection's reads
            /// and retry-phase writes (zero when untraced).
            io: ConnIo,
            /// Upload-arrival latencies on this connection (µs since
            /// round start; empty when untraced).
            arrivals: Histogram,
            /// First error this connection hit (the connection is dead).
            err: Option<anyhow::Error>,
        }

        let wait_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let wait_t0 = Instant::now();
        let results: Vec<ConnRead> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .zip(assignments.iter())
                .enumerate()
                .map(|(peer, (conn, assigned))| {
                    let absorber = &absorber;
                    let failed = &failed;
                    let probe = &probe;
                    let retry = &retry;
                    let orphan = &orphan;
                    let ct =
                        trace.as_deref().map(|sink| ConnTrace { sink, round: p.round, peer });
                    s.spawn(move || -> ConnRead {
                        let mut out = ConnRead {
                            pairs: Vec::with_capacity(assigned.len()),
                            bytes_in: 0,
                            bytes_out: 0,
                            byte_sample: None,
                            io: ConnIo::default(),
                            arrivals: Histogram::new(),
                            err: None,
                        };
                        // Bound the next read by the round deadline (if
                        // any) so a straggler read wakes exactly when
                        // the round must close.
                        let read_bounded = |conn: &mut Conn,
                                            expect_slot: u32,
                                            want_ideal: bool,
                                            io: &mut ConnIo| {
                            if let Some(dl) = deadline {
                                let rem = dl.saturating_duration_since(Instant::now());
                                if rem.is_zero() {
                                    bail!("round deadline expired awaiting slot {expect_slot}");
                                }
                                let t = read_timeout.min(rem);
                                let _ = conn.set_timeouts(Some(t), Some(t));
                            }
                            read_one_upload(
                                conn,
                                expect_slot,
                                max_msg,
                                want_ideal,
                                absorber,
                                probe,
                                ct.map(|c| (c, io)),
                            )
                        };
                        // Phase 1: this connection's own assignments.
                        for (i, &(expect_slot, client)) in assigned.iter().enumerate() {
                            if fail_fast && failed.load(Ordering::SeqCst) {
                                out.err =
                                    Some(anyhow!("round already failed on another connection"));
                                orphan(&assigned[i..], DropReason::Disconnected);
                                return out;
                            }
                            let slot = expect_slot as usize;
                            let want = out.byte_sample.map_or(true, |(s, _, _)| slot < s);
                            match read_bounded(&mut *conn, expect_slot, want, &mut out.io) {
                                Ok(up) => {
                                    out.bytes_in += up.bytes_in;
                                    if let Some(c) = &ct {
                                        out.arrivals
                                            .record(c.sink.now_us().saturating_sub(round_start_us));
                                    }
                                    if want {
                                        out.byte_sample = Some((
                                            expect_slot as usize,
                                            up.frame_bytes,
                                            up.ideal_bytes,
                                        ));
                                    }
                                    out.pairs.push((expect_slot as usize, up.loss));
                                    retry.lock().expect("retry state poisoned").outstanding -= 1;
                                }
                                Err(e) => {
                                    failed.store(true, Ordering::SeqCst);
                                    let at_deadline =
                                        deadline.is_some_and(|dl| Instant::now() >= dl);
                                    let reason = if at_deadline {
                                        DropReason::Deadline
                                    } else {
                                        DropReason::Disconnected
                                    };
                                    orphan(&assigned[i..], reason);
                                    out.err = Some(e.context(format!(
                                        "upload from client {client} (slot {expect_slot})"
                                    )));
                                    return out;
                                }
                            }
                        }
                        // Phase 2: serve the orphan queue until every
                        // slot is resolved. Only healthy connections
                        // get here.
                        loop {
                            let job = {
                                let mut st = retry.lock().expect("retry state poisoned");
                                if st.outstanding == 0 {
                                    break;
                                }
                                match st.queue.pop_front() {
                                    Some((slot, client)) => {
                                        if deadline.is_some_and(|dl| Instant::now() >= dl) {
                                            st.dropped.push((slot, DropReason::Deadline));
                                            st.outstanding -= 1;
                                            continue;
                                        }
                                        st.retries[slot as usize] += 1;
                                        Some((slot, client))
                                    }
                                    None => None,
                                }
                            };
                            let Some((slot, client)) = job else {
                                if deadline.is_some_and(|dl| Instant::now() >= dl) {
                                    // Outstanding slots belong to
                                    // stragglers; their own readers
                                    // resolve them at the deadline.
                                    break;
                                }
                                std::thread::sleep(Duration::from_millis(2));
                                continue;
                            };
                            if let Some(c) = &ct {
                                c.sink.slot_event(
                                    c.round,
                                    slot as usize,
                                    SlotEvent::Reassigned,
                                    Some(c.peer),
                                );
                            }
                            let assign = Msg::SlotAssign { slot, client }.encode();
                            let want =
                                out.byte_sample.map_or(true, |(s, _, _)| (slot as usize) < s);
                            let w_t0 = ct.as_ref().map(|_| Instant::now());
                            let wrote = write_msg(&mut *conn, &assign);
                            if let Some(t0) = w_t0 {
                                out.io.write_us += t0.elapsed().as_micros() as u64;
                            }
                            let sent = match wrote {
                                Ok(n) => read_bounded(&mut *conn, slot, want, &mut out.io)
                                    .map(|up| (n, up)),
                                Err(e) => Err(e),
                            };
                            match sent {
                                Ok((n, up)) => {
                                    out.bytes_out += n;
                                    out.bytes_in += up.bytes_in;
                                    if let Some(c) = &ct {
                                        out.arrivals
                                            .record(c.sink.now_us().saturating_sub(round_start_us));
                                    }
                                    if want {
                                        out.byte_sample =
                                            Some((slot as usize, up.frame_bytes, up.ideal_bytes));
                                    }
                                    out.pairs.push((slot as usize, up.loss));
                                    retry.lock().expect("retry state poisoned").outstanding -= 1;
                                }
                                Err(e) => {
                                    // This connection is dead too; the
                                    // orphan goes back if budget
                                    // remains.
                                    orphan(&[(slot, client)], DropReason::Disconnected);
                                    out.err = Some(e.context(format!(
                                        "reassigned upload from client {client} (slot {slot})"
                                    )));
                                    return out;
                                }
                            }
                        }
                        out
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("transport reader panicked"))
                .collect()
        });
        let absorb_ms = ms_since(wait_t0);
        if let Some(t) = &trace {
            t.span(p.round, Phase::AbsorbWait, wait_start_us, t.now_us());
        }
        let fin_start_us = trace.as_ref().map_or(0, |t| t.now_us());

        // Sweep: orphans left queued because no healthy connection
        // survived to serve them.
        drop(orphan);
        {
            let mut st = retry.lock().expect("retry state poisoned");
            while let Some((slot, _)) = st.queue.pop_front() {
                st.dropped.push((slot, DropReason::Disconnected));
                st.outstanding -= 1;
            }
            debug_assert_eq!(st.outstanding, 0);
        }
        let retry = retry.into_inner().expect("retry state poisoned");
        // Snapshot contention counters before finish/abort consume the
        // in-flight round.
        let absorb = absorber.absorb_stats();

        // Settle the membership ledger.
        let mut membership = RoundMembership::new(slots, policy.clone())?;
        let mut losses = vec![0f32; slots];
        let mut wire_up0 = 0u64;
        let mut ideal_up0 = 0u64;
        let mut sample_slot = usize::MAX;
        let mut transport_in = 0u64;
        let mut first_err: Option<anyhow::Error> = None;
        let mut dead = vec![false; nconns];
        let mut arrivals = Histogram::new();
        for (i, cr) in results.into_iter().enumerate() {
            transport_in += cr.bytes_in;
            transport_bytes += cr.bytes_out;
            if let Some(t) = &trace {
                t.conn(p.round, i, cr.io.stall_us, cr.io.read_us, cr.io.write_us);
            }
            arrivals.merge(&cr.arrivals);
            if let Some((s, frame_bytes, ideal_bytes)) = cr.byte_sample {
                if s < sample_slot {
                    sample_slot = s;
                    wire_up0 = frame_bytes;
                    ideal_up0 = ideal_bytes;
                }
            }
            for (slot, loss) in cr.pairs {
                for _ in 0..retry.retries[slot] {
                    membership.record_retry(slot);
                }
                membership.record_arrival(slot);
                losses[slot] = loss;
            }
            if let Some(e) = cr.err {
                dead[i] = true;
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        for (slot, reason) in retry.dropped {
            let slot = slot as usize;
            for _ in 0..retry.retries[slot] {
                membership.record_retry(slot);
            }
            if let Some(t) = &trace {
                t.slot_dropped(p.round, slot, drop_reason_str(reason));
            }
            membership.record_drop(slot, reason);
        }
        debug_assert!(membership.is_settled());
        transport_bytes += transport_in;

        if !membership.quorum_met() {
            // Keep the shard allocations: a faulted round must not cost
            // the next one a realloc of up to MAX_SHARDS tables.
            self.pipeline.abort(absorber);
            self.abort_round("quorum not met");
            let (arrived, target) = (membership.arrived(), membership.quorum_target());
            let e = first_err.unwrap_or_else(|| {
                anyhow!("round deadline expired with {arrived} of {slots} uploads")
            });
            return Err(e.context(format!(
                "round {}: {arrived} of {slots} uploads arrived (quorum target {target})",
                p.round
            )));
        }
        // The round closes with whoever arrived. Dead connections are
        // dropped (their workers reconnect via ensure_workers next
        // round); survivors carry the broadcast.
        if dead.iter().any(|&d| d) {
            let abort = Msg::Abort { reason: "connection faulted or straggled".into() }.encode();
            let mut keep = dead.iter().map(|&d| !d);
            for (conn, is_dead) in self.conns.iter_mut().zip(dead.iter()) {
                if *is_dead {
                    let _ = write_msg(conn, &abort);
                    conn.shutdown();
                }
            }
            self.conns.retain(|_| keep.next().unwrap());
        }
        if let Some(t) = &trace {
            t.span(p.round, Phase::Finalize, fin_start_us, t.now_us());
        }

        let reduce_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let reduce_t0 = Instant::now();
        let merged = if membership.is_full() {
            self.pipeline.finish(absorber)
        } else {
            self.pipeline.finalize_partial(absorber, &membership)
        };
        let reduce_ms = ms_since(reduce_t0);
        if let Some(t) = &trace {
            t.span(p.round, Phase::Reduce, reduce_start_us, t.now_us());
            t.histogram(Some(p.round), "slot_arrival_us", &arrivals);
        }
        let merged = match merged {
            Ok(m) => m,
            Err(e) => {
                self.abort_round("merge failed");
                return Err(e);
            }
        };
        let update = match agg.finish(&merged, p.lr) {
            Ok(u) => u,
            Err(e) => {
                self.pipeline.recycle(merged);
                self.abort_round("aggregator finish failed");
                return Err(e);
            }
        };
        self.pipeline.recycle(merged);
        let update_nnz = update.nnz();
        let download_bytes_per_client = update.payload_bytes();
        let update_frame = encode_update(&update, self.opts.codec);

        // Broadcast the update frame to every participant connection.
        let bcast_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let end_bytes = Msg::RoundEnd { round: p.round, update_frame: update_frame.clone() }
            .encode();
        let mut bcast_err = None;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            match write_msg(conn, &end_bytes) {
                Ok(n) => transport_bytes += n,
                Err(e) => {
                    bcast_err = Some(e.context(format!("broadcasting round-end to worker {i}")));
                    break;
                }
            }
        }
        if let Some(e) = bcast_err {
            // The aggregator has already advanced (momentum, error
            // sketches) — the round is lost, not replayable. Drop the
            // connections; the model vector is left un-stepped.
            self.abort_round("round-end delivery failed");
            return Err(e);
        }

        // Apply the *decoded* broadcast, exactly as wire-mode training
        // does, so lossy codecs shape the trajectory identically over
        // transport and in-process.
        let decoded = decode_update(&update_frame).context("decoding own broadcast")?;
        decoded.apply(w);
        if let Some(t) = &trace {
            t.span(p.round, Phase::Broadcast, bcast_start_us, t.now_us());
        }

        let mem = membership.summary();
        Ok(RoundStats {
            mean_loss: membership.mean_loss_over_arrived(&losses),
            losses,
            participants: mem.participants,
            dropped_slots: mem.dropped_slots,
            retried_slots: mem.retried_slots,
            update_nnz,
            upload_bytes_per_client: ideal_up0,
            download_bytes_per_client,
            wire_upload_bytes_per_client: wire_up0,
            wire_download_bytes_per_client: update_frame.len() as u64,
            transport_bytes,
            absorb_stalls: absorb.lock_stalls,
            parked_bytes: absorb.parked_bytes,
            chosen_shards: absorb.chosen_shards,
            timing: RoundTiming {
                round_ms: ms_since(round_t0),
                compute_ms: 0.0,
                absorb_ms,
                reduce_ms,
            },
            arrivals,
        })
    }

    /// One server round over a relay tier: each connected peer is a
    /// relay ([`crate::relay`]) that aggregates its own downstream
    /// workers and uploads a single merged frame for its slot chain.
    ///
    /// Chain layout: relay `r` owns slots `{s : s % R == r}` (R = relay
    /// count capped at the slot count) — the same modulo rule the round
    /// pipeline uses to map slots to shards, with the pipeline built at
    /// `shard_override = relay count`. Each merged frame is therefore
    /// absorbed into exactly the shard that would have folded those
    /// slots in a flat round, in the same in-chain order and with the
    /// same global λ weights (applied downstream, shipped in the
    /// assignment), so the tree reproduces the flat server's bits.
    ///
    /// Fault attribution is per subtree: a corrupt or inconsistent
    /// merged frame drops exactly that relay's slot chain (and its
    /// connection), never its siblings — the quorum policy decides
    /// whether the round still closes over the surviving chains.
    /// Under a retry budget (`max_slot_retries >= 1`) a dead relay's
    /// chain is first *re-offered* whole to the lowest-index surviving
    /// relay (`SubtreeAssign` repeats mid-round, protocol v4); only a
    /// chain that cannot be rescued drops.
    fn run_round_relay(
        &mut self,
        agg: &mut dyn ServerAggregator,
        p: &RoundParams<'_>,
        w: &mut [f32],
    ) -> Result<RoundStats> {
        let slots = p.participants.len();
        let nrelays = self.conns.len();
        let trace = self.opts.trace.clone();
        let round_t0 = Instant::now();
        let round_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let policy = self.opts.quorum.clone();
        let deadline = policy.round_deadline().map(|d| Instant::now() + d);
        for conn in &self.conns {
            let t = self.opts.read_timeout;
            let _ = conn.set_timeouts(Some(t), Some(t));
        }
        let lambdas = agg.begin_round(p.client_sizes);
        let spec = agg.upload_spec();
        self.absorbed.store(0, Ordering::SeqCst);

        // Slot chains: relay r owns {s : s % nchains == r}, ascending.
        // With fewer slots than relays the tail relays get empty chains
        // this round; they still receive an assignment and must reply,
        // keeping the per-round message pattern uniform.
        let nchains = nrelays.min(slots);
        let mut chains: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); nrelays];
        for (slot, &c) in p.participants.iter().enumerate() {
            let client = u32::try_from(c).context("client id exceeds u32")?;
            chains[slot % nchains].push((slot as u32, client, lambdas[slot]));
        }

        let mut transport_bytes = 0u64;
        let w_frame = encode_dense_frame(w, &F32LE);
        let mut start_err = None;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            let head = Msg::SubtreeAssign {
                round: p.round,
                round_seed: p.round_seed,
                lr: p.lr,
                codec_id: self.opts.codec.id(),
                spec: spec.clone(),
                entries: chains[i].clone(),
                weights_frame: Vec::new(),
            }
            .encode();
            match write_msg_parts(conn, &head, &w_frame) {
                Ok(n) => transport_bytes += n,
                Err(e) => {
                    start_err = Some(e.context(format!("sending subtree-assign to relay {i}")));
                    break;
                }
            }
        }
        if let Some(e) = start_err {
            self.abort_round("subtree-assign delivery failed");
            return Err(e);
        }
        if let Some(t) = &trace {
            t.span(p.round, Phase::Plan, round_start_us, t.now_us());
        }

        let mut absorber = match self.pipeline.begin(&spec, lambdas) {
            Ok(a) => a,
            Err(e) => {
                self.abort_round("round pipeline setup failed");
                return Err(e);
            }
        };
        if let Some(t) = &trace {
            absorber.attach_trace(Arc::clone(t), p.round);
        }
        let absorber = absorber;
        let max_msg = self.opts.max_msg;
        let read_timeout = self.opts.read_timeout;

        /// One relay's reply, read concurrently but *not* absorbed by
        /// the reader: merged frames fold on the sweep below, in relay
        /// order, so fault attribution is deterministic regardless of
        /// arrival interleaving (one frame per chain — there is nothing
        /// to stream).
        struct RelayRead {
            upload: Option<(u64, Vec<SlotReport>, Vec<u8>)>,
            bytes_in: u64,
            /// When the merged upload finished arriving (µs since round
            /// start; 0 when untraced or nothing arrived).
            arrival_us: u64,
            /// Protocol violation (decode failure, wrong message kind)
            /// rather than a transport fault.
            fault: bool,
            /// The round deadline had fired when the read failed.
            deadline_hit: bool,
            err: Option<anyhow::Error>,
        }
        let wait_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let wait_t0 = Instant::now();
        let results: Vec<RelayRead> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .enumerate()
                .map(|(peer, conn)| {
                    let trace = trace.as_deref();
                    s.spawn(move || -> RelayRead {
                        let mut out = RelayRead {
                            upload: None,
                            bytes_in: 0,
                            arrival_us: 0,
                            fault: false,
                            deadline_hit: false,
                            err: None,
                        };
                        let mut io = ConnIo::default();
                        if let Some(dl) = deadline {
                            let rem = dl.saturating_duration_since(Instant::now());
                            if rem.is_zero() {
                                out.deadline_hit = true;
                                out.err =
                                    Some(anyhow!("round deadline expired awaiting subtree upload"));
                                return out;
                            }
                            let t = read_timeout.min(rem);
                            let _ = conn.set_timeouts(Some(t), Some(t));
                        }
                        let read = match trace {
                            Some(_) => read_msg_timed(&mut *conn, max_msg).map(|(b, n, st, rd)| {
                                io.stall_us += st;
                                io.read_us += rd;
                                (b, n)
                            }),
                            None => read_msg(&mut *conn, max_msg),
                        };
                        match read {
                            Ok((bytes, n)) => {
                                out.bytes_in = n;
                                match Msg::decode(bytes) {
                                    Ok(Msg::SubtreeUpload { round, reports, frame }) => {
                                        if let Some(t) = trace {
                                            out.arrival_us =
                                                t.now_us().saturating_sub(round_start_us);
                                        }
                                        out.upload = Some((round, reports, frame));
                                    }
                                    Ok(other) => {
                                        out.fault = true;
                                        out.err = Some(anyhow!(
                                            "expected a subtree upload, got {}",
                                            other.kind_name()
                                        ));
                                    }
                                    Err(e) => {
                                        out.fault = true;
                                        out.err = Some(e);
                                    }
                                }
                            }
                            Err(e) => {
                                out.deadline_hit =
                                    deadline.is_some_and(|dl| Instant::now() >= dl);
                                out.err = Some(e);
                            }
                        }
                        if let Some(t) = trace {
                            t.conn(p.round, peer, io.stall_us, io.read_us, io.write_us);
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("relay reader panicked")).collect()
        });
        let absorb_ms = ms_since(wait_t0);
        if let Some(t) = &trace {
            t.span(p.round, Phase::AbsorbWait, wait_start_us, t.now_us());
        }
        let fin_start_us = trace.as_ref().map_or(0, |t| t.now_us());

        // Sweep in relay order: validate each reply against its chain,
        // absorb the merged frame, then roll the subtree's per-slot
        // outcomes into the root membership ledger. A failure anywhere
        // drops exactly that chain — reason Faulted for bad content,
        // Disconnected/Deadline for transport faults.
        let mut membership = RoundMembership::new(slots, policy.clone())?;
        let mut losses = vec![0f32; slots];
        let mut wire_up0 = 0u64;
        let mut ideal_up0 = 0u64;
        let mut have_sample = false;
        let mut transport_in = 0u64;
        let mut first_err: Option<anyhow::Error> = None;
        let mut dead = vec![false; nrelays];
        let mut failed: Vec<(usize, DropReason)> = Vec::new();
        let mut arrivals = Histogram::new();
        for (r, rr) in results.into_iter().enumerate() {
            let RelayRead { upload, bytes_in, arrival_us, fault, deadline_hit, err } = rr;
            transport_in += bytes_in;
            let failure = match upload {
                Some((round, reports, frame)) => {
                    match absorb_chain(&absorber, r, &chains[r], round, p.round, &reports, &frame)
                    {
                        Ok(()) => {
                            self.absorbed.fetch_max(absorber.absorbed(), Ordering::SeqCst);
                            roll_up(&mut membership, &mut losses, &reports, false);
                            if trace.is_some() {
                                arrivals.record(arrival_us);
                            }
                            if !frame.is_empty() && !have_sample {
                                // The root link carries one merged frame
                                // per chain regardless of downstream
                                // fan-out; sample the first.
                                have_sample = true;
                                wire_up0 = frame.len() as u64;
                                if let Ok(f) = Frame::parse(&frame) {
                                    ideal_up0 = idealized_payload(&f);
                                }
                            }
                            None
                        }
                        Err(e) => Some((
                            e.context(format!("subtree upload from relay {r}")),
                            DropReason::Faulted,
                        )),
                    }
                }
                None => {
                    let reason = if fault {
                        DropReason::Faulted
                    } else if deadline_hit {
                        DropReason::Deadline
                    } else {
                        DropReason::Disconnected
                    };
                    let e = err.unwrap_or_else(|| anyhow!("relay sent no subtree upload"));
                    Some((e.context(format!("subtree upload from relay {r}")), reason))
                }
            };
            if let Some((e, reason)) = failure {
                dead[r] = true;
                failed.push((r, reason));
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }

        // Mid-round chain re-assignment: a dead relay's chain is
        // untouched (`absorb_chain` is all-or-nothing), so under a
        // retry budget the whole chain is re-offered to the first
        // surviving relay with a fresh `SubtreeAssign` for the same
        // round — the survivor serves it like any other assignment and
        // answers a second `SubtreeUpload`. The survivor choice is
        // deterministic (lowest live index), so a run that loses the
        // same relay reproduces the same bits. A chain that cannot be
        // rescued — no survivors, no retry budget, deadline expired,
        // or the re-offer itself fails — drops with the original
        // fault's reason (fault containment unchanged).
        for (r, reason) in failed {
            let assigned = &chains[r];
            let mut rescued = false;
            if !assigned.is_empty()
                && policy.max_slot_retries() >= 1
                && !deadline.is_some_and(|dl| Instant::now() >= dl)
            {
                if let Some(s) = (0..nrelays).find(|&i| !dead[i]) {
                    if let Some(t) = &trace {
                        for &(slot, _, _) in assigned {
                            t.slot_event(p.round, slot as usize, SlotEvent::Reassigned, Some(s));
                        }
                    }
                    match reoffer_chain(
                        &mut self.conns[s],
                        &absorber,
                        r,
                        assigned,
                        p,
                        &spec,
                        self.opts.codec.id(),
                        &w_frame,
                        max_msg,
                        read_timeout,
                        deadline,
                    ) {
                        Ok((reports, frame, n)) => {
                            transport_in += n;
                            self.absorbed.fetch_max(absorber.absorbed(), Ordering::SeqCst);
                            // The re-offer charges one retry on every
                            // slot of the chain, on top of whatever the
                            // replacement subtree reports.
                            roll_up(&mut membership, &mut losses, &reports, true);
                            if !frame.is_empty() && !have_sample {
                                have_sample = true;
                                wire_up0 = frame.len() as u64;
                                if let Ok(f) = Frame::parse(&frame) {
                                    ideal_up0 = idealized_payload(&f);
                                }
                            }
                            rescued = true;
                        }
                        Err(e) => {
                            // The survivor faulted mid-re-offer: its own
                            // chain is already absorbed (those slots
                            // stand), but the connection is desynced —
                            // drop it with the rescue.
                            dead[s] = true;
                            if first_err.is_none() {
                                first_err =
                                    Some(e.context(format!("re-offering chain {r} to relay {s}")));
                            }
                        }
                    }
                }
            }
            if !rescued {
                // Fault containment: only this subtree's slots drop.
                for &(slot, _, _) in assigned {
                    membership.record_drop(slot as usize, reason);
                    if let Some(t) = &trace {
                        t.slot_dropped(p.round, slot as usize, drop_reason_str(reason));
                    }
                }
            }
        }
        debug_assert!(membership.is_settled());
        transport_bytes += transport_in;
        let absorb = absorber.absorb_stats();

        if !membership.quorum_met() {
            self.pipeline.abort(absorber);
            self.abort_round("quorum not met");
            let (arrived, target) = (membership.arrived(), membership.quorum_target());
            let e = first_err.unwrap_or_else(|| {
                anyhow!("round deadline expired with {arrived} of {slots} uploads")
            });
            return Err(e.context(format!(
                "round {}: {arrived} of {slots} uploads arrived (quorum target {target})",
                p.round
            )));
        }
        // The round closes with the surviving subtrees. Dead relay
        // connections are dropped (they reconnect via ensure_workers
        // next round); survivors carry the broadcast down their trees.
        if dead.iter().any(|&d| d) {
            let abort = Msg::Abort { reason: "subtree faulted or straggled".into() }.encode();
            let mut keep = dead.iter().map(|&d| !d);
            for (conn, is_dead) in self.conns.iter_mut().zip(dead.iter()) {
                if *is_dead {
                    let _ = write_msg(conn, &abort);
                    conn.shutdown();
                }
            }
            self.conns.retain(|_| keep.next().unwrap());
        }
        if let Some(t) = &trace {
            t.span(p.round, Phase::Finalize, fin_start_us, t.now_us());
        }

        let reduce_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let reduce_t0 = Instant::now();
        let merged = if membership.is_full() {
            self.pipeline.finish(absorber)
        } else {
            self.pipeline.finalize_partial(absorber, &membership)
        };
        let reduce_ms = ms_since(reduce_t0);
        if let Some(t) = &trace {
            t.span(p.round, Phase::Reduce, reduce_start_us, t.now_us());
            t.histogram(Some(p.round), "slot_arrival_us", &arrivals);
        }
        let merged = match merged {
            Ok(m) => m,
            Err(e) => {
                self.abort_round("merge failed");
                return Err(e);
            }
        };
        let update = match agg.finish(&merged, p.lr) {
            Ok(u) => u,
            Err(e) => {
                self.pipeline.recycle(merged);
                self.abort_round("aggregator finish failed");
                return Err(e);
            }
        };
        self.pipeline.recycle(merged);
        let update_nnz = update.nnz();
        let download_bytes_per_client = update.payload_bytes();
        let update_frame = encode_update(&update, self.opts.codec);

        // Broadcast round-end to the surviving relays; each forwards it
        // verbatim down to its own workers.
        let bcast_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let end_bytes =
            Msg::RoundEnd { round: p.round, update_frame: update_frame.clone() }.encode();
        let mut bcast_err = None;
        for (i, conn) in self.conns.iter_mut().enumerate() {
            match write_msg(conn, &end_bytes) {
                Ok(n) => transport_bytes += n,
                Err(e) => {
                    bcast_err = Some(e.context(format!("broadcasting round-end to relay {i}")));
                    break;
                }
            }
        }
        if let Some(e) = bcast_err {
            self.abort_round("round-end delivery failed");
            return Err(e);
        }

        let decoded = decode_update(&update_frame).context("decoding own broadcast")?;
        decoded.apply(w);
        if let Some(t) = &trace {
            t.span(p.round, Phase::Broadcast, bcast_start_us, t.now_us());
        }

        let mem = membership.summary();
        Ok(RoundStats {
            mean_loss: membership.mean_loss_over_arrived(&losses),
            losses,
            participants: mem.participants,
            dropped_slots: mem.dropped_slots,
            retried_slots: mem.retried_slots,
            update_nnz,
            upload_bytes_per_client: ideal_up0,
            download_bytes_per_client,
            wire_upload_bytes_per_client: wire_up0,
            wire_download_bytes_per_client: update_frame.len() as u64,
            transport_bytes,
            absorb_stalls: absorb.lock_stalls,
            parked_bytes: absorb.parked_bytes,
            chosen_shards: absorb.chosen_shards,
            timing: RoundTiming {
                round_ms: ms_since(round_t0),
                compute_ms: 0.0,
                absorb_ms,
                reduce_ms,
            },
            arrivals,
        })
    }

    /// Fail the in-flight round: best-effort `Abort` to every worker,
    /// then drop all connections. Scratch and listener stay.
    fn abort_round(&mut self, reason: &str) {
        let bytes = Msg::Abort { reason: reason.to_string() }.encode();
        for conn in &mut self.conns {
            let _ = write_msg(conn, &bytes);
            conn.shutdown();
        }
        self.conns.clear();
    }

    /// End training: tell every worker to disconnect cleanly.
    pub fn shutdown(&mut self) {
        let bytes = Msg::Shutdown.encode();
        for conn in &mut self.conns {
            let _ = write_msg(conn, &bytes);
            conn.shutdown();
        }
        self.conns.clear();
    }
}

impl Drop for RoundServer {
    fn drop(&mut self) {
        self.shutdown();
        #[cfg(unix)]
        if let Some(p) = &self.uds_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Stable wire label for a [`DropReason`], used in trace `slot` events
/// (`event: "dropped"`, `reason: ...`) across every tier.
pub(crate) fn drop_reason_str(r: DropReason) -> &'static str {
    match r {
        DropReason::Faulted => "faulted",
        DropReason::Disconnected => "disconnect",
        DropReason::Deadline => "deadline",
    }
}

/// What one successfully absorbed upload reports back to the reader
/// loop.
struct UploadRead {
    loss: f32,
    bytes_in: u64,
    /// Measured `FSGW` frame bytes of this upload.
    frame_bytes: u64,
    /// Idealized payload bytes of this upload.
    ideal_bytes: u64,
}

/// Read, validate, and absorb one upload from `conn`. `expect_slot` is
/// the next slot this connection owes (clients deliver their assignment
/// list in order, so anything else is a protocol violation). The frame
/// is offered to the shared absorber *immediately*, borrowed straight
/// from the transport read buffer — this is the zero-copy
/// streaming-absorb path; the absorber validates before taking any
/// lock and copies the bytes out only if an earlier slot of the same
/// shard is still outstanding.
#[allow(clippy::too_many_arguments)]
fn read_one_upload(
    conn: &mut Conn,
    expect_slot: u32,
    max_msg: usize,
    want_ideal: bool,
    absorber: &RoundInFlight,
    probe: &AtomicUsize,
    mut trace: Option<(ConnTrace<'_>, &mut ConnIo)>,
) -> Result<UploadRead> {
    // Traced reads split the blocking wait from the body transfer (two
    // extra clock reads); the untraced arm is byte-for-byte `read_msg`.
    let (bytes, bytes_in) = match trace.as_mut() {
        Some((_, io)) => {
            let (b, n, stall, rd) = read_msg_timed(conn, max_msg)?;
            io.stall_us += stall;
            io.read_us += rd;
            (b, n)
        }
        None => read_msg(conn, max_msg)?,
    };
    let (slot, loss, frame) = match Msg::decode(bytes)? {
        Msg::Upload { slot, loss, frame } => (slot, loss, frame),
        other => bail!("expected an upload message, got {}", other.kind_name()),
    };
    if slot != expect_slot {
        bail!("upload for slot {slot}, but slot {expect_slot} is next on this connection");
    }
    let frame_bytes = frame.len() as u64;
    // Byte accounting samples one upload per round (all of a strategy's
    // uploads are the same size); the caller asks for the idealized
    // number only when this read improves its lowest-slot sample, so
    // the other slots don't pay an extra full parse.
    let ideal_bytes = if want_ideal { idealized_payload(&Frame::parse(&frame)?) } else { 0 };
    if let Some((ct, _)) = &trace {
        ct.sink.slot_event(ct.round, slot as usize, SlotEvent::Offered, Some(ct.peer));
    }
    absorber.offer_frame_bytes(slot as usize, &frame)?;
    // `fetch_max`, not `store`: another reader may have raced a later
    // snapshot in — the probe is monotone within a round.
    probe.fetch_max(absorber.absorbed(), Ordering::SeqCst);
    Ok(UploadRead { loss, bytes_in, frame_bytes, ideal_bytes })
}

/// Validate one relay's `SubtreeUpload` against its assigned chain and
/// absorb the merged frame. The reports must cover the assigned slots
/// exactly, in order (the assignment is ascending, so equality implies
/// ascending coverage); the merged frame must be present iff at least
/// one slot arrived. Any violation — including a frame the in-flight
/// round rejects (bad geometry, lossy codec, wrong chain) — is a
/// `Faulted` verdict for the whole chain; nothing is partially
/// absorbed (`offer_chain_frame` is all-or-nothing).
fn absorb_chain(
    absorber: &RoundInFlight,
    chain: usize,
    assigned: &[(u32, u32, f32)],
    round: u64,
    expect_round: u64,
    reports: &[SlotReport],
    frame: &[u8],
) -> Result<()> {
    if round != expect_round {
        bail!("subtree upload for round {round}, expected round {expect_round}");
    }
    if reports.len() != assigned.len() {
        bail!("{} slot report(s) for a {}-slot chain", reports.len(), assigned.len());
    }
    for (rep, &(slot, _, _)) in reports.iter().zip(assigned) {
        if rep.slot != slot {
            bail!("report for slot {}, expected slot {slot}", rep.slot);
        }
        if rep.outcome > OUTCOME_DROPPED_DEADLINE {
            bail!("unknown slot outcome {} for slot {slot}", rep.outcome);
        }
    }
    let arrived: Vec<usize> = reports
        .iter()
        .filter(|rep| rep.outcome == OUTCOME_ARRIVED)
        .map(|rep| rep.slot as usize)
        .collect();
    if arrived.is_empty() != frame.is_empty() {
        bail!(
            "merged frame presence ({} bytes) disagrees with {} arrived report(s)",
            frame.len(),
            arrived.len()
        );
    }
    if !arrived.is_empty() {
        absorber.offer_chain_frame(chain, &arrived, frame)?;
    }
    Ok(())
}

/// Roll one chain's `SlotReport`s into the root membership ledger.
/// `reoffered` charges one extra retry per slot first — the cost of a
/// mid-round chain re-assignment, on top of whatever the subtree
/// itself reports (downstream retries were real work even when a slot
/// ultimately dropped).
fn roll_up(
    membership: &mut RoundMembership,
    losses: &mut [f32],
    reports: &[SlotReport],
    reoffered: bool,
) {
    for rep in reports {
        let slot = rep.slot as usize;
        if reoffered {
            membership.record_retry(slot);
        }
        match rep.outcome {
            OUTCOME_ARRIVED => {
                membership.record_report(
                    slot,
                    if rep.retries > 0 {
                        SlotOutcome::Retried(rep.retries as usize)
                    } else {
                        SlotOutcome::Arrived
                    },
                );
                losses[slot] = rep.loss;
            }
            outcome => {
                for _ in 0..rep.retries {
                    membership.record_retry(slot);
                }
                let reason = match outcome {
                    OUTCOME_DROPPED_FAULTED => DropReason::Faulted,
                    OUTCOME_DROPPED_DISCONNECTED => DropReason::Disconnected,
                    _ => DropReason::Deadline,
                };
                membership.record_report(slot, SlotOutcome::Dropped(reason));
            }
        }
    }
}

/// Re-offer a dead relay's whole slot chain to a surviving relay,
/// mid-round: a fresh `SubtreeAssign` for the same round (protocol v4
/// allows repeats), one `SubtreeUpload` back, validated and absorbed
/// like the original would have been. Returns the replacement reports,
/// the merged frame, and the bytes moved. Any failure leaves the chain
/// untouched (`absorb_chain` is all-or-nothing) so the caller can
/// still drop it cleanly.
#[allow(clippy::too_many_arguments)]
fn reoffer_chain(
    conn: &mut Conn,
    absorber: &RoundInFlight,
    chain: usize,
    assigned: &[(u32, u32, f32)],
    p: &RoundParams<'_>,
    spec: &UploadSpec,
    codec_id: u8,
    w_frame: &[u8],
    max_msg: usize,
    read_timeout: Duration,
    deadline: Option<Instant>,
) -> Result<(Vec<SlotReport>, Vec<u8>, u64)> {
    let mut bytes = 0u64;
    if let Some(dl) = deadline {
        let rem = dl.saturating_duration_since(Instant::now());
        if rem.is_zero() {
            bail!("round deadline expired before the chain could be re-offered");
        }
        let t = read_timeout.min(rem);
        let _ = conn.set_timeouts(Some(t), Some(t));
    }
    let head = Msg::SubtreeAssign {
        round: p.round,
        round_seed: p.round_seed,
        lr: p.lr,
        codec_id,
        spec: spec.clone(),
        entries: assigned.to_vec(),
        weights_frame: Vec::new(),
    }
    .encode();
    bytes += write_msg_parts(conn, &head, w_frame)?;
    let (msg, n) = read_msg(conn, max_msg)?;
    bytes += n;
    let (round, reports, frame) = match Msg::decode(msg)? {
        Msg::SubtreeUpload { round, reports, frame } => (round, reports, frame),
        other => bail!("expected a subtree upload, got {}", other.kind_name()),
    };
    absorb_chain(absorber, chain, assigned, round, p.round, &reports, &frame)?;
    Ok((reports, frame, bytes))
}

/// Server side of the hello handshake: the peer must lead with a
/// matching-version `Hello` (flat mode) or `RelayHello` (relay mode)
/// within the read deadline. The tiers are deliberately not
/// interchangeable — a worker dialing a relay-mode root (or a relay
/// dialing a flat server) is a topology misconfiguration and fails
/// here, before any round state exists.
pub(crate) fn handshake(conn: &mut Conn, max_msg: usize, relay: bool) -> Result<()> {
    let (bytes, _) = read_msg(conn, max_msg)?;
    match (Msg::decode(bytes)?, relay) {
        (Msg::Hello { version }, false) | (Msg::RelayHello { version }, true)
            if version == PROTO_VERSION =>
        {
            Ok(())
        }
        (Msg::Hello { version }, false) | (Msg::RelayHello { version }, true) => {
            bail!("peer speaks transport protocol v{version}, this build speaks v{PROTO_VERSION}")
        }
        (other, true) => bail!("expected relay-hello, got {} message", other.kind_name()),
        (other, false) => bail!("expected hello, got {} message", other.kind_name()),
    }
}

/// Idealized (paper footnote-5) payload bytes of a parsed frame:
/// 4 bytes per encoded value, regardless of codec or index overhead.
fn idealized_payload(frame: &Frame<'_>) -> u64 {
    let n = match &frame.body {
        Body::Sketch { values, .. } => values.len(),
        Body::Sparse { values, .. } => values.len(),
        Body::Dense { values, .. } => values.len(),
    };
    4 * n as u64
}

/// Outcome of a served training run (`fetchsgd serve`).
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub strategy: String,
    pub task: String,
    pub rounds: usize,
    /// Mean training loss over the last 10 rounds.
    pub final_loss: f64,
    /// Idealized totals (paper convention), all clients and rounds.
    pub upload_bytes: u64,
    pub download_bytes: u64,
    /// Measured `FSGW` frame totals.
    pub wire_upload_bytes: u64,
    pub wire_download_bytes: u64,
    /// Measured on-the-wire totals including framing and control
    /// messages — what the sockets actually carried.
    pub transport_bytes: u64,
    /// Planned slots dropped across the run (quorum rounds).
    pub dropped_slots: u64,
    /// Slots that needed at least one retry/reassignment.
    pub retried_slots: u64,
    /// Shard-lock stalls across the run (see
    /// [`RoundStats::absorb_stalls`]).
    pub absorb_stalls: u64,
    /// Frame bytes parked out of order across the run (see
    /// [`RoundStats::parked_bytes`]).
    pub parked_bytes: u64,
    /// Wall-clock totals accumulated over every round (always measured;
    /// `compute_ms` stays 0 — client compute happens remotely).
    pub timing: RoundTiming,
    /// Upload-arrival latency percentiles over the whole run, in
    /// milliseconds since each round's start. Zero unless a trace sink
    /// was attached (arrival stamps are traced-only).
    pub arrival_p50_ms: f64,
    pub arrival_p90_ms: f64,
    pub arrival_p99_ms: f64,
}

/// Validate a configured serve deadline: finite, strictly positive,
/// representable seconds (the socket layer treats zero as "no
/// deadline", which would silently disable fault containment, and
/// `Duration::from_secs_f64` panics on out-of-range floats).
pub(crate) fn duration_from_cfg_secs(secs: f64, knob: &str) -> Result<Duration> {
    if !secs.is_finite() || secs <= 0.0 {
        bail!("{knob} must be a positive number of seconds, got {secs}");
    }
    Duration::try_from_secs_f64(secs)
        .with_context(|| format!("{knob}: {secs} seconds is out of range"))
}

/// Serve a full training run over `cfg.transport`: the server half of
/// `fetchsgd train`, with remote workers doing the client compute via
/// [`crate::transport::client::join`] / `fetchsgd join`.
///
/// Round seeds, client selection, aggregation order, and the broadcast
/// round-trip all match the in-process `Trainer` exactly, so a served
/// run is bitwise identical to `fetchsgd train` on the same config
/// (under a lossless upload codec). Evaluation is not run here — score
/// the resulting metrics log or weights offline.
pub fn serve_training(cfg: &crate::config::TrainConfig) -> Result<ServeSummary> {
    use crate::compression::accounting::CommStats;
    use crate::coordinator::{build_strategy, ClientSelector};
    use crate::metrics::{MetricsLogger, RoundRecord, SummaryRecord};
    use crate::model::build_dataset;
    use crate::runtime::artifact::{Manifest, TaskArtifacts};
    use crate::runtime::Runtime;
    use crate::util::rng::derive_seed;

    let spec = cfg
        .transport
        .as_deref()
        .context("serve mode needs a transport endpoint (transport=tcp:HOST:PORT | uds:/path)")?;
    let ep = Endpoint::parse(spec)?;
    let codec: &'static dyn Codec = match &cfg.wire {
        Some(name) => crate::wire::codec_by_name(name).context("TrainConfig.wire")?,
        None => &F32LE,
    };
    let runtime = std::sync::Arc::new(Runtime::cpu().context("PJRT runtime")?);
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let artifacts = TaskArtifacts::new(runtime, &manifest, &cfg.task)?;
    let (_client, mut agg) = build_strategy(cfg, &artifacts)?;
    let dataset = build_dataset(&artifacts.manifest, &cfg.scale)?;
    let selector = ClientSelector::new(dataset.num_clients(), cfg.clients_per_round, cfg.seed);
    let mut logger = MetricsLogger::new(cfg.log_path.as_deref())?;
    let mut w = artifacts.init_weights()?;
    let trace = match cfg.trace_path.as_deref() {
        Some(p) => Some(std::sync::Arc::new(
            crate::trace::TraceSink::create(p, "root", spec).context("TrainConfig.trace_path")?,
        )),
        None => None,
    };

    let opts = ServeOptions {
        workers: cfg.transport_workers,
        codec,
        read_timeout: duration_from_cfg_secs(cfg.serve_read_timeout_s, "serve_read_timeout_s")?,
        accept_timeout: duration_from_cfg_secs(
            cfg.serve_accept_timeout_s,
            "serve_accept_timeout_s",
        )?,
        max_msg: crate::transport::effective_max_msg(cfg, artifacts.manifest.dim)?,
        reduce_parallelism: cfg.reduce_parallelism,
        quorum: cfg.quorum_policy()?,
        shards: cfg.shards,
        shard_tiers: cfg.shard_tiers.clone(),
        relay_children: cfg.relay_children,
        adaptive_shards: cfg.adaptive_shards,
        pin_shards: cfg.pin_shards,
        trace: trace.clone(),
    };
    let mut server = RoundServer::bind(&ep, opts)?;
    if cfg.relay_children > 0 {
        eprintln!(
            "[serve] listening on {} for {} relay(s), strategy={}",
            server.local_endpoint()?,
            cfg.relay_children,
            agg.name()
        );
    } else {
        eprintln!(
            "[serve] listening on {} for {} worker(s), strategy={}",
            server.local_endpoint()?,
            cfg.transport_workers,
            agg.name()
        );
    }
    let mut comm = CommStats::default();
    let mut transport_bytes = 0u64;
    let mut dropped_slots = 0u64;
    let mut retried_slots = 0u64;
    let mut absorb_stalls = 0u64;
    let mut parked_bytes = 0u64;
    let mut timing = RoundTiming::default();
    let mut arrivals = Histogram::new();
    for round in 0..cfg.rounds {
        let lr = cfg.lr.at(round, cfg.rounds);
        let plan = crate::cohort::CohortPlan::sample(&selector, dataset.as_ref(), round);
        // Same derivation as Trainer::step — a served run replays the
        // exact in-process trajectory for the same config.
        let round_seed = derive_seed(cfg.seed ^ 0xB0B0, round as u64);
        let params = RoundParams {
            round: round as u64,
            round_seed,
            lr,
            participants: &plan.participants,
            client_sizes: &plan.sizes,
        };
        let stats = server
            .run_round(agg.as_mut(), &params, &mut w)
            .with_context(|| format!("round {round}"))?;
        transport_bytes += stats.transport_bytes;
        dropped_slots += stats.dropped_slots as u64;
        retried_slots += stats.retried_slots as u64;
        absorb_stalls += stats.absorb_stalls;
        parked_bytes += stats.parked_bytes;
        timing.accumulate(&stats.timing);
        arrivals.merge(&stats.arrivals);
        comm.record_round(
            stats.participants,
            stats.upload_bytes_per_client,
            stats.download_bytes_per_client,
            0,
            stats.wire_upload_bytes_per_client,
            stats.wire_download_bytes_per_client,
        );
        let n = stats.participants as u64;
        logger.log_round(RoundRecord {
            round,
            loss: stats.mean_loss,
            lr: lr as f64,
            upload_bytes: stats.upload_bytes_per_client * n,
            download_bytes: stats.download_bytes_per_client * n,
            wire_upload_bytes: stats.wire_upload_bytes_per_client * n,
            wire_download_bytes: stats.wire_download_bytes_per_client * n,
            transport_bytes: stats.transport_bytes,
            absorb_stalls: stats.absorb_stalls,
            parked_bytes: stats.parked_bytes,
            chosen_shards: stats.chosen_shards as usize,
            participants: stats.participants,
            dropped_slots: stats.dropped_slots,
            retried_slots: stats.retried_slots,
            update_nnz: stats.update_nnz,
            round_ms: stats.timing.round_ms,
            compute_ms: stats.timing.compute_ms,
            absorb_ms: stats.timing.absorb_ms,
            reduce_ms: stats.timing.reduce_ms,
            tier: if cfg.relay_children > 0 { Some("root") } else { None },
        });
        if cfg.verbose {
            eprintln!(
                "[serve] round {round:>4} loss {:.4} lr {lr:.4} nnz {} wire {} B cohort {}/{}",
                stats.mean_loss,
                stats.update_nnz,
                stats.transport_bytes,
                stats.participants,
                plan.slots()
            );
        }
    }
    server.shutdown();
    let final_loss = logger.recent_loss(10);
    let arrival_p50_ms = arrivals.percentile(0.50) as f64 / 1e3;
    let arrival_p90_ms = arrivals.percentile(0.90) as f64 / 1e3;
    let arrival_p99_ms = arrivals.percentile(0.99) as f64 / 1e3;
    logger.log_summary(&SummaryRecord {
        strategy: agg.name().to_string(),
        task: cfg.task.clone(),
        rounds: cfg.rounds,
        final_loss,
        upload_bytes: comm.upload_bytes,
        download_bytes: comm.download_bytes,
        dropped_slots,
        retried_slots,
        round_ms: timing.round_ms,
        compute_ms: timing.compute_ms,
        absorb_ms: timing.absorb_ms,
        reduce_ms: timing.reduce_ms,
        arrival_p50_ms,
        arrival_p90_ms,
        arrival_p99_ms,
    });
    logger.flush()?;
    if let Some(t) = &trace {
        // Per-round `hist` events already merge bucket-exactly to the
        // run total; a run-level duplicate would double-fold.
        t.flush().context("flushing trace")?;
    }
    Ok(ServeSummary {
        strategy: agg.name().to_string(),
        task: cfg.task.clone(),
        rounds: cfg.rounds,
        final_loss,
        upload_bytes: comm.upload_bytes,
        download_bytes: comm.download_bytes,
        wire_upload_bytes: comm.wire_upload_bytes,
        wire_download_bytes: comm.wire_download_bytes,
        transport_bytes,
        dropped_slots,
        retried_slots,
        absorb_stalls,
        parked_bytes,
        timing,
        arrival_p50_ms,
        arrival_p90_ms,
        arrival_p99_ms,
    })
}
