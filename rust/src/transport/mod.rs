//! Serving `FSGW` frames over a real transport (TCP / Unix-domain
//! sockets).
//!
//! FetchSGD's deployment story — stateless clients, all momentum and
//! error feedback carried server-side in mergeable Count Sketches —
//! only holds up if uploads actually cross a process boundary. The
//! [`crate::wire`] module (PR 2) defined the framed byte grammar and
//! the byte-level absorb path; this module puts a socket under it:
//!
//! - [`server::RoundServer`] — binds TCP or UDS, accepts a fixed pool
//!   of worker connections, fans each round's participant slots out
//!   over them, validates every incoming upload frame against the
//!   round's `UploadSpec`, and **streams frames into the shard
//!   accumulator pool as they arrive** via the shared
//!   [`crate::compression::aggregate::RoundPipeline`] (the same fan-in
//!   the in-process engine drives) — no barrier waits for the whole
//!   cohort, and a straggler only delays its own shard's later slots.
//!   The resulting `RoundUpdate` frame is broadcast back to every
//!   participant.
//! - [`client::join`] — drives any [`crate::compression::ClientCompute`]
//!   over a socket: receives round assignments plus the current weights
//!   as a dense frame, runs the client compute for each assigned slot,
//!   and uploads the encoded frames.
//! - [`framing`] — length-prefixed message framing with an explicit
//!   message-size cap, so a forged length prefix is rejected before any
//!   allocation.
//! - [`proto`] — the small control grammar (hello / round-start /
//!   upload / round-end / abort / shutdown, plus the v3 relay messages
//!   relay-hello / subtree-assign / subtree-upload) wrapped around
//!   `FSGW` payload frames.
//!
//! ## Tree aggregation (the relay tier)
//!
//! A [`RoundServer`] in relay mode (`ServeOptions::relay_children > 0`)
//! aggregates over mid-tier [`crate::relay`] nodes instead of workers:
//! each relay greets with `relay-hello`, receives its slot *chain* as a
//! `subtree-assign` (global slot ids, client ids, and **global**
//! aggregation weights λ), folds its own downstream workers' uploads
//! through the shared `RoundPipeline`, and answers with exactly one
//! `subtree-upload` — a merged lossless `f32le` frame plus a per-slot
//! outcome roll-up the root folds into its membership accounting. The
//! root link therefore carries one upload-sized frame per relay per
//! round *regardless of downstream fan-out*. The root pins one shard
//! chain per relay (slot `s` belongs to relay `s mod R` — the same
//! layout a flat server uses with `shards = R`), each tier folds in
//! ascending slot order, and renormalization over the arrived subset
//! happens once at the root, so a two-level tree is bitwise identical
//! to the flat server and the in-process engine over the same
//! surviving membership set. Enforced by
//! `rust/tests/relay_determinism.rs`.
//!
//! ## Determinism
//!
//! A transport round is bitwise identical to the in-process engine at
//! any parallelism: both drive the *same* `aggregate::RoundPipeline` —
//! one shard layout (`aggregate::shard_of`), in-shard slot order (early
//! frames are parked as bytes until their turn), shard-order row-strip
//! reduction — and the broadcast round-trips encode→decode exactly as
//! wire mode does. Weights are always sent
//! losslessly (`f32le`) regardless of the upload codec. Enforced by
//! `rust/tests/transport_determinism.rs`.
//!
//! ## Fault containment
//!
//! Per-connection read/write deadlines bound how long a stalled or
//! malicious peer can hold a round open; frame validation (magic,
//! version, geometry, seed, index bounds) plus slot bookkeeping
//! (range, duplicates, per-connection order) mean a bad peer fails the
//! round *loudly* without an accumulator ever being scribbled — the
//! server drops the round's connections, keeps its scratch pool, and
//! is immediately reusable for the next round. Enforced by
//! `rust/tests/transport_faults.rs`.
//!
//! ## Partial-cohort rounds
//!
//! With a tolerant `cohort::QuorumPolicy` (`quorum_fraction` /
//! `round_deadline_ms` / `max_slot_retries`), a fault no longer aborts
//! the round: the lost worker's slots are reassigned to healthy
//! connections mid-round (`SlotAssign`), stragglers past the deadline
//! are dropped, and the round closes at quorum with weights
//! renormalized over the actual participants — FetchSGD's sparse-
//! participation story served over a real socket. Enforced by
//! `rust/tests/cohort_quorum.rs` and `transport_straggler.rs`.

pub mod client;
pub mod framing;
pub mod proto;
pub mod server;

pub use client::{join, join_training, JoinOptions, JoinSummary};
pub use server::{serve_training, RoundParams, RoundServer, RoundStats, ServeOptions, ServeSummary};

use anyhow::{bail, Context, Result};
use std::fmt;

/// The per-message size cap both sides of a serve/join deployment use:
/// `cfg.serve_max_msg` when set, otherwise auto-sized so the biggest
/// legitimate message — the round-start's ~4·dim-byte lossless weights
/// frame plus an 8-byte-per-slot assignment table — clears it with
/// slack for headers. One formula, called by `serve_training` and
/// `join_training`, so the two caps cannot drift apart. An explicit cap
/// smaller than that round-start floor is a config error here, at
/// startup — not a confusing per-round oversize-frame abort that blames
/// the peer.
pub(crate) fn effective_max_msg(cfg: &crate::config::TrainConfig, dim: usize) -> Result<usize> {
    let floor = 4 * dim + 8 * cfg.clients_per_round + (1 << 12);
    if cfg.serve_max_msg == 0 {
        return Ok(framing::DEFAULT_MAX_MSG_BYTES.max(floor));
    }
    if cfg.serve_max_msg < floor {
        bail!(
            "serve_max_msg={} is below the {floor}-byte round-start frame this model needs \
             (4*dim + 8*clients_per_round + header slack); every round would abort as oversize",
            cfg.serve_max_msg
        );
    }
    Ok(cfg.serve_max_msg)
}
use std::io::{Read, Write};
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
#[cfg(unix)]
use std::path::PathBuf;
use std::time::Duration;

/// A transport endpoint: where a server listens / a client connects.
///
/// Textual form (the `TrainConfig.transport` knob and the CLI
/// `--listen`/`--connect` flags): `tcp:HOST:PORT` or `uds:/path.sock`
/// (alias `unix:`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP socket address, e.g. `127.0.0.1:7070` (port 0 = ephemeral).
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Unix(PathBuf),
}

impl Endpoint {
    /// Parse `tcp:HOST:PORT` | `uds:PATH` | `unix:PATH`.
    pub fn parse(s: &str) -> Result<Endpoint> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            if addr.is_empty() {
                bail!("empty tcp endpoint address");
            }
            return Ok(Endpoint::Tcp(addr.to_string()));
        }
        if let Some(path) = s.strip_prefix("uds:").or_else(|| s.strip_prefix("unix:")) {
            if path.is_empty() {
                bail!("empty unix socket path");
            }
            #[cfg(unix)]
            {
                return Ok(Endpoint::Unix(PathBuf::from(path)));
            }
            #[cfg(not(unix))]
            bail!("unix-domain sockets are unavailable on this platform");
        }
        bail!("transport endpoint '{s}' must be tcp:HOST:PORT or uds:/path.sock")
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Tcp(addr) => write!(f, "tcp:{addr}"),
            #[cfg(unix)]
            Endpoint::Unix(path) => write!(f, "uds:{}", path.display()),
        }
    }
}

enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

/// One bidirectional transport connection (either family), with
/// socket-level read/write deadlines.
pub struct Conn {
    stream: Stream,
}

impl Conn {
    /// Connect to a server endpoint (blocking).
    pub fn connect(ep: &Endpoint) -> Result<Conn> {
        let stream = match ep {
            Endpoint::Tcp(addr) => {
                let s = TcpStream::connect(addr.as_str())
                    .with_context(|| format!("connecting to tcp:{addr}"))?;
                s.set_nodelay(true).ok();
                Stream::Tcp(s)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                let s = UnixStream::connect(path)
                    .with_context(|| format!("connecting to uds:{}", path.display()))?;
                Stream::Unix(s)
            }
        };
        Ok(Conn { stream })
    }

    pub(crate) fn from_tcp(s: TcpStream) -> Conn {
        s.set_nodelay(true).ok();
        Conn { stream: Stream::Tcp(s) }
    }

    #[cfg(unix)]
    pub(crate) fn from_unix(s: UnixStream) -> Conn {
        Conn { stream: Stream::Unix(s) }
    }

    /// Ensure blocking mode (accepted sockets may inherit the
    /// listener's non-blocking flag on some platforms).
    pub(crate) fn set_blocking(&self) -> Result<()> {
        match &self.stream {
            Stream::Tcp(s) => s.set_nonblocking(false)?,
            #[cfg(unix)]
            Stream::Unix(s) => s.set_nonblocking(false)?,
        }
        Ok(())
    }

    /// Set the read/write deadlines. `None` blocks forever; `Some(d)`
    /// makes a stalled peer surface as a timed-out I/O error instead of
    /// wedging the round.
    pub fn set_timeouts(&self, read: Option<Duration>, write: Option<Duration>) -> Result<()> {
        match &self.stream {
            Stream::Tcp(s) => {
                s.set_read_timeout(read).context("set_read_timeout")?;
                s.set_write_timeout(write).context("set_write_timeout")?;
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                s.set_read_timeout(read).context("set_read_timeout")?;
                s.set_write_timeout(write).context("set_write_timeout")?;
            }
        }
        Ok(())
    }

    /// Best-effort full shutdown (both directions).
    pub fn shutdown(&self) {
        match &self.stream {
            Stream::Tcp(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
            #[cfg(unix)]
            Stream::Unix(s) => {
                let _ = s.shutdown(std::net::Shutdown::Both);
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match &mut self.stream {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match &mut self.stream {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match &mut self.stream {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_parse_roundtrips() {
        let ep = Endpoint::parse("tcp:127.0.0.1:7070").unwrap();
        assert_eq!(ep, Endpoint::Tcp("127.0.0.1:7070".into()));
        assert_eq!(ep.to_string(), "tcp:127.0.0.1:7070");
        #[cfg(unix)]
        {
            let ep = Endpoint::parse("uds:/tmp/fsgw.sock").unwrap();
            assert_eq!(ep.to_string(), "uds:/tmp/fsgw.sock");
            assert_eq!(Endpoint::parse("unix:/tmp/fsgw.sock").unwrap(), ep);
        }
        assert!(Endpoint::parse("tcp:").is_err());
        assert!(Endpoint::parse("uds:").is_err());
        assert!(Endpoint::parse("http://x").is_err());
        assert!(Endpoint::parse("").is_err());
    }

    #[test]
    fn max_msg_auto_sizes_and_enforces_the_round_start_floor() {
        let mut cfg = crate::config::TrainConfig::default_smoke();
        cfg.clients_per_round = 10;
        let dim = 100_000;
        let floor = 4 * dim + 8 * cfg.clients_per_round + (1 << 12);
        // Auto (0) always clears the round-start frame.
        assert!(effective_max_msg(&cfg, dim).unwrap() >= floor);
        // An explicit cap below the frame is a config error at startup,
        // not a per-round oversize abort.
        cfg.serve_max_msg = 1 << 16;
        let err = effective_max_msg(&cfg, dim).unwrap_err().to_string();
        assert!(err.contains("serve_max_msg"), "{err}");
        // An explicit cap above the floor is taken verbatim.
        cfg.serve_max_msg = 8 << 20;
        assert_eq!(effective_max_msg(&cfg, dim).unwrap(), 8 << 20);
    }
}
