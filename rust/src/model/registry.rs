//! Dataset construction from manifest task + scale parameters.

use anyhow::Result;

use crate::data::partition::{LabelSkewImages, PersonaText, WriterImages};
use crate::data::synth_images::ImageGen;
use crate::data::synth_text::TextGen;
use crate::data::FedDataset;
use crate::runtime::artifact::{DataSpec, TaskManifest};

/// Population-size knobs, independent of the model artifacts.
#[derive(Clone, Debug)]
pub struct DataScale {
    /// Total client population.
    pub num_clients: usize,
    /// Samples per client (label-skew split; paper: 1–5).
    pub samples_per_client: usize,
    /// Mean samples per writer (writer split; paper: ~226).
    pub writer_mean_size: usize,
    /// Largest persona's sequence count (power-law head).
    pub persona_max_size: usize,
    /// Power-law exponent for persona sizes.
    pub persona_alpha: f64,
    /// Held-out eval batches per evaluation pass.
    pub eval_batches: usize,
    /// Per-sample noise for image tasks.
    pub noise_sigma: f32,
    /// Partition style: "label_skew" | "writer" (image tasks only;
    /// text tasks always use the persona partition).
    pub partition: String,
    /// Dataset seed.
    pub seed: u64,
}

impl Default for DataScale {
    fn default() -> Self {
        DataScale {
            num_clients: 1000,
            samples_per_client: 5,
            writer_mean_size: 40,
            persona_max_size: 200,
            persona_alpha: 1.1,
            eval_batches: 8,
            noise_sigma: 0.3,
            partition: "label_skew".to_string(),
            seed: 0xDA7A,
        }
    }
}

impl DataScale {
    pub fn smoke() -> Self {
        DataScale {
            num_clients: 50,
            samples_per_client: 5,
            writer_mean_size: 10,
            persona_max_size: 20,
            eval_batches: 2,
            ..Default::default()
        }
    }
}

/// Build the federated dataset for a manifest task.
pub fn build_dataset(task: &TaskManifest, scale: &DataScale) -> Result<Box<dyn FedDataset>> {
    match &task.data {
        DataSpec::Images { image, classes } => {
            let gen = ImageGen::new(
                image[0],
                image[1],
                image[2],
                *classes,
                scale.noise_sigma,
                scale.seed,
            );
            match scale.partition.as_str() {
                "label_skew" => Ok(Box::new(LabelSkewImages::new(
                    gen,
                    scale.num_clients,
                    scale.samples_per_client,
                    task.batch,
                    scale.eval_batches,
                ))),
                "writer" => Ok(Box::new(WriterImages::new(
                    gen,
                    scale.num_clients,
                    scale.writer_mean_size,
                    task.batch,
                    scale.eval_batches,
                    scale.seed,
                ))),
                other => anyhow::bail!("unknown partition '{other}'"),
            }
        }
        DataSpec::Text { vocab, seq } => {
            let gen = TextGen::new(*vocab, *seq, scale.seed);
            Ok(Box::new(PersonaText::new(
                gen,
                scale.num_clients,
                scale.persona_max_size,
                scale.persona_alpha,
                task.batch,
                scale.eval_batches,
                scale.seed,
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::SketchSpec;
    use std::collections::HashMap;

    fn fake_task(data: DataSpec) -> TaskManifest {
        TaskManifest {
            name: "t".into(),
            model: "m".into(),
            dim: 100,
            batch: 4,
            inputs: HashMap::new(),
            data,
            init_weights: "x.bin".into(),
            artifacts: HashMap::new(),
            sketch: SketchSpec { rows: 5, seed: 1, cols_options: vec![64] },
            fedavg_steps: vec![2],
        }
    }

    #[test]
    fn builds_image_partitions() {
        let t = fake_task(DataSpec::Images { image: [8, 8, 1], classes: 10 });
        let mut scale = DataScale::smoke();
        let ds = build_dataset(&t, &scale).unwrap();
        assert_eq!(ds.num_clients(), 50);
        scale.partition = "writer".into();
        let ds = build_dataset(&t, &scale).unwrap();
        assert!(ds.client_size(0) >= 2);
        scale.partition = "bogus".into();
        assert!(build_dataset(&t, &scale).is_err());
    }

    #[test]
    fn builds_text_partition() {
        let t = fake_task(DataSpec::Text { vocab: 64, seq: 16 });
        let ds = build_dataset(&t, &DataScale::smoke()).unwrap();
        assert_eq!(ds.num_clients(), 50);
        assert!(ds.num_eval_batches() > 0);
    }
}
