//! Model/task registry: binds a manifest task to the synthetic dataset
//! population the experiments train on.
//!
//! The *model* itself lives in the HLO artifacts (L2); what the Rust side
//! owns is the flat weight vector and the federated data population. The
//! [`DataScale`] knobs let one manifest task back populations of
//! different sizes (smoke / small / full experiment scales).

pub mod registry;

pub use registry::{build_dataset, DataScale};
