//! The federated coordinator (L3): owns the round loop, client
//! selection, strategy dispatch, evaluation, and communication
//! accounting. This is the paper's "central aggregator".
//!
//! - [`engine`] — the parallel round engine: client compute on a worker
//!   pool, deterministic sharded upload aggregation.
//! - [`trainer`] — the run loop tying selection, engine, strategy
//!   server halves, metrics and accounting together.

pub mod engine;
pub mod selection;
pub mod trainer;

pub use selection::ClientSelector;
pub use trainer::{build_strategy, RunSummary, Trainer};
