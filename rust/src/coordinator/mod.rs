//! The federated coordinator (L3): owns the round loop, client
//! selection, strategy dispatch, evaluation, and communication
//! accounting. This is the paper's "central aggregator".

pub mod selection;
pub mod trainer;

pub use selection::ClientSelector;
pub use trainer::{RunSummary, Trainer};
