//! The training coordinator: one federated optimization run.
//!
//! Per round (paper Algorithm 1 + the baselines' equivalents):
//! 1. sample W clients uniformly,
//! 2. the round engine fans the clients' local computation out over a
//!    worker pool (gradient + in-graph sketch for FetchSGD via PJRT;
//!    plain gradient for top-k/uncompressed; K local steps for FedAvg)
//!    and merges uploads into shard accumulators as they complete,
//! 3. the strategy's server half consumes the merged weighted sum and
//!    updates the flat weight vector,
//! 4. communication is accounted (upload / per-round download /
//!    staleness-aware download) and metrics logged.
//!
//! Parallelism is a pure throughput knob: the engine's shard layout is
//! thread-invariant, so `parallelism = 1` and `parallelism = N` produce
//! bitwise-identical weights and summaries for the same seed.
//!
//! With `TrainConfig.wire` set, every upload is encoded to a framed
//! wire message and absorbed from bytes (`RoundAccum::absorb_bytes`),
//! and the broadcast update round-trips encode→decode before it is
//! applied — so a lossy codec affects the weights exactly as a real
//! deployment would, while the lossless `f32le` codec is bitwise
//! identical to wire-off. Measured frame bytes land in [`CommStats`]
//! and the metrics log next to the idealized estimates.

use anyhow::{bail, Context, Result};
use std::sync::Arc;
use std::time::Instant;

use crate::cohort::{CohortPlan, QuorumPolicy};
use crate::compression::accounting::{CommStats, Ratios, StalenessTracker};
use crate::compression::aggregate::{resolve_parallelism, PipelineOptions, RoundPipeline};
use crate::compression::fedavg::{FedAvgClient, FedAvgServer};
use crate::compression::fetchsgd::{ErrorUpdate, FetchSgdClient, FetchSgdServer};
use crate::compression::local_topk::{LocalTopKClient, LocalTopKServer};
use crate::compression::timing::{CommTime, LinkProfile};
use crate::compression::true_topk::{DenseGradClient, TrueTopKServer};
use crate::compression::uncompressed::UncompressedServer;
use crate::compression::{ClientCompute, ServerAggregator};
use crate::config::{StrategyConfig, TrainConfig};
use crate::coordinator::engine;
use crate::coordinator::selection::ClientSelector;
use crate::data::FedDataset;
use crate::metrics::{EvalRecord, MetricsLogger, RoundRecord, SummaryRecord};
use crate::model::build_dataset;
use crate::runtime::artifact::{Manifest, TaskArtifacts};
use crate::runtime::exec::run_eval;
use crate::runtime::Runtime;
use crate::trace::{ms_since, Histogram, Phase, RoundTiming, TraceSink};
use crate::util::rng::derive_seed;
use crate::wire;

/// Outcome of a training run.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub strategy: String,
    pub task: String,
    pub rounds: usize,
    pub final_loss: f64,
    pub eval_loss: f64,
    pub accuracy: f64,
    pub perplexity: f64,
    pub upload_bytes: u64,
    pub download_bytes: u64,
    pub download_bytes_stale: u64,
    /// Measured wire-frame bytes, both directions (0 unless
    /// `TrainConfig.wire` is set). Under the lossless `f32le` codec
    /// these are always ≥ the idealized numbers (frames carry
    /// header/shape/index overhead the paper's footnote-5 convention
    /// ignores); a lossy codec like `f16le` can dip below them on
    /// dense payloads (2 bytes/value).
    pub wire_upload_bytes: u64,
    pub wire_download_bytes: u64,
    /// Planned slots dropped across the run (faults / deadline, after
    /// retries) — 0 under the strict default policy.
    pub dropped_slots: u64,
    /// Slots that needed at least one retry across the run.
    pub retried_slots: u64,
    /// Shard-lock absorb stalls across the run (contention between
    /// workers folding into the same shard) — purely observational,
    /// never affects the merged bits.
    pub absorb_stalls: u64,
    /// Upload bytes parked out of shard order across the run.
    pub parked_bytes: u64,
    pub ratios: Ratios,
    /// Estimated per-client communication wallclock over the whole run
    /// under the paper's motivating ~1 Mbps asymmetric residential link.
    pub comm_time_residential_s: f64,
    /// Same under a fast-WiFi profile.
    pub comm_time_wifi_s: f64,
    /// Wall-clock totals across rounds (`round_ms` always measured;
    /// `absorb_ms` only nonzero when tracing was on — see
    /// [`crate::trace::RoundTiming`]).
    pub timing: RoundTiming,
    /// Run-level slot-arrival latency percentiles in milliseconds
    /// (log-bucket upper edges; all 0 when tracing was off).
    pub arrival_p50_ms: f64,
    pub arrival_p90_ms: f64,
    pub arrival_p99_ms: f64,
}

pub struct Trainer {
    cfg: TrainConfig,
    artifacts: TaskArtifacts,
    dataset: Box<dyn FedDataset>,
    client: Box<dyn ClientCompute>,
    aggregator: Box<dyn ServerAggregator>,
    selector: ClientSelector,
    comm: CommStats,
    comm_time_res: CommTime,
    comm_time_wifi: CommTime,
    stale: StalenessTracker,
    pub logger: MetricsLogger,
    w: Vec<f32>,
    dim: usize,
    /// Resolved worker-pool width (cfg.parallelism, 0 = cores).
    threads: usize,
    /// Resolved wire codec (from cfg.wire; validated at construction).
    wire_codec: Option<&'static dyn wire::Codec>,
    /// Partial-participation policy (cfg.quorum_fraction /
    /// round_deadline_ms / max_slot_retries; validated at
    /// construction). Strict by default.
    quorum: QuorumPolicy,
    /// The round-aggregation pipeline: shard layout, reusable
    /// accumulator pool, absorb-on-arrival, row-strip parallel reduce.
    pipeline: RoundPipeline,
    /// Structured trace sink (cfg.trace_path; tier "engine"). Shared by
    /// Arc with each round's engine context and in-flight pipeline
    /// state. `None` keeps every per-upload path clock-free.
    trace: Option<Arc<TraceSink>>,
    /// Phase-timing totals across the run's rounds.
    timing: RoundTiming,
    /// Run-level slot-arrival histogram (merged per-round, exact).
    arrivals: Histogram,
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        let runtime = Arc::new(Runtime::cpu().context("PJRT runtime")?);
        Self::with_runtime(cfg, runtime)
    }

    /// Share one PJRT runtime across many trainers (experiment sweeps).
    pub fn with_runtime(cfg: TrainConfig, runtime: Arc<Runtime>) -> Result<Self> {
        let manifest = Manifest::load(&cfg.artifacts_dir)?;
        let artifacts = TaskArtifacts::new(runtime, &manifest, &cfg.task)?;
        let tm = &artifacts.manifest;
        let dim = tm.dim;
        let (client, aggregator) = build_strategy(&cfg, &artifacts)?;
        let dataset = build_dataset(tm, &cfg.scale)?;
        let selector =
            ClientSelector::new(dataset.num_clients(), cfg.clients_per_round, cfg.seed);
        let stale = StalenessTracker::new(dataset.num_clients(), dim);
        let logger = MetricsLogger::new(cfg.log_path.as_deref())?;
        let w = artifacts.init_weights()?;
        let threads = resolve_parallelism(cfg.parallelism);
        let wire_codec = match &cfg.wire {
            Some(name) => Some(wire::codec_by_name(name).context("TrainConfig.wire")?),
            None => None,
        };
        let quorum = cfg.quorum_policy()?;
        let trace = match cfg.trace_path.as_deref() {
            Some(p) => Some(Arc::new(
                TraceSink::create(p, "engine", &cfg.task).context("TrainConfig.trace_path")?,
            )),
            None => None,
        };
        // 0 = inherit the compute parallelism (itself 0 = all cores).
        let reduce = if cfg.reduce_parallelism > 0 { cfg.reduce_parallelism } else { threads };
        let pipeline = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: reduce,
            shard_override: cfg.shards,
            reduce_tiers: cfg.shard_tiers.clone(),
            adaptive_shards: cfg.adaptive_shards,
            pin_shards: cfg.pin_shards,
        });
        Ok(Trainer {
            cfg,
            artifacts,
            dataset,
            client,
            aggregator,
            selector,
            comm: CommStats::default(),
            comm_time_res: CommTime::default(),
            comm_time_wifi: CommTime::default(),
            stale,
            logger,
            w,
            dim,
            threads,
            wire_codec,
            quorum,
            pipeline,
            trace,
            timing: RoundTiming::default(),
            arrivals: Histogram::new(),
        })
    }

    pub fn weights(&self) -> &[f32] {
        &self.w
    }

    pub fn dim(&self) -> usize {
        self.dim
    }
}

/// Build a strategy's two halves from a config. Shared by the
/// in-process [`Trainer`], the transport server (which keeps only the
/// [`ServerAggregator`]), and transport workers (which keep only the
/// [`ClientCompute`]).
#[allow(clippy::type_complexity)]
pub fn build_strategy(
    cfg: &TrainConfig,
    artifacts: &TaskArtifacts,
) -> Result<(Box<dyn ClientCompute>, Box<dyn ServerAggregator>)> {
    let tm = &artifacts.manifest;
    Ok(match &cfg.strategy {
        StrategyConfig::FetchSgd { k, cols, rho, error_update, error_window, masking } => {
            if !tm.sketch.cols_options.contains(cols) {
                bail!(
                    "task '{}' has no client_step artifact for cols={cols} \
                     (available: {:?}) — add it to aot.py or pick another width",
                    tm.name,
                    tm.sketch.cols_options
                );
            }
            let eu = match error_update.as_str() {
                "zero_out" => ErrorUpdate::ZeroOut,
                "subtract" => ErrorUpdate::Subtract,
                other => bail!("error_update must be zero_out|subtract, got '{other}'"),
            };
            (
                Box::new(FetchSgdClient::new(tm.sketch.rows, *cols, tm.sketch.seed)),
                Box::new(FetchSgdServer::new(
                    tm.sketch.rows,
                    *cols,
                    tm.sketch.seed,
                    tm.dim,
                    *k,
                    *rho,
                    eu,
                    *masking,
                    error_window,
                )?),
            )
        }
        StrategyConfig::LocalTopK { k, rho_g, masking, local_error } => (
            Box::new(LocalTopKClient::new(*k, *local_error)),
            Box::new(LocalTopKServer::new(tm.dim, *rho_g, *masking)),
        ),
        StrategyConfig::FedAvg { local_steps, rho_g } => {
            if !tm.fedavg_steps.contains(local_steps) {
                bail!(
                    "task '{}' has no fedavg artifact for local_steps={local_steps} \
                     (available: {:?})",
                    tm.name,
                    tm.fedavg_steps
                );
            }
            (
                Box::new(FedAvgClient::new(*local_steps)),
                Box::new(FedAvgServer::new(tm.dim, *rho_g)),
            )
        }
        StrategyConfig::Uncompressed { rho_g } => (
            Box::new(DenseGradClient::new("uncompressed")),
            Box::new(UncompressedServer::new(tm.dim, *rho_g)),
        ),
        StrategyConfig::TrueTopK { k, rho, masking } => (
            Box::new(DenseGradClient::new("true_topk")),
            Box::new(TrueTopKServer::new(tm.dim, *k, *rho, *masking)),
        ),
    })
}

impl Trainer {
    /// One federated round. Returns the mean client training loss
    /// (over the arrived participants).
    pub fn step(&mut self, round: usize) -> Result<f64> {
        let step_t0 = Instant::now();
        let lr = self.cfg.lr.at(round, self.cfg.rounds);
        let plan = CohortPlan::sample(&self.selector, self.dataset.as_ref(), round);
        let weights = self.aggregator.begin_round(&plan.sizes);
        let spec = self.aggregator.upload_spec();

        let round_seed = derive_seed(self.cfg.seed ^ 0xB0B0, round as u64);
        let ctx = engine::RoundCtx {
            client: self.client.as_ref(),
            artifacts: &self.artifacts,
            dataset: self.dataset.as_ref(),
            w: &self.w,
            lr,
            round_seed,
            threads: self.threads,
            wire: self.wire_codec,
            policy: &self.quorum,
            round: round as u64,
            trace: self.trace.clone(),
        };
        let out = engine::run_round(&ctx, &plan.participants, &weights, &spec, &mut self.pipeline)
            .with_context(|| format!("round {round}"))?;
        let mem = out.membership.summary();
        // Only clients whose upload made it into the round count for
        // communication and staleness accounting.
        let arrived_clients: Vec<usize> =
            out.membership.arrived_slots().iter().map(|&s| plan.participants[s]).collect();
        let upload_per_client = out.upload_bytes_per_client;
        // broadcast span: the server half — update extraction, the wire
        // round-trip, and applying the update to the weights.
        let broadcast_start_us = self.trace.as_ref().map_or(0, |t| t.now_us());
        let update = self.aggregator.finish(&out.merged, lr)?;
        // The server is done with the merged sum: return the
        // accumulator to the pipeline's pool for next round.
        self.pipeline.recycle(out.merged);
        // Wire mode: the broadcast the clients apply is the decoded
        // frame, not the in-memory update — a lossy codec therefore
        // shapes the trajectory exactly as a real deployment would.
        let (update, wire_down_per_client) = match self.wire_codec {
            Some(codec) => {
                let frame = wire::encode_update(&update, codec);
                let measured = frame.len() as u64;
                let decoded = wire::decode_update(&frame)
                    .with_context(|| format!("broadcast frame, round {round}"))?;
                (decoded, measured)
            }
            None => (update, 0),
        };
        update.apply(&mut self.w);
        if let Some(t) = &self.trace {
            t.span(round as u64, Phase::Broadcast, broadcast_start_us, t.now_us());
        }
        let update_nnz = update.nnz();
        let stale_bytes = self.stale.round(round as u64, &arrived_clients, update_nnz);
        let down_per_client = update.payload_bytes();
        self.comm.record_round(
            arrived_clients.len(),
            upload_per_client,
            down_per_client,
            stale_bytes,
            out.wire_upload_bytes_per_client,
            wire_down_per_client,
        );
        self.comm_time_res.record_round(
            &LinkProfile::residential(),
            upload_per_client,
            down_per_client,
        );
        self.comm_time_wifi
            .record_round(&LinkProfile::wifi(), upload_per_client, down_per_client);
        let mean_loss = out.mean_loss;
        let n = arrived_clients.len() as u64;
        // Full-step wall clock (engine round plus the server half),
        // accumulated into the run totals alongside the engine's phase
        // breakdown.
        let timing = RoundTiming { round_ms: ms_since(step_t0), ..out.timing };
        self.timing.accumulate(&timing);
        self.arrivals.merge(&out.arrivals);
        self.logger.log_round(RoundRecord {
            round,
            loss: mean_loss,
            lr: lr as f64,
            upload_bytes: upload_per_client * n,
            download_bytes: down_per_client * n,
            wire_upload_bytes: out.wire_upload_bytes_per_client * n,
            wire_download_bytes: wire_down_per_client * n,
            transport_bytes: 0,
            absorb_stalls: out.absorb_stats.lock_stalls,
            parked_bytes: out.absorb_stats.parked_bytes,
            chosen_shards: out.absorb_stats.chosen_shards as usize,
            participants: mem.participants,
            dropped_slots: mem.dropped_slots,
            retried_slots: mem.retried_slots,
            update_nnz,
            round_ms: timing.round_ms,
            compute_ms: timing.compute_ms,
            absorb_ms: timing.absorb_ms,
            reduce_ms: timing.reduce_ms,
            tier: None,
        });
        if self.cfg.verbose {
            eprintln!(
                "[{}] round {round:>4} loss {mean_loss:.4} lr {lr:.4} nnz {update_nnz} \
                 cohort {}/{}",
                self.aggregator.name(),
                mem.participants,
                plan.slots()
            );
        }
        Ok(mean_loss)
    }

    /// Evaluate on the held-out set: (loss, accuracy, perplexity).
    pub fn evaluate(&mut self, round: usize) -> Result<EvalRecord> {
        let exe = self.artifacts.executable("eval")?;
        let mut sum_ce = 0f64;
        let mut units = 0f64;
        let mut correct = 0f64;
        for i in 0..self.dataset.num_eval_batches() {
            let batch = self.dataset.eval_batch(i);
            let (ce, u, c) = run_eval(&exe, &self.w, &batch)?;
            sum_ce += ce;
            units += u;
            correct += c;
        }
        let eval_loss = sum_ce / units.max(1.0);
        let rec = EvalRecord {
            round,
            eval_loss,
            accuracy: correct / units.max(1.0),
            perplexity: eval_loss.exp(),
        };
        self.logger.log_eval(rec.clone());
        Ok(rec)
    }

    /// Full training run with periodic + final evaluation.
    pub fn run(&mut self) -> Result<RunSummary> {
        for round in 0..self.cfg.rounds {
            self.step(round)?;
            if self.cfg.eval_every > 0
                && (round + 1) % self.cfg.eval_every == 0
                && round + 1 < self.cfg.rounds
            {
                let e = self.evaluate(round)?;
                if self.cfg.verbose {
                    eprintln!(
                        "[eval] round {round} loss {:.4} acc {:.4} ppl {:.2}",
                        e.eval_loss, e.accuracy, e.perplexity
                    );
                }
            }
        }
        let e = self.evaluate(self.cfg.rounds.saturating_sub(1))?;
        let baseline_rounds = self.cfg.baseline_rounds.unwrap_or(self.cfg.rounds) as u64;
        let ratios =
            self.comm.ratios(baseline_rounds, self.cfg.clients_per_round as u64, self.dim);
        let summary = RunSummary {
            strategy: self.aggregator.name().to_string(),
            task: self.cfg.task.clone(),
            rounds: self.cfg.rounds,
            final_loss: self.logger.recent_loss(10),
            eval_loss: e.eval_loss,
            accuracy: e.accuracy,
            perplexity: e.perplexity,
            upload_bytes: self.comm.upload_bytes,
            download_bytes: self.comm.download_bytes,
            download_bytes_stale: self.comm.download_bytes_stale,
            wire_upload_bytes: self.comm.wire_upload_bytes,
            wire_download_bytes: self.comm.wire_download_bytes,
            dropped_slots: self.logger.rounds.iter().map(|r| r.dropped_slots as u64).sum(),
            retried_slots: self.logger.rounds.iter().map(|r| r.retried_slots as u64).sum(),
            absorb_stalls: self.logger.rounds.iter().map(|r| r.absorb_stalls).sum(),
            parked_bytes: self.logger.rounds.iter().map(|r| r.parked_bytes).sum(),
            ratios,
            comm_time_residential_s: self.comm_time_res.total_s,
            comm_time_wifi_s: self.comm_time_wifi.total_s,
            timing: self.timing,
            arrival_p50_ms: self.arrivals.percentile(0.50) as f64 / 1e3,
            arrival_p90_ms: self.arrivals.percentile(0.90) as f64 / 1e3,
            arrival_p99_ms: self.arrivals.percentile(0.99) as f64 / 1e3,
        };
        self.logger.log_summary(&SummaryRecord {
            strategy: summary.strategy.clone(),
            task: summary.task.clone(),
            rounds: summary.rounds,
            final_loss: summary.final_loss,
            upload_bytes: summary.upload_bytes,
            download_bytes: summary.download_bytes,
            dropped_slots: summary.dropped_slots,
            retried_slots: summary.retried_slots,
            round_ms: summary.timing.round_ms,
            compute_ms: summary.timing.compute_ms,
            absorb_ms: summary.timing.absorb_ms,
            reduce_ms: summary.timing.reduce_ms,
            arrival_p50_ms: summary.arrival_p50_ms,
            arrival_p90_ms: summary.arrival_p90_ms,
            arrival_p99_ms: summary.arrival_p99_ms,
        });
        // Surface write failures loudly instead of shipping a silently
        // truncated log or trace.
        self.logger.flush()?;
        if let Some(t) = &self.trace {
            // No run-level histogram here: the per-round `hist` events
            // already merge bucket-exactly to the run total, and a
            // duplicate emission would double-fold in `trace-summary`.
            t.flush()?;
        }
        Ok(summary)
    }
}
