//! The parallel round engine: fan client compute out over a worker
//! pool, merge uploads into shard accumulators as they arrive, reduce
//! shards in a fixed order.
//!
//! ## Determinism
//!
//! Results are **bitwise identical for a given seed at any thread
//! count**. The invariants that guarantee it:
//!
//! 1. The shard *layout* is a pure function of the cohort size:
//!    [`shard_count`] caps at [`MAX_SHARDS`] and slot `i` belongs to
//!    shard `i % shards` — never a function of `threads`.
//! 2. Each shard absorbs its slots in increasing slot order (one worker
//!    owns a shard at a time, and walks its slots in order).
//! 3. Shards are reduced strictly in shard order
//!    ([`crate::compression::aggregate::reduce_shards_in_place`], which
//!    uses [`crate::sketch::CountSketch::merge_shard_refs`] for sketch
//!    shards).
//! 4. Per-slot losses are written into slot-indexed cells and summed in
//!    slot order by the caller.
//!
//! Threads only change *which worker* runs a shard, never the
//! floating-point reduction tree. Wire mode ([`RoundCtx::wire`]) doesn't
//! either, under the lossless `f32le` codec: encode→`absorb_bytes`
//! performs the same additions in the same order as in-memory absorbs.
//!
//! ## Scheduling
//!
//! Workers pull whole shards off an atomic counter (shard = unit of
//! work stealing). With `W` participants and `S = min(W, MAX_SHARDS)`
//! shards, each shard holds `~W/S` clients, so the pool load-balances
//! at shard granularity while the per-shard scratch memory stays
//! bounded at `S` accumulators regardless of cohort size.
//!
//! ## Scratch reuse
//!
//! Shard accumulators are taken from a caller-owned `scratch` pool and
//! reset in place (workers zero their own shard, in parallel) instead
//! of being allocated fresh: at large `dim`, re-allocating and paging
//! in up to `MAX_SHARDS` tables every round is measurable. The caller
//! gets the merged accumulator back in [`RoundOutput::merged`] and
//! returns it to the pool once the server is done with it (see
//! `coordinator::trainer`).

use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::compression::aggregate::{reduce_shards_in_place, RoundAccum};
use crate::compression::{ClientCompute, UploadSpec};
use crate::data::FedDataset;
use crate::runtime::artifact::TaskArtifacts;
use crate::wire::{encode_upload, Codec};

// The shard layout (slot `i` belongs to shard `shard_of(i, S)`, with
// `S = shard_count(W)` capped at `MAX_SHARDS`) lives next to the
// accumulators in `compression::aggregate` since the transport server's
// streaming absorber must replicate it bit-for-bit; re-exported here
// because the engine is where the layout is *scheduled*.
pub use crate::compression::aggregate::{shard_count, shard_of, MAX_SHARDS};

/// Resolve a configured parallelism knob: 0 = all available cores.
pub fn resolve_parallelism(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// The round-invariant context for [`run_round`]: what to run, on what
/// data, against which weights, and how (threads / wire codec).
pub struct RoundCtx<'a> {
    pub client: &'a dyn ClientCompute,
    pub artifacts: &'a TaskArtifacts,
    pub dataset: &'a dyn FedDataset,
    /// Current model weights (read-only during the round).
    pub w: &'a [f32],
    pub lr: f32,
    pub round_seed: u64,
    /// Worker threads (clamped to [1, shard count]).
    pub threads: usize,
    /// When set, every upload round-trips through the framed wire
    /// encoding under this codec: the engine encodes each
    /// `ClientUpload` to a frame and the shard accumulator decodes it
    /// streaming ([`RoundAccum::absorb_bytes`]), recording measured
    /// frame bytes alongside the idealized estimate.
    pub wire: Option<&'a dyn Codec>,
}

/// Everything one round of client compute produces.
pub struct RoundOutput {
    /// Per-slot client training loss, in participant order.
    pub losses: Vec<f32>,
    /// Merged weighted upload sum (`Σ λ_i · upload_i`). Return it to the
    /// scratch pool after the server consumes it.
    pub merged: RoundAccum,
    /// Payload bytes of slot 0's upload under the paper's idealized
    /// accounting (all uploads of a strategy are the same size).
    pub upload_bytes_per_client: u64,
    /// Measured wire-frame bytes of slot 0's upload (0 when wire mode
    /// is off).
    pub wire_upload_bytes_per_client: u64,
}

struct ShardOut {
    accum: RoundAccum,
    /// (slot, loss) pairs for the slots this shard owns.
    losses: Vec<(usize, f32)>,
    /// Idealized upload payload bytes of this shard's lowest slot.
    payload_bytes: u64,
    /// Measured wire bytes of this shard's lowest slot (wire mode only).
    wire_bytes: u64,
}

/// Execute one federated round's client work: for each participant
/// slot, generate the batch, run the client compute, and absorb the
/// upload (weighted by `weights[slot]`) into the slot's shard
/// accumulator — through the wire encoding when `ctx.wire` is set.
/// Returns the fully merged accumulator and per-slot losses.
///
/// `scratch` is the reusable shard-accumulator pool: entries matching
/// `spec` are reset and reused, anything else is dropped and rebuilt.
pub fn run_round(
    ctx: &RoundCtx<'_>,
    participants: &[usize],
    weights: &[f32],
    spec: &UploadSpec,
    scratch: &mut Vec<RoundAccum>,
) -> Result<RoundOutput> {
    assert_eq!(participants.len(), weights.len(), "one weight per participant");
    let slots = participants.len();
    let shards = shard_count(slots);
    let threads = ctx.threads.clamp(1, shards);
    let stacked_k = ctx.client.wants_stacked_batches();

    // Refill the scratch pool: keep spec-compatible accumulators (reset
    // happens in the worker, so zeroing parallelizes), rebuild the rest.
    scratch.retain(|a| a.matches_spec(spec));
    while scratch.len() < shards {
        scratch.push(RoundAccum::new(spec)?);
    }
    let cells: Vec<Mutex<Option<RoundAccum>>> =
        scratch.drain(..).map(|a| Mutex::new(Some(a))).collect();

    let run_shard = |shard: usize| -> Result<ShardOut> {
        let mut accum = cells[shard]
            .lock()
            .expect("scratch cell poisoned")
            .take()
            .expect("each shard claims its scratch exactly once");
        accum.reset();
        let mut losses = Vec::with_capacity(slots / shards + 1);
        let mut payload_bytes = 0u64;
        let mut wire_bytes = 0u64;
        let mut slot = shard;
        while slot < slots {
            let c = participants[slot];
            let batch = ctx.dataset.client_batch(c, ctx.round_seed);
            let stacked =
                stacked_k.map(|k| ctx.dataset.client_batches_stacked(c, k, ctx.round_seed));
            let res = ctx
                .client
                .client_round(ctx.artifacts, ctx.w, &batch, c, stacked, ctx.lr)
                .with_context(|| format!("client {c} (slot {slot})"))?;
            if slot == shard {
                payload_bytes = res.upload.payload_bytes();
            }
            losses.push((slot, res.loss));
            match ctx.wire {
                Some(codec) => {
                    let frame = encode_upload(&res.upload, codec);
                    if slot == shard {
                        wire_bytes = frame.len() as u64;
                    }
                    accum
                        .absorb_bytes(&frame, weights[slot])
                        .with_context(|| format!("wire upload from client {c} (slot {slot})"))?;
                }
                None => accum.absorb(res.upload, weights[slot])?,
            }
            slot += shards;
        }
        Ok(ShardOut { accum, losses, payload_bytes, wire_bytes })
    };

    let mut shard_outs: Vec<Option<Result<ShardOut>>> = (0..shards).map(|_| None).collect();
    if threads <= 1 {
        for (shard, out) in shard_outs.iter_mut().enumerate() {
            *out = Some(run_shard(shard));
        }
    } else {
        let next = AtomicUsize::new(0);
        let completed = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(|| {
                        let mut outs = Vec::new();
                        loop {
                            let shard = next.fetch_add(1, Ordering::Relaxed);
                            if shard >= shards {
                                break;
                            }
                            outs.push((shard, run_shard(shard)));
                        }
                        outs
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("round worker panicked"))
                .collect::<Vec<_>>()
        });
        for (shard, out) in completed {
            shard_outs[shard] = Some(out);
        }
    }

    // Surface the lowest-shard error first (deterministic failure too).
    let mut losses = vec![0f32; slots];
    let mut upload_bytes_per_client = 0u64;
    let mut wire_upload_bytes_per_client = 0u64;
    let mut accums = Vec::with_capacity(shards);
    for (shard, out) in shard_outs.into_iter().enumerate() {
        let out = out.expect("every shard scheduled")?;
        if shard == 0 {
            upload_bytes_per_client = out.payload_bytes;
            wire_upload_bytes_per_client = out.wire_bytes;
        }
        for (slot, loss) in out.losses {
            losses[slot] = loss;
        }
        accums.push(out.accum);
    }
    reduce_shards_in_place(&mut accums)?;
    if accums[0].absorbed() != slots {
        bail!("absorbed {} uploads for {slots} slots", accums[0].absorbed());
    }
    // Shard 0 carries the merged sum; the rest go back to the pool.
    let merged = accums.swap_remove(0);
    scratch.extend(accums);
    Ok(RoundOutput {
        losses,
        merged,
        upload_bytes_per_client,
        wire_upload_bytes_per_client,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::sim::{sim_artifacts, SimDataset, SimSketchClient};
    use crate::compression::ServerAggregator;
    use crate::wire::F32LE;

    const DIM: usize = 5000;
    const ROWS: usize = 5;
    const COLS: usize = 512;
    const SEED: u64 = 21;

    fn sim_round(threads: usize, w_cohort: usize, wire: bool) -> (Vec<f32>, Vec<f32>) {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let participants: Vec<usize> = (0..w_cohort).collect();
        let weights = vec![1.0 / w_cohort as f32; w_cohort];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        let ctx = RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: 0xFEED,
            threads,
            wire: if wire { Some(&F32LE) } else { None },
        };
        let mut scratch = Vec::new();
        let out = run_round(&ctx, &participants, &weights, &spec, &mut scratch).unwrap();
        assert_eq!(out.merged.absorbed(), w_cohort);
        assert_eq!(out.upload_bytes_per_client, (ROWS * COLS * 4) as u64);
        if wire {
            assert!(
                out.wire_upload_bytes_per_client > out.upload_bytes_per_client,
                "frames carry header+shape overhead"
            );
        } else {
            assert_eq!(out.wire_upload_bytes_per_client, 0);
        }
        assert_eq!(scratch.len(), shard_count(w_cohort) - 1, "tail shards return to the pool");
        let table = out.merged.into_sketch().unwrap().table().to_vec();
        (out.losses, table)
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        for cohort in [3usize, 16, 33] {
            let (l1, t1) = sim_round(1, cohort, false);
            for threads in [2usize, 4, 8] {
                let (ln, tn) = sim_round(threads, cohort, false);
                assert_eq!(
                    l1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    ln.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "losses differ at {threads} threads (cohort {cohort})"
                );
                assert_eq!(
                    t1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    tn.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "merged sketch differs at {threads} threads (cohort {cohort})"
                );
            }
        }
    }

    #[test]
    fn wire_mode_does_not_change_bits_under_f32le() {
        for (threads, cohort) in [(1usize, 5usize), (4, 33)] {
            let (l_mem, t_mem) = sim_round(threads, cohort, false);
            let (l_wire, t_wire) = sim_round(threads, cohort, true);
            assert_eq!(
                l_mem.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                l_wire.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            );
            assert_eq!(
                t_mem.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                t_wire.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "wire round-trip changed the merged sketch (threads {threads})"
            );
        }
    }

    #[test]
    fn scratch_is_reused_across_rounds() {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let participants: Vec<usize> = (0..8).collect();
        let weights = vec![0.125f32; 8];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        let mut scratch = Vec::new();
        let mut tables = Vec::new();
        for _ in 0..3 {
            let ctx = RoundCtx {
                client: &client,
                artifacts: &artifacts,
                dataset: &dataset,
                w: &w,
                lr: 0.1,
                round_seed: 0xFEED, // same seed: rounds must be identical
                threads: 4,
                wire: None,
            };
            let out = run_round(&ctx, &participants, &weights, &spec, &mut scratch).unwrap();
            tables.push(out.merged.as_sketch().unwrap().table().to_vec());
            scratch.push(out.merged); // trainer's return-to-pool step
            assert_eq!(scratch.len(), 8);
        }
        // Reused (reset) scratch must not leak state between rounds.
        for t in &tables[1..] {
            assert_eq!(
                tables[0].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                t.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn shard_layout_is_parallelism_invariant() {
        assert_eq!(shard_count(1), 1);
        assert_eq!(shard_count(7), 7);
        assert_eq!(shard_count(MAX_SHARDS), MAX_SHARDS);
        assert_eq!(shard_count(100), MAX_SHARDS);
        assert_eq!(shard_count(0), 1);
        assert!(resolve_parallelism(0) >= 1);
        assert_eq!(resolve_parallelism(3), 3);
    }

    #[test]
    fn engine_feeds_a_full_aggregator_pipeline() {
        // One end-to-end sim round through a real FetchSGD server.
        use crate::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 50 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let mut server = FetchSgdServer::new(
            ROWS, COLS, SEED, DIM, 20, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )
        .unwrap();
        let participants: Vec<usize> = (0..10).collect();
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let mut w = vec![0f32; DIM];
        let ctx = RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: 7,
            threads: 4,
            wire: None,
        };
        let mut scratch = Vec::new();
        let out = run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut scratch)
            .unwrap();
        let update = server.finish(&out.merged, 0.1).unwrap();
        update.apply(&mut w);
        assert!(update.nnz() > 0);
        assert!(w.iter().any(|&x| x != 0.0), "model should move");
    }
}
