//! The parallel round engine: fan client compute out over a worker
//! pool, merge uploads into shard accumulators as they arrive, reduce
//! shards in a fixed order.
//!
//! ## Determinism
//!
//! Results are **bitwise identical for a given seed at any thread
//! count**. The invariants that guarantee it:
//!
//! 1. The shard *layout* is a pure function of the cohort size:
//!    [`shard_count`] caps at [`MAX_SHARDS`] and slot `i` belongs to
//!    shard `i % shards` — never a function of `threads`.
//! 2. Each shard absorbs its slots in increasing slot order (one worker
//!    owns a shard at a time, and walks its slots in order).
//! 3. Shards are reduced strictly in shard order
//!    ([`crate::compression::aggregate::reduce_shards`], which uses
//!    [`crate::sketch::CountSketch::merge_shards`] for sketch shards).
//! 4. Per-slot losses are written into slot-indexed cells and summed in
//!    slot order by the caller.
//!
//! Threads only change *which worker* runs a shard, never the
//! floating-point reduction tree.
//!
//! ## Scheduling
//!
//! Workers pull whole shards off an atomic counter (shard = unit of
//! work stealing). With `W` participants and `S = min(W, MAX_SHARDS)`
//! shards, each shard holds `~W/S` clients, so the pool load-balances
//! at shard granularity while the per-shard scratch memory stays
//! bounded at `S` accumulators regardless of cohort size.

use anyhow::{Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::compression::aggregate::{reduce_shards, RoundAccum};
use crate::compression::{ClientCompute, UploadSpec};
use crate::data::FedDataset;
use crate::runtime::artifact::TaskArtifacts;

/// Upper bound on shard accumulators per round. Bounds both the final
/// fan-in cost and the scratch memory (`MAX_SHARDS` dense vectors /
/// sketch tables), and is deliberately independent of the machine's
/// core count so the reduction tree is machine-invariant.
pub const MAX_SHARDS: usize = 16;

/// Number of shard accumulators for a cohort of `participants` clients.
pub fn shard_count(participants: usize) -> usize {
    participants.clamp(1, MAX_SHARDS)
}

/// Resolve a configured parallelism knob: 0 = all available cores.
pub fn resolve_parallelism(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Everything one round of client compute produces.
pub struct RoundOutput {
    /// Per-slot client training loss, in participant order.
    pub losses: Vec<f32>,
    /// Merged weighted upload sum (`Σ λ_i · upload_i`).
    pub merged: RoundAccum,
    /// Payload bytes of slot 0's upload (all uploads of a strategy are
    /// the same size; used for communication accounting).
    pub upload_bytes_per_client: u64,
}

struct ShardOut {
    accum: RoundAccum,
    /// (slot, loss) pairs for the slots this shard owns.
    losses: Vec<(usize, f32)>,
    /// Upload payload bytes of this shard's lowest slot.
    payload_bytes: u64,
}

/// Execute one federated round's client work: for each participant
/// slot, generate the batch, run the client compute, and absorb the
/// upload (weighted by `weights[slot]`) into the slot's shard
/// accumulator. Returns the fully merged accumulator and per-slot
/// losses.
#[allow(clippy::too_many_arguments)]
pub fn run_round(
    client: &dyn ClientCompute,
    artifacts: &TaskArtifacts,
    dataset: &dyn FedDataset,
    participants: &[usize],
    weights: &[f32],
    spec: &UploadSpec,
    w: &[f32],
    lr: f32,
    round_seed: u64,
    threads: usize,
) -> Result<RoundOutput> {
    assert_eq!(participants.len(), weights.len(), "one weight per participant");
    let slots = participants.len();
    let shards = shard_count(slots);
    let threads = threads.clamp(1, shards);
    let stacked_k = client.wants_stacked_batches();

    let run_shard = |shard: usize| -> Result<ShardOut> {
        let mut accum = RoundAccum::new(spec)?;
        let mut losses = Vec::with_capacity(slots / shards + 1);
        let mut payload_bytes = 0u64;
        let mut slot = shard;
        while slot < slots {
            let c = participants[slot];
            let batch = dataset.client_batch(c, round_seed);
            let stacked = stacked_k.map(|k| dataset.client_batches_stacked(c, k, round_seed));
            let res = client
                .client_round(artifacts, w, &batch, c, stacked, lr)
                .with_context(|| format!("client {c} (slot {slot})"))?;
            if slot == shard {
                payload_bytes = res.upload.payload_bytes();
            }
            losses.push((slot, res.loss));
            accum.absorb(res.upload, weights[slot])?;
            slot += shards;
        }
        Ok(ShardOut { accum, losses, payload_bytes })
    };

    let mut shard_outs: Vec<Option<Result<ShardOut>>> = (0..shards).map(|_| None).collect();
    if threads <= 1 {
        for (shard, out) in shard_outs.iter_mut().enumerate() {
            *out = Some(run_shard(shard));
        }
    } else {
        let next = AtomicUsize::new(0);
        let completed = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    scope.spawn(|| {
                        let mut outs = Vec::new();
                        loop {
                            let shard = next.fetch_add(1, Ordering::Relaxed);
                            if shard >= shards {
                                break;
                            }
                            outs.push((shard, run_shard(shard)));
                        }
                        outs
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("round worker panicked"))
                .collect::<Vec<_>>()
        });
        for (shard, out) in completed {
            shard_outs[shard] = Some(out);
        }
    }

    // Surface the lowest-shard error first (deterministic failure too).
    let mut losses = vec![0f32; slots];
    let mut upload_bytes_per_client = 0u64;
    let mut accums = Vec::with_capacity(shards);
    for (shard, out) in shard_outs.into_iter().enumerate() {
        let out = out.expect("every shard scheduled")?;
        if shard == 0 {
            upload_bytes_per_client = out.payload_bytes;
        }
        for (slot, loss) in out.losses {
            losses[slot] = loss;
        }
        accums.push(out.accum);
    }
    let merged = reduce_shards(accums)?;
    Ok(RoundOutput { losses, merged, upload_bytes_per_client })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::sim::{sim_artifacts, SimDataset, SimSketchClient};
    use crate::compression::ServerAggregator;

    const DIM: usize = 5000;
    const ROWS: usize = 5;
    const COLS: usize = 512;
    const SEED: u64 = 21;

    fn sim_round(threads: usize, w_cohort: usize) -> (Vec<f32>, Vec<f32>) {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let participants: Vec<usize> = (0..w_cohort).collect();
        let weights = vec![1.0 / w_cohort as f32; w_cohort];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        let out = run_round(
            &client,
            &artifacts,
            &dataset,
            &participants,
            &weights,
            &spec,
            &w,
            0.1,
            0xFEED,
            threads,
        )
        .unwrap();
        assert_eq!(out.merged.absorbed(), w_cohort);
        assert_eq!(out.upload_bytes_per_client, (ROWS * COLS * 4) as u64);
        let table = out.merged.into_sketch().unwrap().table().to_vec();
        (out.losses, table)
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        for cohort in [3usize, 16, 33] {
            let (l1, t1) = sim_round(1, cohort);
            for threads in [2usize, 4, 8] {
                let (ln, tn) = sim_round(threads, cohort);
                assert_eq!(
                    l1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    ln.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "losses differ at {threads} threads (cohort {cohort})"
                );
                assert_eq!(
                    t1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    tn.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "merged sketch differs at {threads} threads (cohort {cohort})"
                );
            }
        }
    }

    #[test]
    fn shard_layout_is_parallelism_invariant() {
        assert_eq!(shard_count(1), 1);
        assert_eq!(shard_count(7), 7);
        assert_eq!(shard_count(MAX_SHARDS), MAX_SHARDS);
        assert_eq!(shard_count(100), MAX_SHARDS);
        assert_eq!(shard_count(0), 1);
        assert!(resolve_parallelism(0) >= 1);
        assert_eq!(resolve_parallelism(3), 3);
    }

    #[test]
    fn engine_feeds_a_full_aggregator_pipeline() {
        // One end-to-end sim round through a real FetchSGD server.
        use crate::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 50 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let mut server = FetchSgdServer::new(
            ROWS, COLS, SEED, DIM, 20, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )
        .unwrap();
        let participants: Vec<usize> = (0..10).collect();
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let mut w = vec![0f32; DIM];
        let out = run_round(
            &client,
            &artifacts,
            &dataset,
            &participants,
            &weights,
            &server.upload_spec(),
            &w,
            0.1,
            7,
            4,
        )
        .unwrap();
        let update = server.finish(out.merged, &mut w, 0.1).unwrap();
        assert!(update.nnz(DIM) > 0);
        assert!(w.iter().any(|&x| x != 0.0), "model should move");
    }
}
