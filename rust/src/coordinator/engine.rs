//! The parallel round engine: fan client compute out over a worker
//! pool, folding every upload into the shared round pipeline the moment
//! it completes.
//!
//! This is the in-process driver of
//! [`crate::compression::aggregate::RoundPipeline`] — the same
//! absorb-on-arrival fan-in the transport server
//! (`crate::transport::server`) drives over sockets, so the
//! slot→shard→reduce logic exists exactly once.
//!
//! ## Determinism
//!
//! Results are **bitwise identical for a given seed at any thread
//! count**. The invariants that guarantee it:
//!
//! 1. The shard *layout* is a pure function of the cohort size
//!    ([`crate::compression::aggregate::shard_count`] caps at
//!    [`crate::compression::aggregate::MAX_SHARDS`]; slot `i` belongs
//!    to shard `i % shards`) — never a function of `threads`.
//! 2. Each shard absorbs its slots in increasing slot order: workers
//!    offer uploads to the shared
//!    [`crate::compression::aggregate::RoundInFlight`] as they finish,
//!    and it parks early arrivals until their in-shard turn.
//! 3. Shards reduce strictly in shard order over geometry-pure row
//!    strips ([`crate::compression::aggregate::reduce_shards_in_place`]).
//! 4. Per-slot losses are written into slot-indexed cells and summed in
//!    slot order by the caller.
//!
//! Threads only change *which worker* computes a slot and *when* its
//! upload is offered, never the floating-point reduction tree. Wire
//! mode ([`RoundCtx::wire`]) doesn't either, under the lossless `f32le`
//! codec: encode→`offer_frame` performs the same additions in the same
//! order as in-memory offers. Partial-cohort rounds
//! ([`RoundCtx::policy`]) extend the contract: *which* slots drop may
//! depend on wall-clock or flaky clients, but conditioned on the final
//! membership set the merged (renormalized) bits are identical at any
//! parallelism — `finalize_partial` absorbs the arrived slots in the
//! same in-shard order and scales by a pure function of the set.
//!
//! ## Scheduling
//!
//! Workers pull individual *slots* off an atomic counter, so the pool
//! load-balances at client granularity: a straggling client delays only
//! its own shard's later slots, and thread counts above the shard cap
//! keep paying off up to the cohort size. (Before the pipeline
//! refactor, workers owned whole shards and parallelism was capped at
//! `MAX_SHARDS`.) Out-of-order completions are parked by the pipeline —
//! worst case the parking buffer holds the cohort's uploads, the price
//! of never blocking a worker on another worker's slot.
//!
//! Absorption is shard-parallel (the same discipline the transport
//! server uses): the in-flight round's offer methods take `&self` — each
//! shard's accumulator sits behind its own lock with a lock-free
//! claim/counter layer on top — so workers folding into different
//! shards never contend, and a shard lock covers only that shard's
//! O(table) fold, never client compute. Contention that does occur is
//! counted ([`RoundOutput::absorb_stats`]) rather than guessed at.
//!
//! ## Scratch reuse
//!
//! Shard accumulators come from the pipeline's pool and are reset in
//! place (in parallel for large tables) instead of being allocated
//! fresh: at large `dim`, re-allocating and paging in up to
//! `MAX_SHARDS` tables every round is measurable. The caller gets the
//! merged accumulator back in [`RoundOutput::merged`] and returns it to
//! the pool via [`RoundPipeline::recycle`] once the server is done with
//! it (see `coordinator::trainer`).

use anyhow::{anyhow, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::cohort::{DropReason, QuorumPolicy, RoundMembership};
use crate::compression::aggregate::{AbsorbStats, RoundAccum, RoundPipeline};
use crate::compression::{ClientCompute, UploadSpec};
use crate::data::FedDataset;
use crate::runtime::artifact::TaskArtifacts;
use crate::trace::{ms_since, Histogram, Phase, RoundTiming, SlotEvent, TraceSink};
use crate::wire::{encode_upload, Codec};

/// The round-invariant context for [`run_round`]: what to run, on what
/// data, against which weights, and how (threads / wire codec /
/// quorum policy).
pub struct RoundCtx<'a> {
    pub client: &'a dyn ClientCompute,
    pub artifacts: &'a TaskArtifacts,
    pub dataset: &'a dyn FedDataset,
    /// Current model weights (read-only during the round).
    pub w: &'a [f32],
    pub lr: f32,
    pub round_seed: u64,
    /// Worker threads (clamped to [1, cohort size]).
    pub threads: usize,
    /// When set, every upload round-trips through the framed wire
    /// encoding under this codec: the engine encodes each
    /// `ClientUpload` to a frame and the pipeline decodes it streaming
    /// ([`crate::compression::aggregate::RoundInFlight::offer_frame`]),
    /// recording measured frame bytes
    /// alongside the idealized estimate.
    pub wire: Option<&'a dyn Codec>,
    /// Partial-participation policy. [`QuorumPolicy::strict`] (the
    /// default config) reproduces the pre-cohort behavior: any slot
    /// fault fails the round with the lowest-slot error. A tolerant
    /// policy retries faulted slots up to its budget, drops what still
    /// fails, and closes the round at quorum via
    /// [`RoundPipeline::finalize_partial`].
    pub policy: &'a QuorumPolicy,
    /// Round index, stamped into trace events and timing records. Pure
    /// observability — never an input to sampling or aggregation.
    pub round: u64,
    /// Structured trace sink (`crate::trace`). When set, the engine and
    /// the round pipeline stamp phase spans, per-slot timeline events,
    /// and the round's arrival histogram into it; `None` (the default
    /// everywhere) keeps the per-upload hot path free of clock reads
    /// and allocation.
    pub trace: Option<Arc<TraceSink>>,
}

/// Everything one round of client compute produces.
pub struct RoundOutput {
    /// Per-slot client training loss, in participant order (0.0 for
    /// dropped slots — consult `membership` before averaging).
    pub losses: Vec<f32>,
    /// Mean training loss over the *arrived* slots, reduced in slot
    /// order (scheduling-invariant).
    pub mean_loss: f64,
    /// Merged weighted upload sum (`Σ λ_i · upload_i`, renormalized
    /// over the arrived subset when the round closed at quorum).
    /// Return it to the pipeline's pool ([`RoundPipeline::recycle`])
    /// after the server consumes it.
    pub merged: RoundAccum,
    /// Per-slot outcomes: who arrived, who retried, who dropped.
    pub membership: RoundMembership,
    /// Payload bytes of one upload under the paper's idealized
    /// accounting (all uploads of a strategy are the same size; sampled
    /// from the lowest computed slot, so the number stays real even
    /// when slot 0 drops out of a quorum round).
    pub upload_bytes_per_client: u64,
    /// Measured wire-frame bytes of one upload (0 when wire mode is
    /// off).
    pub wire_upload_bytes_per_client: u64,
    /// Absorb-phase contention counters (shard-lock stalls, parked
    /// bytes) for this round.
    pub absorb_stats: AbsorbStats,
    /// Wall-clock phase durations. `round_ms` / `compute_ms` /
    /// `reduce_ms` are always measured (a handful of per-round clock
    /// reads); `absorb_ms` needs per-upload timing and is only nonzero
    /// when a trace sink was attached.
    pub timing: RoundTiming,
    /// Slot-arrival latencies (µs from round start to each upload's
    /// offer), recorded only when a trace sink was attached — empty
    /// otherwise. Merging across rounds is exact.
    pub arrivals: Histogram,
}

/// One worker's contribution to the round (everything except the
/// uploads themselves, which stream into the shared pipeline).
struct WorkerOut {
    /// (slot, loss, retries used) for the slots this worker delivered.
    pairs: Vec<(usize, f32, usize)>,
    /// (slot, idealized payload bytes, wire frame bytes) of the lowest
    /// slot this worker computed. All of a strategy's uploads are the
    /// same size (the accounting convention), but sampling the lowest
    /// *computed* slot — instead of slot 0 — keeps the numbers real
    /// when slot 0 drops out of a quorum round.
    byte_sample: Option<(usize, u64, u64)>,
    /// (slot, final error, retries used) for slots this worker gave up
    /// on; sorted by slot at the join so failure reporting stays
    /// deterministic.
    errs: Vec<(usize, anyhow::Error, usize)>,
    /// Slots skipped because the round deadline had already fired.
    missed: Vec<usize>,
    /// Arrival latencies (µs since round start) of the slots this
    /// worker delivered — recorded only when tracing, merged across
    /// workers at the join (exact, per `trace::hist`).
    arrivals: Histogram,
    /// Cumulative nanoseconds this worker spent inside pipeline offers
    /// (the absorb fold). Only measured when tracing — with no sink the
    /// per-upload path reads no clocks.
    absorb_ns: u64,
}

/// Execute one federated round's client work: workers pull participant
/// slots off a shared counter, run the client compute, and offer each
/// upload (weighted by `weights[slot]`) to the round pipeline the
/// moment it completes — through the wire encoding when `ctx.wire` is
/// set. Returns the fully merged accumulator and per-slot losses.
pub fn run_round(
    ctx: &RoundCtx<'_>,
    participants: &[usize],
    weights: &[f32],
    spec: &UploadSpec,
    pipeline: &mut RoundPipeline,
) -> Result<RoundOutput> {
    assert_eq!(participants.len(), weights.len(), "one weight per participant");
    let slots = participants.len();
    // Timing instrumentation is two-tier: a handful of per-round
    // Instants (always on — they feed `RoundRecord::round_ms`), and
    // per-upload clock reads plus slot events (only when `ctx.trace` is
    // set — the disabled hot path stays syscall-free).
    let round_t0 = Instant::now();
    let trace = ctx.trace.as_deref();
    let round_start_us = trace.map_or(0, |t| t.now_us());
    let mut round = pipeline.begin(spec, weights.to_vec())?;
    if let Some(t) = &ctx.trace {
        round.attach_trace(t.clone(), ctx.round);
    }
    let round = round;
    let threads = ctx.threads.clamp(1, slots);
    let stacked_k = ctx.client.wants_stacked_batches();

    let next = AtomicUsize::new(0);
    let deadline = ctx.policy.round_deadline().map(|d| Instant::now() + d);
    let max_retries = ctx.policy.max_slot_retries();

    // No cross-worker abort flag: every slot is attempted even when
    // another slot has already failed, so the *set* of failing slots —
    // and therefore the lowest-slot error the caller sees — is a pure
    // function of the round, not of scheduling. (A failed round costs
    // one full round of client compute, exactly as the pre-pipeline
    // engine did.) The round deadline is the one wall-clock input:
    // slots not yet started when it fires are skipped, to be dropped —
    // or to fail the round — at the join depending on the quorum.
    let run_worker = || -> WorkerOut {
        let mut out = WorkerOut {
            pairs: Vec::new(),
            byte_sample: None,
            errs: Vec::new(),
            missed: Vec::new(),
            arrivals: Histogram::new(),
            absorb_ns: 0,
        };
        let note_bytes = |out: &mut WorkerOut, slot: usize, payload: u64, wire: u64| {
            if out.byte_sample.map_or(true, |(s, _, _)| slot < s) {
                out.byte_sample = Some((slot, payload, wire));
            }
        };
        loop {
            let slot = next.fetch_add(1, Ordering::Relaxed);
            if slot >= slots {
                break;
            }
            if let Some(dl) = deadline {
                if Instant::now() >= dl {
                    out.missed.push(slot);
                    continue;
                }
            }
            let c = participants[slot];
            let mut retries = 0usize;
            let res = loop {
                let batch = ctx.dataset.client_batch(c, ctx.round_seed);
                let stacked =
                    stacked_k.map(|k| ctx.dataset.client_batches_stacked(c, k, ctx.round_seed));
                match ctx
                    .client
                    .client_round(ctx.artifacts, ctx.w, &batch, c, stacked, ctx.lr)
                    .with_context(|| format!("client {c} (slot {slot})"))
                {
                    Ok(r) => break Ok(r),
                    Err(e) => {
                        if retries >= max_retries {
                            break Err(e);
                        }
                        retries += 1;
                        if let Some(t) = trace {
                            t.slot_event(ctx.round, slot, SlotEvent::Retried, None);
                        }
                    }
                }
            };
            let res = match res {
                Ok(r) => r,
                Err(e) => {
                    out.errs.push((slot, e, retries));
                    continue;
                }
            };
            let payload_bytes = res.upload.payload_bytes();
            // Offer the upload to the shared round immediately —
            // absorb-on-arrival; only the target shard's lock is held,
            // and only for that shard's fold, never client compute.
            if let Some(t) = trace {
                t.slot_event(ctx.round, slot, SlotEvent::Offered, None);
            }
            let offer_t0 = trace.map(|_| Instant::now());
            let offered = match ctx.wire {
                Some(codec) => {
                    let frame = encode_upload(&res.upload, codec);
                    note_bytes(&mut out, slot, payload_bytes, frame.len() as u64);
                    round
                        .offer_frame(slot, frame)
                        .with_context(|| format!("wire upload from client {c} (slot {slot})"))
                }
                None => {
                    note_bytes(&mut out, slot, payload_bytes, 0);
                    round
                        .offer(slot, res.upload)
                        .with_context(|| format!("upload from client {c} (slot {slot})"))
                }
            };
            if let Some(t0) = offer_t0 {
                out.absorb_ns += t0.elapsed().as_nanos() as u64;
            }
            match offered {
                Ok(()) => {
                    if let Some(t) = trace {
                        out.arrivals.record(t.now_us().saturating_sub(round_start_us));
                    }
                    out.pairs.push((slot, res.loss, retries))
                }
                Err(e) => out.errs.push((slot, e, retries)),
            }
        }
        out
    };

    // Placement hint only (`pin_shards`): worker t pins itself to core
    // t before pulling slots, so the shard accumulators it folds into
    // stay in one cache domain. Never affects which bits come out —
    // slot→shard and fold order are fixed regardless of where a worker
    // runs — and the single-threaded path never pins (pinning the
    // caller's thread would outlive the round).
    let pin_workers = pipeline.options().pin_shards;
    if let Some(t) = trace {
        // plan: round entry through accumulator setup, before any
        // client compute starts.
        t.span(ctx.round, Phase::Plan, round_start_us, t.now_us());
    }
    let compute_start_us = trace.map_or(0, |t| t.now_us());
    let compute_t0 = Instant::now();
    let worker_outs: Vec<WorkerOut> = if threads <= 1 {
        vec![run_worker()]
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..threads)
                .map(|t| {
                    let run_worker = &run_worker;
                    scope.spawn(move || {
                        if pin_workers {
                            crate::util::affinity::pin_current_thread(t);
                        }
                        run_worker()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("round worker panicked"))
                .collect()
        })
    };

    let compute_ms = ms_since(compute_t0);
    if let Some(t) = trace {
        // compute: worker-pool span, client compute plus the absorbs
        // interleaved into it.
        t.span(ctx.round, Phase::Compute, compute_start_us, t.now_us());
    }
    let finalize_start_us = trace.map_or(0, |t| t.now_us());

    // Settle the membership; surface the lowest-slot error first when
    // the round cannot close (deterministic failure too).
    let absorb_stats = round.absorb_stats();
    let mut membership = RoundMembership::new(slots, ctx.policy.clone())?;
    let mut faults: Vec<(usize, anyhow::Error)> = Vec::new();
    let mut missed: Vec<usize> = Vec::new();
    let mut losses = vec![0f32; slots];
    let mut upload_bytes_per_client = 0u64;
    let mut wire_upload_bytes_per_client = 0u64;
    let mut sample_slot = usize::MAX;
    let mut arrivals = Histogram::new();
    let mut absorb_ns = 0u64;
    for wo in worker_outs {
        arrivals.merge(&wo.arrivals);
        absorb_ns += wo.absorb_ns;
        if let Some((s, payload, wire)) = wo.byte_sample {
            if s < sample_slot {
                sample_slot = s;
                upload_bytes_per_client = payload;
                wire_upload_bytes_per_client = wire;
            }
        }
        for (slot, loss, retries) in wo.pairs {
            for _ in 0..retries {
                membership.record_retry(slot);
            }
            membership.record_arrival(slot);
            losses[slot] = loss;
        }
        for (slot, e, retries) in wo.errs {
            for _ in 0..retries {
                membership.record_retry(slot);
            }
            faults.push((slot, e));
        }
        missed.extend(wo.missed);
    }
    faults.sort_by_key(|(slot, _)| *slot);
    for &(slot, _) in &faults {
        membership.record_drop(slot, DropReason::Faulted);
        if let Some(t) = trace {
            t.slot_dropped(ctx.round, slot, "faulted");
        }
    }
    for slot in missed {
        membership.record_drop(slot, DropReason::Deadline);
        if let Some(t) = trace {
            t.slot_dropped(ctx.round, slot, "deadline");
        }
    }
    debug_assert!(membership.is_settled());
    if !membership.quorum_met() {
        pipeline.abort(round);
        let (arrived, target) = (membership.arrived(), membership.quorum_target());
        return Err(match faults.into_iter().next() {
            Some((_, e)) => e,
            None => anyhow!(
                "round deadline expired with {arrived} of {slots} uploads \
                 (quorum target {target})"
            ),
        });
    }
    if let Some(t) = trace {
        // finalize: worker join through the quorum decision.
        t.span(ctx.round, Phase::Finalize, finalize_start_us, t.now_us());
    }
    let reduce_start_us = trace.map_or(0, |t| t.now_us());
    let reduce_t0 = Instant::now();
    let merged = if membership.is_full() {
        pipeline.finish(round)?
    } else {
        pipeline.finalize_partial(round, &membership)?
    };
    let reduce_ms = ms_since(reduce_t0);
    if let Some(t) = trace {
        t.span(ctx.round, Phase::Reduce, reduce_start_us, t.now_us());
        t.histogram(Some(ctx.round), "slot_arrival_us", &arrivals);
    }
    let mean_loss = membership.mean_loss_over_arrived(&losses);
    Ok(RoundOutput {
        losses,
        mean_loss,
        merged,
        membership,
        upload_bytes_per_client,
        wire_upload_bytes_per_client,
        absorb_stats,
        timing: RoundTiming {
            round_ms: ms_since(round_t0),
            compute_ms,
            absorb_ms: absorb_ns as f64 / 1e6,
            reduce_ms,
        },
        arrivals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cohort::SlotOutcome;
    use crate::compression::aggregate::{
        resolve_parallelism, shard_count, PipelineOptions, MAX_SHARDS,
    };
    use crate::compression::sim::{sim_artifacts, SimDataset, SimFlakyClient, SimSketchClient};
    use crate::compression::ServerAggregator;
    use crate::wire::F32LE;

    const DIM: usize = 5000;
    const ROWS: usize = 5;
    const COLS: usize = 512;
    const SEED: u64 = 21;

    fn sim_round(threads: usize, w_cohort: usize, wire: bool) -> (Vec<f32>, Vec<f32>) {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let participants: Vec<usize> = (0..w_cohort).collect();
        let weights = vec![1.0 / w_cohort as f32; w_cohort];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        let policy = QuorumPolicy::strict();
        let ctx = RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: 0xFEED,
            threads,
            wire: if wire { Some(&F32LE) } else { None },
            policy: &policy,
            round: 0,
            trace: None,
        };
        let mut pipeline = RoundPipeline::new(PipelineOptions::default());
        let out = run_round(&ctx, &participants, &weights, &spec, &mut pipeline).unwrap();
        assert_eq!(out.merged.absorbed(), w_cohort);
        assert!(out.membership.is_full());
        assert_eq!(out.upload_bytes_per_client, (ROWS * COLS * 4) as u64);
        if wire {
            assert!(
                out.wire_upload_bytes_per_client > out.upload_bytes_per_client,
                "frames carry header+shape overhead"
            );
        } else {
            assert_eq!(out.wire_upload_bytes_per_client, 0);
        }
        assert_eq!(
            pipeline.pooled(),
            shard_count(w_cohort) - 1,
            "tail shards return to the pool"
        );
        let table = out.merged.into_sketch().unwrap().table().to_vec();
        (out.losses, table)
    }

    #[test]
    fn thread_count_does_not_change_bits() {
        for cohort in [3usize, 16, 33] {
            let (l1, t1) = sim_round(1, cohort, false);
            // 40 > cohort exercises the slot-count clamp; 8 and 3 leave
            // multiple slots per worker with uneven hand-offs.
            for threads in [2usize, 3, 8, 40] {
                let (ln, tn) = sim_round(threads, cohort, false);
                assert_eq!(
                    l1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    ln.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "losses differ at {threads} threads (cohort {cohort})"
                );
                assert_eq!(
                    t1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    tn.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "merged sketch differs at {threads} threads (cohort {cohort})"
                );
            }
        }
    }

    #[test]
    fn wire_mode_does_not_change_bits_under_f32le() {
        for (threads, cohort) in [(1usize, 5usize), (4, 33)] {
            let (l_mem, t_mem) = sim_round(threads, cohort, false);
            let (l_wire, t_wire) = sim_round(threads, cohort, true);
            assert_eq!(
                l_mem.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                l_wire.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            );
            assert_eq!(
                t_mem.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                t_wire.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "wire round-trip changed the merged sketch (threads {threads})"
            );
        }
    }

    #[test]
    fn pipeline_pool_is_reused_across_rounds() {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let participants: Vec<usize> = (0..8).collect();
        let weights = vec![0.125f32; 8];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        let mut pipeline = RoundPipeline::new(PipelineOptions::default());
        let mut tables = Vec::new();
        let policy = QuorumPolicy::strict();
        for _ in 0..3 {
            let ctx = RoundCtx {
                client: &client,
                artifacts: &artifacts,
                dataset: &dataset,
                w: &w,
                lr: 0.1,
                round_seed: 0xFEED, // same seed: rounds must be identical
                threads: 4,
                wire: None,
                policy: &policy,
                round: 0,
                trace: None,
            };
            let out = run_round(&ctx, &participants, &weights, &spec, &mut pipeline).unwrap();
            tables.push(out.merged.as_sketch().unwrap().table().to_vec());
            pipeline.recycle(out.merged); // trainer's return-to-pool step
            assert_eq!(pipeline.pooled(), 8);
        }
        // Reused (reset) accumulators must not leak state between rounds.
        for t in &tables[1..] {
            assert_eq!(
                tables[0].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                t.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn shard_layout_is_parallelism_invariant() {
        assert_eq!(shard_count(1), 1);
        assert_eq!(shard_count(7), 7);
        assert_eq!(shard_count(MAX_SHARDS), MAX_SHARDS);
        assert_eq!(shard_count(100), MAX_SHARDS);
        assert_eq!(shard_count(0), 1);
        assert!(resolve_parallelism(0) >= 1);
        assert_eq!(resolve_parallelism(3), 3);
    }

    #[test]
    fn strict_policy_fails_on_a_flaky_slot_with_the_lowest_slot_error() {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimFlakyClient {
            inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 },
            fail: [2usize, 5].into_iter().collect(),
        };
        let participants: Vec<usize> = (0..8).collect();
        let weights = vec![0.125f32; 8];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        let policy = QuorumPolicy::strict();
        let ctx = RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: 1,
            threads: 4,
            wire: None,
            policy: &policy,
            round: 0,
            trace: None,
        };
        let mut pipeline = RoundPipeline::new(PipelineOptions::default());
        let err = run_round(&ctx, &participants, &weights, &spec, &mut pipeline)
            .unwrap_err()
            .to_string();
        assert!(err.contains("client 2"), "lowest-slot error first: {err}");
    }

    #[test]
    fn quorum_policy_drops_flaky_slots_and_renormalizes() {
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 100 };
        let client = SimFlakyClient {
            inner: SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 },
            fail: [2usize, 5].into_iter().collect(),
        };
        let participants: Vec<usize> = (0..8).collect();
        let weights = vec![0.125f32; 8];
        let spec = UploadSpec::Sketch { rows: ROWS, cols: COLS, dim: DIM, seed: SEED };
        let w = vec![0f32; DIM];
        // Retries are charged (and visible) even though a deterministic
        // failure never recovers.
        let policy = QuorumPolicy::new(0.5, 0, 1).unwrap();
        let run = |threads: usize| {
            let ctx = RoundCtx {
                client: &client,
                artifacts: &artifacts,
                dataset: &dataset,
                w: &w,
                lr: 0.1,
                round_seed: 1,
                threads,
                wire: None,
                policy: &policy,
                round: 0,
                trace: None,
            };
            let mut pipeline = RoundPipeline::new(PipelineOptions::default());
            let out = run_round(&ctx, &participants, &weights, &spec, &mut pipeline).unwrap();
            assert_eq!(out.membership.arrived(), 6);
            assert_eq!(out.membership.summary().dropped_slots, 2);
            assert_eq!(out.membership.summary().retried_slots, 2);
            assert!(matches!(out.membership.outcome(2), SlotOutcome::Dropped(_)));
            assert_eq!(out.merged.absorbed(), 6);
            (out.merged.into_sketch().unwrap().table().to_vec(), out.mean_loss)
        };
        let (t1, m1) = run(1);
        for threads in [3usize, 8] {
            let (tn, mn) = run(threads);
            assert_eq!(
                t1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                tn.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "partial-round merge differs at {threads} threads"
            );
            assert_eq!(m1.to_bits(), mn.to_bits());
        }
        // Below quorum the round still fails loudly.
        let policy = QuorumPolicy::new(0.9, 0, 0).unwrap();
        let ctx = RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: 1,
            threads: 4,
            wire: None,
            policy: &policy,
            round: 0,
            trace: None,
        };
        let mut pipeline = RoundPipeline::new(PipelineOptions::default());
        assert!(run_round(&ctx, &participants, &weights, &spec, &mut pipeline).is_err());
    }

    #[test]
    fn engine_feeds_a_full_aggregator_pipeline() {
        // One end-to-end sim round through a real FetchSGD server.
        use crate::compression::fetchsgd::{ErrorUpdate, FetchSgdServer};
        let artifacts = sim_artifacts(DIM, ROWS, COLS, SEED).unwrap();
        let dataset = SimDataset { num_clients: 50 };
        let client = SimSketchClient { rows: ROWS, cols: COLS, seed: SEED, dim: DIM, heavy: 3 };
        let mut server = FetchSgdServer::new(
            ROWS, COLS, SEED, DIM, 20, 0.9, ErrorUpdate::ZeroOut, true, "vanilla",
        )
        .unwrap();
        let participants: Vec<usize> = (0..10).collect();
        let sizes: Vec<f32> = participants.iter().map(|&c| dataset.client_size(c) as f32).collect();
        let weights = server.begin_round(&sizes);
        let mut w = vec![0f32; DIM];
        let policy = QuorumPolicy::strict();
        let ctx = RoundCtx {
            client: &client,
            artifacts: &artifacts,
            dataset: &dataset,
            w: &w,
            lr: 0.1,
            round_seed: 7,
            threads: 4,
            wire: None,
            policy: &policy,
            round: 0,
            trace: None,
        };
        let mut pipeline = RoundPipeline::new(PipelineOptions::default());
        let out = run_round(&ctx, &participants, &weights, &server.upload_spec(), &mut pipeline)
            .unwrap();
        let update = server.finish(&out.merged, 0.1).unwrap();
        update.apply(&mut w);
        assert!(update.nnz() > 0);
        assert!(w.iter().any(|&x| x != 0.0), "model should move");
    }
}
