//! Client selection: uniform random sampling of W distinct clients per
//! round (paper §3.1: "the aggregator chooses W clients uniformly at
//! random"). Deterministic given the run seed; a round's participant set
//! is reproducible independently of execution order.
//!
//! Selection produces the *plan* only — `crate::cohort::CohortPlan`
//! wraps a selected cohort with its dataset sizes, and
//! `crate::cohort::RoundMembership` tracks which of the planned slots
//! actually deliver an upload (partial-cohort rounds close at a quorum
//! of the plan, not necessarily all of it).

use crate::util::rng::{derive_seed, Rng};

pub struct ClientSelector {
    num_clients: usize,
    per_round: usize,
    seed: u64,
}

impl ClientSelector {
    pub fn new(num_clients: usize, per_round: usize, seed: u64) -> Self {
        assert!(per_round >= 1, "need at least one client per round");
        assert!(
            per_round <= num_clients,
            "clients_per_round {per_round} > population {num_clients}"
        );
        ClientSelector { num_clients, per_round, seed }
    }

    /// The participant set for `round`.
    pub fn select(&self, round: usize) -> Vec<usize> {
        let mut rng = Rng::new(derive_seed(self.seed, round as u64));
        rng.sample_distinct(self.num_clients, self.per_round)
    }

    /// Clients sampled per round (W) — the planned cohort size every
    /// `select` returns.
    pub fn per_round(&self) -> usize {
        self.per_round
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_round() {
        let s = ClientSelector::new(100, 10, 7);
        assert_eq!(s.select(3), s.select(3));
        assert_ne!(s.select(3), s.select(4));
    }

    #[test]
    fn distinct_and_in_range() {
        let s = ClientSelector::new(50, 50, 1);
        let sel = s.select(0);
        let set: std::collections::HashSet<_> = sel.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn coverage_over_many_rounds() {
        // Every client should participate eventually (uniformity smoke
        // test).
        let s = ClientSelector::new(30, 3, 99);
        let mut seen = vec![false; 30];
        for r in 0..200 {
            for c in s.select(r) {
                seen[c] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    #[should_panic]
    fn rejects_oversized_w() {
        ClientSelector::new(5, 6, 0);
    }
}
