//! Frame encoding/parsing for uploads and broadcasts.
//!
//! See `crate::wire` module docs for the byte-level layout table. A
//! parsed [`Frame`] is a *borrowed view* into the frame bytes: shape
//! fields are decoded, payload bytes are sliced but not decoded, so
//! consumers can stream values straight out of the receive buffer
//! ([`Values::for_each`]) — the zero-copy absorb path.

use anyhow::{bail, Context, Result};

use crate::compression::{ClientUpload, RoundUpdate};
use crate::serialize::le::{extend_u32_le, for_each_u32_le};
use crate::sketch::{CountSketch, SparseVec};
use crate::wire::codec::{codec_by_id, Codec};

/// Frame magic: "FSGW" (FetchSGD Wire).
pub const MAGIC: [u8; 4] = *b"FSGW";
/// Current frame version. Receivers reject any other value — versioning
/// rule: bump on ANY layout change; decoders never guess.
pub const VERSION: u8 = 1;
/// Fixed prefix: magic + version + codec id + kind + reserved zero.
pub const HEADER_LEN: usize = 8;

/// Payload kind tag (header byte 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// R×C Count-Sketch table (FetchSGD uploads).
    Sketch = 0,
    /// k-sparse vector: sorted u32 indices + values (top-k uploads,
    /// sparse broadcasts).
    Sparse = 1,
    /// Dense vector (dense-baseline uploads, dense broadcasts).
    Dense = 2,
}

impl Kind {
    fn from_tag(tag: u8) -> Result<Kind> {
        match tag {
            0 => Ok(Kind::Sketch),
            1 => Ok(Kind::Sparse),
            2 => Ok(Kind::Dense),
            other => bail!("unknown wire payload kind {other}"),
        }
    }
}

/// A codec-tagged, length-validated view of a frame's value payload.
pub struct Values<'a> {
    codec: &'static dyn Codec,
    bytes: &'a [u8],
    n: usize,
}

impl Values<'_> {
    /// Number of encoded values.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Stream every value, in order, without materializing a buffer.
    pub fn for_each(&self, sink: &mut dyn FnMut(f32)) {
        self.codec.decode_values(self.bytes, sink);
    }

    /// `dst[i] += weight * value[i]` for every value, in order — the
    /// blocked absorb fold ([`crate::wire::codec::Codec::axpy_values`]).
    /// Bitwise identical to streaming [`Values::for_each`] through the
    /// same fold. `dst.len()` must equal [`Values::len`].
    pub fn axpy_into(&self, weight: f32, dst: &mut [f32]) {
        debug_assert_eq!(self.n, dst.len());
        self.codec.axpy_values(self.bytes, weight, dst);
    }

    /// Materialize (frame→struct decode; tests).
    pub fn to_vec(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n);
        self.for_each(&mut |v| out.push(v));
        out
    }
}

/// A parsed frame: borrowed shape header + payload slices.
pub struct Frame<'a> {
    pub codec: &'static dyn Codec,
    pub body: Body<'a>,
}

/// Kind-specific shape header + payload views.
pub enum Body<'a> {
    Sketch { rows: usize, cols: usize, dim: usize, seed: u64, values: Values<'a> },
    Sparse { dim: usize, idx: &'a [u8], values: Values<'a> },
    Dense { dim: usize, values: Values<'a> },
}

impl<'a> Frame<'a> {
    pub fn kind(&self) -> Kind {
        match self.body {
            Body::Sketch { .. } => Kind::Sketch,
            Body::Sparse { .. } => Kind::Sparse,
            Body::Dense { .. } => Kind::Dense,
        }
    }

    /// Parse and fully validate a frame: magic, version, codec id, kind
    /// tag, shape-header bounds, exact payload length (no trailing
    /// bytes), and — for sparse frames — strictly-increasing in-range
    /// indices. Everything fails loudly; nothing is decoded lazily
    /// except the values themselves.
    pub fn parse(bytes: &'a [u8]) -> Result<Frame<'a>> {
        if bytes.len() < HEADER_LEN {
            bail!(
                "wire frame of {} bytes is shorter than the {HEADER_LEN}-byte header",
                bytes.len()
            );
        }
        if bytes[..4] != MAGIC {
            bail!("bad wire magic {:02x?} (expected {MAGIC:02x?})", &bytes[..4]);
        }
        if bytes[4] != VERSION {
            bail!("unsupported wire version {} (this build speaks {VERSION})", bytes[4]);
        }
        let codec = codec_by_id(bytes[5]).context("frame codec id")?;
        let kind = Kind::from_tag(bytes[6])?;
        if bytes[7] != 0 {
            bail!("nonzero reserved header byte {}", bytes[7]);
        }
        let rest = &bytes[HEADER_LEN..];
        let body = match kind {
            Kind::Sketch => {
                let (shape, payload) = split_shape(rest, 24)?;
                let rows = u32::from_le_bytes(shape[0..4].try_into().unwrap()) as usize;
                let cols = u32::from_le_bytes(shape[4..8].try_into().unwrap()) as usize;
                // Sanity bounds (generous vs. the hasher's own limits)
                // keep `rows * cols` far from overflow and forged frames
                // from requesting absurd allocations downstream.
                if rows == 0 || rows > 256 || !cols.is_power_of_two() || cols > 1 << 30 {
                    bail!("sketch frame geometry {rows}x{cols} out of range");
                }
                let dim = checked_dim(u64::from_le_bytes(shape[8..16].try_into().unwrap()))?;
                let seed = u64::from_le_bytes(shape[16..24].try_into().unwrap());
                let values = take_values(codec, payload, rows * cols)?;
                Body::Sketch { rows, cols, dim, seed, values }
            }
            Kind::Sparse => {
                let (shape, payload) = split_shape(rest, 16)?;
                let dim = checked_dim(u64::from_le_bytes(shape[0..8].try_into().unwrap()))?;
                let nnz = u64::from_le_bytes(shape[8..16].try_into().unwrap()) as usize;
                if nnz > dim {
                    bail!("sparse frame claims {nnz} nonzeros in dimension {dim}");
                }
                let idx_len = nnz.saturating_mul(4);
                if payload.len() < idx_len {
                    bail!(
                        "sparse frame truncated: {} payload bytes, need {idx_len} for indices alone",
                        payload.len()
                    );
                }
                let (idx, vals) = payload.split_at(idx_len);
                validate_sparse_indices(idx, dim)?;
                let values = take_values(codec, vals, nnz)?;
                Body::Sparse { dim, idx, values }
            }
            Kind::Dense => {
                let (shape, payload) = split_shape(rest, 8)?;
                let dim = checked_dim(u64::from_le_bytes(shape[0..8].try_into().unwrap()))?;
                let values = take_values(codec, payload, dim)?;
                Body::Dense { dim, values }
            }
        };
        Ok(Frame { codec, body })
    }
}

fn split_shape(rest: &[u8], shape_len: usize) -> Result<(&[u8], &[u8])> {
    if rest.len() < shape_len {
        bail!("wire frame truncated inside the {shape_len}-byte shape header");
    }
    Ok(rest.split_at(shape_len))
}

fn checked_dim(dim: u64) -> Result<usize> {
    if dim == 0 || dim > u32::MAX as u64 {
        bail!("wire frame dim {dim} out of range");
    }
    Ok(dim as usize)
}

fn take_values<'a>(codec: &'static dyn Codec, payload: &'a [u8], n: usize) -> Result<Values<'a>> {
    let want = codec.encoded_len(n);
    if payload.len() != want {
        bail!(
            "wire payload is {} bytes, expected {want} ({n} values under {})",
            payload.len(),
            codec.name()
        );
    }
    Ok(Values { codec, bytes: payload, n })
}

/// Sparse index arrays must be strictly increasing and in range — the
/// invariant `SparseVec` maintains, checked here so a corrupt frame
/// cannot smuggle out-of-bounds writes into an accumulator.
fn validate_sparse_indices(idx: &[u8], dim: usize) -> Result<()> {
    let mut prev: i64 = -1;
    let mut bad = None;
    for_each_u32_le(idx, &mut |i| {
        if bad.is_none() && (i as i64 <= prev || i as usize >= dim) {
            bad = Some(i);
        }
        prev = i as i64;
    });
    if let Some(i) = bad {
        bail!("sparse frame index {i} is out of order or exceeds dim {dim}");
    }
    Ok(())
}

fn header(codec: &dyn Codec, kind: Kind, cap: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + cap);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(codec.id());
    out.push(kind as u8);
    out.push(0);
    out
}

fn encode_sketch(s: &CountSketch, codec: &dyn Codec) -> Vec<u8> {
    let mut out = header(codec, Kind::Sketch, 24 + codec.encoded_len(s.cells()));
    out.extend_from_slice(&(s.rows() as u32).to_le_bytes());
    out.extend_from_slice(&(s.cols() as u32).to_le_bytes());
    out.extend_from_slice(&(s.dim() as u64).to_le_bytes());
    out.extend_from_slice(&s.seed().to_le_bytes());
    codec.encode_values(s.table(), &mut out);
    out
}

fn encode_sparse(sv: &SparseVec, codec: &dyn Codec) -> Vec<u8> {
    let mut out = header(codec, Kind::Sparse, 16 + 4 * sv.nnz() + codec.encoded_len(sv.nnz()));
    out.extend_from_slice(&(sv.dim as u64).to_le_bytes());
    out.extend_from_slice(&(sv.nnz() as u64).to_le_bytes());
    extend_u32_le(&mut out, &sv.idx);
    codec.encode_values(&sv.val, &mut out);
    out
}

fn encode_dense(v: &[f32], codec: &dyn Codec) -> Vec<u8> {
    let mut out = header(codec, Kind::Dense, 8 + codec.encoded_len(v.len()));
    out.extend_from_slice(&(v.len() as u64).to_le_bytes());
    codec.encode_values(v, &mut out);
    out
}

/// Encode a bare dense vector as one frame — the transport layer's
/// per-round weights broadcast (same grammar as dense uploads/updates,
/// so receivers need no extra machinery).
pub fn encode_dense_frame(v: &[f32], codec: &dyn Codec) -> Vec<u8> {
    encode_dense(v, codec)
}

/// Encode a bare sketch as one frame — the relay tier's merged-subtree
/// upload (a λ-weighted partial sum of downstream sketches is itself a
/// valid sketch upload, so it travels in the same grammar). Always pair
/// with a lossless codec: the merged accumulator must survive the hop
/// bit-for-bit for tree aggregation to stay deterministic.
pub fn encode_sketch_frame(s: &CountSketch, codec: &dyn Codec) -> Vec<u8> {
    encode_sketch(s, codec)
}

/// Decode a frame that must carry a dense payload (the transport
/// client's view of the weights broadcast). Rejects sketch/sparse
/// frames.
pub fn decode_dense_frame(bytes: &[u8]) -> Result<Vec<f32>> {
    match Frame::parse(bytes)?.body {
        Body::Dense { values, .. } => Ok(values.to_vec()),
        Body::Sketch { .. } | Body::Sparse { .. } => {
            bail!("expected a dense frame, got a different payload kind")
        }
    }
}

/// Encode a client upload as one frame.
pub fn encode_upload(upload: &ClientUpload, codec: &dyn Codec) -> Vec<u8> {
    match upload {
        ClientUpload::Sketch(s) => encode_sketch(s, codec),
        ClientUpload::Sparse(sv) => encode_sparse(sv, codec),
        ClientUpload::Dense(v) => encode_dense(v, codec),
    }
}

/// Decode a frame into an owned [`ClientUpload`] (generic consumers and
/// tests; the aggregation hot path uses
/// `RoundAccum::absorb_bytes` instead, which never materializes this).
pub fn decode_upload(bytes: &[u8]) -> Result<ClientUpload> {
    let frame = Frame::parse(bytes)?;
    Ok(match frame.body {
        Body::Sketch { rows, cols, dim, seed, values } => {
            ClientUpload::Sketch(CountSketch::from_table(rows, cols, dim, seed, values.to_vec())?)
        }
        Body::Sparse { dim, idx, values } => {
            let mut indices = Vec::with_capacity(idx.len() / 4);
            for_each_u32_le(idx, &mut |i| indices.push(i));
            ClientUpload::Sparse(SparseVec::from_sorted(dim, indices, values.to_vec())?)
        }
        Body::Dense { values, .. } => ClientUpload::Dense(values.to_vec()),
    })
}

/// Encode the server's broadcast update as one frame (same grammar as
/// uploads; broadcasts are never sketches).
pub fn encode_update(update: &RoundUpdate, codec: &dyn Codec) -> Vec<u8> {
    match update {
        RoundUpdate::Sparse(sv) => encode_sparse(sv, codec),
        RoundUpdate::Dense(step) => encode_dense(step, codec),
    }
}

/// Decode a broadcast frame. Rejects sketch frames: no strategy
/// broadcasts a sketch.
pub fn decode_update(bytes: &[u8]) -> Result<RoundUpdate> {
    match decode_upload(bytes)? {
        ClientUpload::Sparse(sv) => Ok(RoundUpdate::Sparse(sv)),
        ClientUpload::Dense(v) => Ok(RoundUpdate::Dense(v)),
        ClientUpload::Sketch(_) => bail!("broadcast frames cannot carry a sketch payload"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::codec::{F16LE, F32LE};

    fn sketch_upload() -> ClientUpload {
        let g: Vec<f32> = (0..500).map(|i| (i as f32 * 0.7).sin()).collect();
        ClientUpload::Sketch(CountSketch::encode(3, 128, 9, &g).unwrap())
    }

    fn sparse_upload() -> ClientUpload {
        ClientUpload::Sparse(SparseVec::from_pairs(
            1000,
            vec![(3, 1.5), (17, -2.25), (999, 0.125)],
        ))
    }

    fn dense_upload() -> ClientUpload {
        ClientUpload::Dense((0..257).map(|i| i as f32 - 128.0).collect())
    }

    #[test]
    fn f32le_upload_roundtrip_is_exact_for_all_kinds() {
        for upload in [sketch_upload(), sparse_upload(), dense_upload()] {
            let frame = encode_upload(&upload, &F32LE);
            let back = decode_upload(&frame).unwrap();
            match (&upload, &back) {
                (ClientUpload::Sketch(a), ClientUpload::Sketch(b)) => {
                    assert_eq!(a.rows(), b.rows());
                    assert_eq!(a.cols(), b.cols());
                    assert_eq!(a.dim(), b.dim());
                    assert_eq!(a.seed(), b.seed());
                    for (x, y) in a.table().iter().zip(b.table()) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (ClientUpload::Sparse(a), ClientUpload::Sparse(b)) => assert_eq!(a, b),
                (ClientUpload::Dense(a), ClientUpload::Dense(b)) => assert_eq!(a, b),
                _ => panic!("payload kind changed across the wire"),
            }
        }
    }

    #[test]
    fn frame_bytes_exceed_idealized_payload() {
        for upload in [sketch_upload(), sparse_upload(), dense_upload()] {
            let frame = encode_upload(&upload, &F32LE);
            assert!(
                frame.len() as u64 > upload.payload_bytes(),
                "measured {} <= idealized {}",
                frame.len(),
                upload.payload_bytes()
            );
        }
    }

    #[test]
    fn f16_halves_value_bytes() {
        let frame32 = encode_upload(&dense_upload(), &F32LE);
        let frame16 = encode_upload(&dense_upload(), &F16LE);
        assert_eq!(frame32.len() - HEADER_LEN - 8, 2 * (frame16.len() - HEADER_LEN - 8));
        assert!(decode_upload(&frame16).is_ok());
    }

    #[test]
    fn corrupt_frames_fail_loudly() {
        let good = encode_upload(&sparse_upload(), &F32LE);
        assert!(decode_upload(&good).is_ok());

        let mut bad = good.clone();
        bad[0] = b'X'; // magic
        assert!(decode_upload(&bad).unwrap_err().to_string().contains("magic"));

        let mut bad = good.clone();
        bad[4] = 2; // version
        assert!(decode_upload(&bad).unwrap_err().to_string().contains("version"));

        let mut bad = good.clone();
        bad[5] = 250; // codec id
        assert!(decode_upload(&bad).unwrap_err().to_string().contains("codec"));

        let mut bad = good.clone();
        bad[6] = 9; // kind
        assert!(decode_upload(&bad).is_err());

        let mut bad = good.clone();
        bad[7] = 1; // reserved
        assert!(decode_upload(&bad).unwrap_err().to_string().contains("reserved"));

        // truncation at every prefix length must error, never panic
        for cut in 0..good.len() {
            assert!(decode_upload(&good[..cut]).is_err(), "prefix {cut} accepted");
        }
        // trailing garbage
        let mut bad = good.clone();
        bad.push(0);
        assert!(decode_upload(&bad).is_err());
    }

    #[test]
    fn sparse_index_corruption_is_rejected() {
        let good = encode_upload(&sparse_upload(), &F32LE);
        // first index (offset: header + dim + nnz) bumped past the second
        let off = HEADER_LEN + 16;
        let mut bad = good.clone();
        bad[off..off + 4].copy_from_slice(&100u32.to_le_bytes());
        let err = decode_upload(&bad).unwrap_err().to_string();
        assert!(err.contains("out of order"), "{err}");
        // index >= dim
        let mut bad = good.clone();
        bad[off..off + 4].copy_from_slice(&5000u32.to_le_bytes());
        assert!(decode_upload(&bad).is_err());
    }

    #[test]
    fn sketch_frames_with_bad_geometry_are_rejected() {
        let frame = encode_upload(&sketch_upload(), &F32LE);
        // cols field (header + rows) → non-power-of-two 100: the payload
        // length no longer matches rows*cols, and even a length-matched
        // forgery dies in CountSketch::from_table's geometry check.
        let mut bad = frame.clone();
        bad[HEADER_LEN + 4..HEADER_LEN + 8].copy_from_slice(&100u32.to_le_bytes());
        assert!(decode_upload(&bad).is_err());
    }

    #[test]
    fn update_frames_roundtrip_and_reject_sketches() {
        let sv = SparseVec::from_pairs(50, vec![(1, 1.0), (30, -0.5)]);
        let frame = encode_update(&RoundUpdate::Sparse(sv.clone()), &F32LE);
        match decode_update(&frame).unwrap() {
            RoundUpdate::Sparse(back) => assert_eq!(back, sv),
            _ => panic!(),
        }
        let step: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let frame = encode_update(&RoundUpdate::Dense(step.clone()), &F32LE);
        match decode_update(&frame).unwrap() {
            RoundUpdate::Dense(back) => assert_eq!(back, step),
            _ => panic!(),
        }
        let sketch_frame = encode_upload(&sketch_upload(), &F32LE);
        assert!(decode_update(&sketch_frame).unwrap_err().to_string().contains("broadcast"));
    }
}
