//! Pluggable value codecs for wire frames.
//!
//! A [`Codec`] turns an f32 value sequence into payload bytes and back.
//! The frame grammar (`crate::wire::frame`) carries the codec id in its
//! header, so a receiver picks the decoder from the frame itself. Two
//! implementations ship:
//!
//! - [`F32Le`] (id 0, lossless) — raw little-endian f32; the default and
//!   the codec under which wire mode is bitwise identical to in-memory
//!   aggregation.
//! - [`F16Le`] (id 1, lossy) — IEEE 754 binary16 with round-to-nearest-
//!   even and saturation at ±65504, halving upload bytes at a bounded
//!   relative error of 2⁻¹¹ (absolute 2⁻²⁵ in the subnormal range).
//!   This is the extension-point proof: quantized uploads in the spirit
//!   of Konečný et al.'s "Strategies for Improving Communication
//!   Efficiency" / FedSKETCH.
//!
//! Decoding streams values through a callback rather than materializing
//! a `Vec<f32>` — see
//! [`crate::compression::aggregate::RoundAccum::absorb_bytes`], which
//! folds frames straight into the accumulator.

use anyhow::{bail, Result};

use crate::serialize::le::{axpy_f32_le, extend_f32_le, for_each_f32_le};

/// A value codec: f32 sequence ↔ payload bytes.
pub trait Codec: Send + Sync {
    /// Wire id carried in the frame header (stable across versions).
    fn id(&self) -> u8;
    /// Human-readable name (config values, logs).
    fn name(&self) -> &'static str;
    /// Whether decode∘encode is the identity on every finite f32.
    fn lossless(&self) -> bool;
    /// Payload bytes for `n` values.
    fn encoded_len(&self, n: usize) -> usize;
    /// Append the encoding of `vals` to `out`.
    fn encode_values(&self, vals: &[f32], out: &mut Vec<u8>);
    /// Stream every value of a payload (whose length the frame parser
    /// has already validated against [`Codec::encoded_len`]) to `sink`,
    /// in order, without materializing an intermediate buffer.
    fn decode_values(&self, bytes: &[u8], sink: &mut dyn FnMut(f32));
    /// `dst[i] += weight * decode(bytes)[i]` for every `i` in order —
    /// the absorb-path fold. The default streams through
    /// [`Codec::decode_values`]; codecs with a cheap fixed-width layout
    /// (f32le) override with a blocked kernel that performs the same
    /// per-cell op in the same order, so results stay bitwise identical.
    fn axpy_values(&self, bytes: &[u8], weight: f32, dst: &mut [f32]) {
        let mut i = 0;
        self.decode_values(bytes, &mut |v| {
            dst[i] += weight * v;
            i += 1;
        });
        debug_assert_eq!(i, dst.len());
    }
}

/// Raw little-endian f32 (lossless default).
pub struct F32Le;

impl Codec for F32Le {
    fn id(&self) -> u8 {
        0
    }
    fn name(&self) -> &'static str {
        "f32le"
    }
    fn lossless(&self) -> bool {
        true
    }
    fn encoded_len(&self, n: usize) -> usize {
        4 * n
    }
    fn encode_values(&self, vals: &[f32], out: &mut Vec<u8>) {
        extend_f32_le(out, vals);
    }
    fn decode_values(&self, bytes: &[u8], sink: &mut dyn FnMut(f32)) {
        for_each_f32_le(bytes, sink);
    }
    fn axpy_values(&self, bytes: &[u8], weight: f32, dst: &mut [f32]) {
        axpy_f32_le(bytes, weight, dst);
    }
}

/// IEEE 754 binary16, little-endian (lossy, 2 bytes/value).
pub struct F16Le;

impl Codec for F16Le {
    fn id(&self) -> u8 {
        1
    }
    fn name(&self) -> &'static str {
        "f16le"
    }
    fn lossless(&self) -> bool {
        false
    }
    fn encoded_len(&self, n: usize) -> usize {
        2 * n
    }
    fn encode_values(&self, vals: &[f32], out: &mut Vec<u8>) {
        out.reserve(vals.len() * 2);
        for &x in vals {
            out.extend_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
    }
    fn decode_values(&self, bytes: &[u8], sink: &mut dyn FnMut(f32)) {
        debug_assert_eq!(bytes.len() % 2, 0);
        for chunk in bytes.chunks_exact(2) {
            sink(f16_bits_to_f32(u16::from_le_bytes(chunk.try_into().unwrap())));
        }
    }
    fn axpy_values(&self, bytes: &[u8], weight: f32, dst: &mut [f32]) {
        // Lane-wise widening absorb: under `--features simd` the halves
        // are widened four at a time in registers by a sequence proven
        // bit-identical to `f16_bits_to_f32` over all 65536 patterns
        // (exhaustive test in `util::simd`), then folded with the same
        // mul-then-add the streamed path performs — so results stay
        // bitwise identical to the default `decode_values` fold.
        crate::util::simd::axpy_f16_le(bytes, weight, dst);
    }
}

/// The codec instances, indexable by wire id.
pub static F32LE: F32Le = F32Le;
pub static F16LE: F16Le = F16Le;

/// Look a codec up by its wire id (frame header byte).
pub fn codec_by_id(id: u8) -> Result<&'static dyn Codec> {
    match id {
        0 => Ok(&F32LE),
        1 => Ok(&F16LE),
        other => bail!("unknown wire codec id {other}"),
    }
}

/// Look a codec up by name (config values: "f32le" | "f16le").
pub fn codec_by_name(name: &str) -> Result<&'static dyn Codec> {
    match name {
        "f32le" => Ok(&F32LE),
        "f16le" => Ok(&F16LE),
        other => bail!("unknown wire codec '{other}' (expected f32le|f16le)"),
    }
}

/// f32 → binary16 bits with round-to-nearest-even. Finite values beyond
/// the half range saturate to ±65504 (keeping the decode error bounded
/// instead of overflowing to ±inf); ±inf maps to ±inf and NaN to the
/// canonical quiet NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;
    if abs >= 0x7f80_0000 {
        // inf / NaN
        return sign | if abs > 0x7f80_0000 { 0x7e00 } else { 0x7c00 };
    }
    // 0x477f_f000 = 65520.0, the smallest f32 that rounds (ties-to-even)
    // past the max finite half 65504: saturate from there up.
    if abs >= 0x477f_f000 {
        return sign | 0x7bff;
    }
    if abs >= 0x3880_0000 {
        // Normal half range (|x| >= 2^-14): rebias exponent, round the
        // 23-bit mantissa to 10 bits. A mantissa carry into the exponent
        // is correct and cannot overflow (saturation above).
        let mut h = (((abs >> 23) - 112) << 10) | ((abs >> 13) & 0x3ff);
        let rem = abs & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && h & 1 == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    // Subnormal half range (|x| < 2^-14): the half value is
    // round(mantissa * 2^(e-126)) units of 2^-24.
    let e = (abs >> 23) as i32; // biased f32 exponent (0 for f32 subnormals)
    let m = (abs & 0x007f_ffff) | if e > 0 { 0x0080_0000 } else { 0 };
    let shift = 126 - e.max(1);
    if shift > 24 {
        return sign; // underflows to ±0 even after rounding
    }
    let mut h = m >> shift;
    let rem = m & ((1u32 << shift) - 1);
    let half = 1u32 << (shift - 1);
    if rem > half || (rem == half && h & 1 == 1) {
        h += 1; // may carry into the exponent: smallest normal, correct
    }
    sign | h as u16
}

/// binary16 bits → f32 (exact: every half value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal half: normalize into an f32 exponent.
            let mut e = 113u32;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn f32le_roundtrip_is_identity() {
        check("f32le identity", 30, |g| {
            let vals = g.vec_f32(1, 500, -1e6, 1e6);
            let mut bytes = Vec::new();
            F32LE.encode_values(&vals, &mut bytes);
            assert_eq!(bytes.len(), F32LE.encoded_len(vals.len()));
            let mut back = Vec::new();
            F32LE.decode_values(&bytes, &mut |v| back.push(v));
            assert_eq!(
                vals.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                back.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        });
    }

    #[test]
    fn f16_roundtrip_over_all_bit_patterns() {
        // decode is exact, so encode(decode(h)) must reproduce every
        // non-NaN half bit pattern (NaNs canonicalize).
        for h in 0..=u16::MAX {
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                assert_eq!(f32_to_f16_bits(x) & 0x7e00, 0x7e00);
                continue;
            }
            assert_eq!(f32_to_f16_bits(x), h, "half bits 0x{h:04x} (value {x})");
        }
    }

    #[test]
    fn f16_error_is_bounded() {
        check("f16 bounded error", 50, |g| {
            let vals = g.vec_f32(1, 300, -60_000.0, 60_000.0);
            let mut bytes = Vec::new();
            F16LE.encode_values(&vals, &mut bytes);
            assert_eq!(bytes.len(), F16LE.encoded_len(vals.len()));
            let mut i = 0;
            F16LE.decode_values(&bytes, &mut |v| {
                let x = vals[i];
                // relative 2^-11 for normals, absolute 2^-25 below them.
                let bound = (x.abs() / 2048.0).max(1.0 / (1u64 << 25) as f32);
                assert!((v - x).abs() <= bound, "x={x} decoded={v}");
                i += 1;
            });
            assert_eq!(i, vals.len());
        });
    }

    #[test]
    fn f16_saturates_and_keeps_specials() {
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), 65504.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1e9)), -65504.0);
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f32_to_f16_bits(-0.0), 0x8000);
        // round-to-nearest-even at the representable midpoint
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2049.0)), 2048.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(2051.0)), 2052.0);
        // tiny values underflow to zero
        assert_eq!(f32_to_f16_bits(1e-9), 0);
    }

    #[test]
    fn axpy_values_matches_streamed_fold_for_both_codecs() {
        check("axpy_values == decode fold", 30, |g| {
            // Lengths deliberately straddle the 8-lane block boundary.
            let vals = g.vec_f32(1, 70, -1000.0, 1000.0);
            for codec in [&F32LE as &dyn Codec, &F16LE as &dyn Codec] {
                let mut bytes = Vec::new();
                codec.encode_values(&vals, &mut bytes);
                let weight = g.f32_in(-2.0, 2.0);
                let mut blocked: Vec<f32> = (0..vals.len()).map(|i| i as f32 * 0.25).collect();
                let mut streamed = blocked.clone();
                codec.axpy_values(&bytes, weight, &mut blocked);
                let mut i = 0;
                codec.decode_values(&bytes, &mut |v| {
                    streamed[i] += weight * v;
                    i += 1;
                });
                assert_eq!(
                    blocked.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    streamed.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "codec {}",
                    codec.name()
                );
            }
        });
    }

    #[test]
    fn registry_resolves_both_ways() {
        for codec in [&F32LE as &dyn Codec, &F16LE as &dyn Codec] {
            assert_eq!(codec_by_id(codec.id()).unwrap().name(), codec.name());
            assert_eq!(codec_by_name(codec.name()).unwrap().id(), codec.id());
        }
        assert!(codec_by_id(99).is_err());
        assert!(codec_by_name("zstd").is_err());
    }
}
