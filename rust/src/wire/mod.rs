//! The wire protocol for the client↔server boundary.
//!
//! FetchSGD's claim is *communication* efficiency, so the thing clients
//! and the server exchange needs to be actual bytes, not in-memory Rust
//! enums, and byte accounting needs a measured number next to the
//! paper's idealized estimate (footnote 5). This module defines the
//! framed, versioned binary encoding for every upload
//! ([`crate::compression::ClientUpload`]) and broadcast
//! ([`crate::compression::RoundUpdate`]), behind a pluggable value
//! [`Codec`] ([`F32Le`] lossless default, [`F16Le`] lossy half-precision
//! proving the extension point).
//!
//! ## Frame layout (version 1)
//!
//! All integers little-endian. One frame = header, shape, payload; the
//! total length must match exactly (no trailing bytes).
//!
//! | offset | size | field                                        |
//! |--------|------|----------------------------------------------|
//! | 0      | 4    | magic `"FSGW"`                               |
//! | 4      | 1    | version (`1`)                                |
//! | 5      | 1    | codec id (`0` = f32le, `1` = f16le)          |
//! | 6      | 1    | payload kind (`0` sketch, `1` sparse, `2` dense) |
//! | 7      | 1    | reserved, must be `0`                        |
//! | 8      | …    | kind-specific shape header (below)           |
//! | …      | …    | payload (codec-encoded values)               |
//!
//! Shape headers and payloads per kind:
//!
//! | kind   | shape header                                | payload |
//! |--------|---------------------------------------------|---------|
//! | sketch | `rows: u32, cols: u32, dim: u64, seed: u64` | `rows·cols` encoded values (row-major table) |
//! | sparse | `dim: u64, nnz: u64`                        | `nnz` raw `u32` indices (strictly increasing, `< dim`), then `nnz` encoded values |
//! | dense  | `dim: u64`                                  | `dim` encoded values |
//!
//! ## Versioning rules
//!
//! - Byte 4 is bumped on **any** change to the header, shape, or payload
//!   layout; receivers reject unknown versions outright (no best-effort
//!   decoding of newer frames).
//! - New codecs and new payload kinds extend their one-byte id spaces
//!   *without* a version bump — an old receiver rejects the unknown id
//!   loudly, which is the intended failure mode.
//! - Sparse indices are always raw little-endian `u32`, independent of
//!   the value codec: the codec compresses *values*, index compression
//!   would be a new payload kind.
//!
//! ## Validation
//!
//! [`Frame::parse`] checks magic, version, codec id, kind tag, the
//! reserved byte, shape-header bounds, exact payload length, and sparse
//! index monotonicity/range, so a corrupted or truncated frame can never
//! reach an accumulator. [`crate::compression::UploadSpec::validate_frame`]
//! additionally pins a parsed frame against the geometry the server
//! expects this round (rows/cols/dim/seed), making shape or seed drift
//! between client and server a loud error rather than silent garbage.
//!
//! ## Zero-copy absorb
//!
//! A parsed [`Frame`] borrows the receive buffer; value payloads are
//! decoded by streaming ([`frame::Values::for_each`]) so the server's
//! aggregation path
//! ([`crate::compression::aggregate::RoundAccum::absorb_bytes`])
//! folds `weight · value` straight from the wire bytes into the
//! accumulator — no intermediate `ClientUpload`, table, or `Vec<f32>`
//! is ever materialized for uploads in wire mode.

pub mod codec;
pub mod frame;

pub use codec::{codec_by_id, codec_by_name, Codec, F16Le, F16LE, F32Le, F32LE};
pub use frame::{
    decode_dense_frame, decode_update, decode_upload, encode_dense_frame, encode_sketch_frame,
    encode_update, encode_upload, Body, Frame, Kind, HEADER_LEN, MAGIC, VERSION,
};
