//! PJRT runtime: loads the AOT-compiled HLO artifacts and executes them
//! on the training hot path. This is the only boundary between the Rust
//! coordinator and the JAX/Pallas compute stack — Python is never
//! invoked at run time.
//!
//! - [`pjrt`] — thin wrapper over the `xla` crate: HLO text →
//!   `HloModuleProto` → compile → typed execute.
//! - [`artifact`] — `artifacts/manifest.json` schema + lazy executable
//!   cache per task.
//! - [`exec`] — typed entry points for each artifact kind
//!   (client_step / client_grad / fedavg / eval).

pub mod artifact;
pub mod exec;
pub mod pjrt;

pub use artifact::{Manifest, TaskArtifacts};
pub use pjrt::{Executable, Runtime, Tensor};
