//! Thin typed wrapper over the `xla` crate's PJRT CPU client.
//!
//! Interchange format is HLO *text* (see `python/compile/aot.py` and
//! /opt/xla-example/README.md): jax >= 0.5 serialized protos use 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids and round-trips cleanly.
//!
//! Concurrency note: the `xla` crate's handles wrap raw PJRT pointers
//! and are not `Send`. The coordinator therefore executes artifacts from
//! a single thread; XLA:CPU parallelizes *inside* each execution via its
//! own intra-op thread pool, which is where the FLOPs are. Rust-side
//! parallelism (sketch merges, data generation) uses plain `std::thread`
//! over pure-Rust data.

use anyhow::{bail, Context, Result};
use std::path::Path;

/// A typed host tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "f32 tensor shape/product mismatch");
        Tensor::F32 { data, shape: shape.iter().map(|&s| s as i64).collect() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "i32 tensor shape/product mismatch");
        Tensor::I32 { data, shape: shape.iter().map(|&s| s as i64).collect() }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { data: vec![v], shape: vec![] }
    }

    /// Extract f32 payload (errors on dtype mismatch).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("expected f32 output, got i32"),
        }
    }

    pub fn as_scalar_f32(&self) -> Result<f32> {
        match self {
            Tensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => bail!("expected scalar f32, got {:?}", shape_of(other)),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::F32 { data, shape } => {
                let l = xla::Literal::vec1(data.as_slice());
                if shape.is_empty() {
                    // rank-0: reshape to scalar
                    l.reshape(&[])?
                } else {
                    l.reshape(shape)?
                }
            }
            Tensor::I32 { data, shape } => {
                let l = xla::Literal::vec1(data.as_slice());
                if shape.is_empty() {
                    l.reshape(&[])?
                } else {
                    l.reshape(shape)?
                }
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().context("output literal shape")?;
        let dims: Vec<i64> = shape.dims().to_vec();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(Tensor::F32 { data: lit.to_vec::<f32>()?, shape: dims })
            }
            xla::ElementType::S32 => {
                Ok(Tensor::I32 { data: lit.to_vec::<i32>()?, shape: dims })
            }
            other => bail!("unsupported output element type {other:?}"),
        }
    }
}

fn shape_of(t: &Tensor) -> &Vec<i64> {
    match t {
        Tensor::F32 { shape, .. } => shape,
        Tensor::I32 { shape, .. } => shape,
    }
}

/// Owns the PJRT client. One per process.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host tensors; returns the flattened output tuple.
    /// All our artifacts are lowered with `return_tuple=True`, so the
    /// single device output is a tuple literal we decompose.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let buffer = &result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow::anyhow!("no output buffer from {}", self.name))?;
        let tuple_lit = buffer.to_literal_sync()?;
        let parts = tuple_lit.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}
