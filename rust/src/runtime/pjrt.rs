//! Thin typed wrapper over the PJRT CPU client, with two backends:
//!
//! - **`xla-backend` feature** — the real path: HLO *text* artifacts
//!   (see `python/compile/aot.py` and /opt/xla-example/README.md) are
//!   parsed, compiled and executed through the external `xla` crate.
//!   jax >= 0.5 serialized protos use 64-bit instruction ids that
//!   xla_extension 0.5.1 rejects; the text parser reassigns ids and
//!   round-trips cleanly. The `xla` crate is not available in the
//!   offline build image, so the dependency must be added manually
//!   before enabling the feature (see Cargo.toml).
//! - **default (offline stub)** — everything that does not execute an
//!   HLO artifact works normally (sketching, aggregation, the parallel
//!   round engine over simulated clients, accounting, experiments
//!   plumbing); [`Executable::run`] returns a clear error.
//!
//! Concurrency: the parallel round engine executes client steps from a
//! worker pool, so [`Runtime`] and [`Executable`] must be `Send + Sync`.
//! The stub types trivially are. The `xla` crate's handles are `!Send`
//! because they clone a non-atomic refcount on the shared client handle
//! internally, so the feature-gated real backend serializes **every**
//! xla call behind one process-wide mutex (`XLA_CALL_LOCK`) and only
//! then asserts `Send`/`Sync`; XLA:CPU's intra-op thread pool still
//! parallelizes the FLOPs inside each execution.

use anyhow::{bail, Result};

/// A typed host tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<i64> },
    I32 { data: Vec<i32>, shape: Vec<i64> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "f32 tensor shape/product mismatch");
        Tensor::F32 { data, shape: shape.iter().map(|&s| s as i64).collect() }
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(data.len(), n, "i32 tensor shape/product mismatch");
        Tensor::I32 { data, shape: shape.iter().map(|&s| s as i64).collect() }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::F32 { data: vec![v], shape: vec![] }
    }

    /// Extract f32 payload (errors on dtype mismatch).
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => bail!("expected f32 output, got i32"),
        }
    }

    pub fn as_scalar_f32(&self) -> Result<f32> {
        match self {
            Tensor::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => bail!("expected scalar f32, got {:?}", shape_of(other)),
        }
    }
}

fn shape_of(t: &Tensor) -> &Vec<i64> {
    match t {
        Tensor::F32 { shape, .. } => shape,
        Tensor::I32 { shape, .. } => shape,
    }
}

#[cfg(feature = "xla-backend")]
mod backend {
    use super::Tensor;
    use anyhow::{bail, Context, Result};
    use std::path::Path;

    impl Tensor {
        pub(super) fn to_literal(&self) -> Result<xla::Literal> {
            let lit = match self {
                Tensor::F32 { data, shape } => {
                    let l = xla::Literal::vec1(data.as_slice());
                    if shape.is_empty() {
                        // rank-0: reshape to scalar
                        l.reshape(&[])?
                    } else {
                        l.reshape(shape)?
                    }
                }
                Tensor::I32 { data, shape } => {
                    let l = xla::Literal::vec1(data.as_slice());
                    if shape.is_empty() {
                        l.reshape(&[])?
                    } else {
                        l.reshape(shape)?
                    }
                }
            };
            Ok(lit)
        }

        pub(super) fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
            let shape = lit.array_shape().context("output literal shape")?;
            let dims: Vec<i64> = shape.dims().to_vec();
            match shape.ty() {
                xla::ElementType::F32 => {
                    Ok(Tensor::F32 { data: lit.to_vec::<f32>()?, shape: dims })
                }
                xla::ElementType::S32 => {
                    Ok(Tensor::I32 { data: lit.to_vec::<i32>()?, shape: dims })
                }
                other => bail!("unsupported output element type {other:?}"),
            }
        }
    }

    /// Serializes every call into the `xla` crate. Its handle types are
    /// `!Send` for a reason: they clone a **non-atomic** refcount on the
    /// shared client handle internally, so two concurrent xla calls —
    /// even on different executables of the same client — race the
    /// refcount (UB). The engine's worker pool therefore funnels all
    /// xla-crate work through this one process-wide lock; XLA:CPU still
    /// parallelizes *inside* each execution via its intra-op thread
    /// pool, which is where the FLOPs are, so coordinator-side
    /// parallelism still pays for data generation and sketch merging.
    static XLA_CALL_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    /// Wraps an xla-crate handle so that its destructor also runs under
    /// [`XLA_CALL_LOCK`]: dropping a handle decrements the same
    /// non-atomic refcount the calls touch, so an unlocked drop racing a
    /// locked call would be the exact UB the lock exists to prevent
    /// (e.g. the documented double-compile race in `TaskArtifacts`
    /// drops the losing `Arc<Executable>` on a worker thread).
    struct Locked<T>(Option<T>);

    impl<T> Locked<T> {
        fn new(value: T) -> Self {
            Locked(Some(value))
        }

        /// Borrow the handle. Callers must already hold XLA_CALL_LOCK.
        fn get(&self) -> &T {
            self.0.as_ref().expect("xla handle already dropped")
        }
    }

    impl<T> Drop for Locked<T> {
        fn drop(&mut self) {
            // Never double-panic out of Drop on a poisoned lock.
            let _xla = XLA_CALL_LOCK.lock().unwrap_or_else(|p| p.into_inner());
            self.0.take();
        }
    }

    /// Owns the PJRT client. One per process.
    pub struct Runtime {
        client: Locked<xla::PjRtClient>,
    }

    // SAFETY: all access to the wrapped handles (and the non-atomic
    // refcounts they clone internally) goes through XLA_CALL_LOCK —
    // including destruction, via `Locked` — so no two threads ever
    // touch xla-crate state concurrently.
    unsafe impl Send for Runtime {}
    unsafe impl Sync for Runtime {}

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            let _xla = XLA_CALL_LOCK.lock().expect("xla lock poisoned");
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client: Locked::new(client) })
        }

        pub fn platform(&self) -> String {
            let _xla = XLA_CALL_LOCK.lock().expect("xla lock poisoned");
            self.client.get().platform_name()
        }

        /// Load + compile an HLO-text artifact.
        pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
            let _xla = XLA_CALL_LOCK.lock().expect("xla lock poisoned");
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .get()
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable { exe: Locked::new(exe), name: path.display().to_string() })
        }
    }

    /// A compiled artifact ready to execute.
    pub struct Executable {
        exe: Locked<xla::PjRtLoadedExecutable>,
        name: String,
    }

    // SAFETY: see `Runtime` above — every use and the destructor run
    // under XLA_CALL_LOCK.
    unsafe impl Send for Executable {}
    unsafe impl Sync for Executable {}

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with host tensors; returns the flattened output tuple.
        /// All our artifacts are lowered with `return_tuple=True`, so the
        /// single device output is a tuple literal we decompose.
        /// The guard spans the whole body, so the intermediate literals
        /// and buffers also drop under the lock.
        pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            let _xla = XLA_CALL_LOCK.lock().expect("xla lock poisoned");
            let literals: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
            let result = self
                .exe
                .get()
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let buffer = &result
                .first()
                .and_then(|r| r.first())
                .ok_or_else(|| anyhow::anyhow!("no output buffer from {}", self.name))?;
            let tuple_lit = buffer.to_literal_sync()?;
            let parts = tuple_lit.to_tuple()?;
            parts.iter().map(Tensor::from_literal).collect()
        }
    }
}

#[cfg(not(feature = "xla-backend"))]
mod backend {
    use super::Tensor;
    use anyhow::{bail, Result};
    use std::path::{Path, PathBuf};

    const STUB_MSG: &str = "PJRT backend unavailable: this build uses the offline stub \
         (add the `xla` crate and build with `--features xla-backend` to execute HLO artifacts)";

    /// Offline stand-in for the PJRT client: construction succeeds (so
    /// simulation paths, benches and artifact-free tests run), but any
    /// attempt to execute an HLO artifact reports the missing backend.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Self> {
            Ok(Runtime { _private: () })
        }

        pub fn platform(&self) -> String {
            "stub (no XLA backend in this build)".to_string()
        }

        /// Loading defers the failure to execution so that artifact
        /// enumeration and cache bookkeeping still work in stub builds.
        pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
            if !path.exists() {
                bail!("HLO artifact {} not found", path.display());
            }
            Ok(Executable { name: path.display().to_string(), _path: path.to_path_buf() })
        }
    }

    /// Stub executable: remembers its identity, refuses to run.
    pub struct Executable {
        name: String,
        _path: PathBuf,
    }

    impl Executable {
        pub fn name(&self) -> &str {
            &self.name
        }

        pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            bail!("{STUB_MSG} (artifact: {})", self.name)
        }
    }
}

pub use backend::{Executable, Runtime};

// The parallel round engine shares Runtime/Executable across worker
// threads; both backends must uphold this.
#[allow(dead_code)]
fn assert_backend_is_threadsafe() {
    fn check<T: Send + Sync>() {}
    check::<Runtime>();
    check::<Executable>();
}
