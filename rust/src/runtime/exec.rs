//! Typed entry points for each artifact kind.
//!
//! These are the calls the coordinator makes on the hot path; each
//! packs host buffers into [`Tensor`]s in the argument order fixed by
//! `python/compile/model.py` and unpacks the output tuple.

use anyhow::{bail, Result};

use crate::runtime::pjrt::{Executable, Tensor};
use crate::sketch::CountSketch;

/// A client minibatch in host memory. `x` is f32 for image tasks and i32
/// token ids for text tasks; `y` is labels/targets; `mask` weights valid
/// examples (tasks pad tiny local datasets up to the artifact's batch).
#[derive(Clone, Debug)]
pub struct Batch {
    pub x: Tensor,
    pub y: Tensor,
    pub mask: Tensor,
}

/// FetchSGD client step: returns (loss, sketch-of-gradient).
pub fn run_client_step(
    exe: &Executable,
    w: &[f32],
    batch: &Batch,
    rows: usize,
    cols: usize,
    seed: u64,
) -> Result<(f32, CountSketch)> {
    let out = exe.run(&[
        Tensor::f32(w.to_vec(), &[w.len()]),
        batch.x.clone(),
        batch.y.clone(),
        batch.mask.clone(),
    ])?;
    if out.len() != 2 {
        bail!("client_step returned {} outputs, expected 2", out.len());
    }
    let loss = out[0].as_scalar_f32()?;
    let table = out[1].clone().into_f32()?;
    Ok((loss, CountSketch::from_table(rows, cols, w.len(), seed, table)?))
}

/// Baseline client step: returns (loss, dense gradient).
pub fn run_client_grad(exe: &Executable, w: &[f32], batch: &Batch) -> Result<(f32, Vec<f32>)> {
    let out = exe.run(&[
        Tensor::f32(w.to_vec(), &[w.len()]),
        batch.x.clone(),
        batch.y.clone(),
        batch.mask.clone(),
    ])?;
    if out.len() != 2 {
        bail!("client_grad returned {} outputs, expected 2", out.len());
    }
    let loss = out[0].as_scalar_f32()?;
    let grad = out[1].clone().into_f32()?;
    if grad.len() != w.len() {
        bail!("gradient dim {} != weight dim {}", grad.len(), w.len());
    }
    Ok((loss, grad))
}

/// FedAvg client: `batches` stacked along a leading local-steps axis
/// (done by the caller); returns (mean local loss, delta = w_in - w_out).
pub fn run_fedavg(
    exe: &Executable,
    w: &[f32],
    xs: Tensor,
    ys: Tensor,
    masks: Tensor,
    lr: f32,
) -> Result<(f32, Vec<f32>)> {
    let out = exe.run(&[
        Tensor::f32(w.to_vec(), &[w.len()]),
        xs,
        ys,
        masks,
        Tensor::scalar_f32(lr),
    ])?;
    if out.len() != 2 {
        bail!("fedavg returned {} outputs, expected 2", out.len());
    }
    Ok((out[0].as_scalar_f32()?, out[1].clone().into_f32()?))
}

/// Evaluation: returns (sum_loss, units, correct) over the batch.
pub fn run_eval(exe: &Executable, w: &[f32], batch: &Batch) -> Result<(f64, f64, f64)> {
    let out = exe.run(&[
        Tensor::f32(w.to_vec(), &[w.len()]),
        batch.x.clone(),
        batch.y.clone(),
        batch.mask.clone(),
    ])?;
    if out.len() != 3 {
        bail!("eval returned {} outputs, expected 3", out.len());
    }
    Ok((
        out[0].as_scalar_f32()? as f64,
        out[1].as_scalar_f32()? as f64,
        out[2].as_scalar_f32()? as f64,
    ))
}
