//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the coordinator.
//!
//! `artifacts/manifest.json` describes every lowered HLO module, the
//! model's flat dimension, batch shapes, the sketch parameterization
//! (rows/cols/seed — Rust re-derives the identical hash constants), the
//! synthetic-data configuration, and the initial-weights file.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::hashing::SPEC_VERSION;
use crate::runtime::pjrt::{Executable, Runtime};
use crate::serialize::json::{parse, Value};

/// Input tensor description (shape + dtype).
#[derive(Clone, Debug)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// Synthetic dataset configuration mirrored from the manifest.
#[derive(Clone, Debug)]
pub enum DataSpec {
    Images { image: [usize; 3], classes: usize },
    Text { vocab: usize, seq: usize },
}

/// Sketch parameterization available for a task.
#[derive(Clone, Debug)]
pub struct SketchSpec {
    pub rows: usize,
    pub seed: u64,
    pub cols_options: Vec<usize>,
}

/// One task entry from the manifest.
#[derive(Clone, Debug)]
pub struct TaskManifest {
    pub name: String,
    pub model: String,
    pub dim: usize,
    pub batch: usize,
    pub inputs: HashMap<String, InputSpec>,
    pub data: DataSpec,
    pub init_weights: String,
    pub artifacts: HashMap<String, String>,
    pub sketch: SketchSpec,
    pub fedavg_steps: Vec<usize>,
}

/// The whole manifest.
pub struct Manifest {
    pub dir: PathBuf,
    pub tasks: Vec<TaskManifest>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = parse(&text).context("parsing manifest.json")?;
        let spec_version = v.req_u64("spec_version")? as u32;
        if spec_version != SPEC_VERSION {
            bail!(
                "manifest spec_version {spec_version} != binary spec {SPEC_VERSION}; \
                 re-run `make artifacts`"
            );
        }
        let mut tasks = Vec::new();
        for t in v.req_array("tasks")? {
            tasks.push(Self::parse_task(t)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), tasks })
    }

    fn parse_task(t: &Value) -> Result<TaskManifest> {
        let name = t.req_str("name")?.to_string();
        let mut inputs = HashMap::new();
        if let Some(Value::Object(spec)) = t.get("input_spec") {
            for (k, v) in spec {
                let shape = v
                    .req_array("shape")?
                    .iter()
                    .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad shape")))
                    .collect::<Result<Vec<_>>>()?;
                let dtype = v.req_str("dtype")?.to_string();
                inputs.insert(k.clone(), InputSpec { shape, dtype });
            }
        }
        let data_v = t.req("data")?;
        let data = match data_v.req_str("kind")? {
            "images" => {
                let img = data_v.req_array("image")?;
                if img.len() != 3 {
                    bail!("image must be [H,W,C]");
                }
                DataSpec::Images {
                    image: [
                        img[0].as_usize().unwrap(),
                        img[1].as_usize().unwrap(),
                        img[2].as_usize().unwrap(),
                    ],
                    classes: data_v.req_usize("classes")?,
                }
            }
            "text" => DataSpec::Text {
                vocab: data_v.req_usize("vocab")?,
                seq: data_v.req_usize("seq")?,
            },
            other => bail!("unknown data kind '{other}'"),
        };
        let mut artifacts = HashMap::new();
        if let Some(Value::Object(a)) = t.get("artifacts") {
            for (k, v) in a {
                artifacts.insert(
                    k.clone(),
                    v.as_str().ok_or_else(|| anyhow!("artifact path"))?.to_string(),
                );
            }
        }
        let sk = t.req("sketch")?;
        let sketch_spec_version = sk.req_u64("spec_version")? as u32;
        if sketch_spec_version != SPEC_VERSION {
            bail!("sketch spec_version mismatch");
        }
        let sketch = SketchSpec {
            rows: sk.req_usize("rows")?,
            seed: sk.req_u64("seed")?,
            cols_options: sk
                .req_array("cols")?
                .iter()
                .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad cols")))
                .collect::<Result<Vec<_>>>()?,
        };
        let fedavg_steps = t
            .req_array("fedavg_steps")?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad fedavg step")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TaskManifest {
            name,
            model: t.req_str("model")?.to_string(),
            dim: t.req_usize("dim")?,
            batch: t.req_usize("batch")?,
            inputs,
            data,
            init_weights: t.req_str("init_weights")?.to_string(),
            artifacts,
            sketch,
            fedavg_steps,
        })
    }

    pub fn task(&self, name: &str) -> Result<&TaskManifest> {
        self.tasks
            .iter()
            .find(|t| t.name == name)
            .ok_or_else(|| anyhow!("task '{name}' not in manifest (have: {:?})",
                self.tasks.iter().map(|t| &t.name).collect::<Vec<_>>()))
    }
}

/// Loaded executables for one task, compiled lazily and cached.
///
/// Shared across the round engine's worker threads: the runtime handle
/// is an `Arc` and the lazy compile cache sits behind an `RwLock`, so
/// any worker can look up (or compile) an executable concurrently. Two
/// workers racing on an uncached kind may both compile it; the second
/// insert wins and the duplicate is dropped — wasteful but correct, and
/// only possible on each kind's first round.
pub struct TaskArtifacts {
    runtime: std::sync::Arc<Runtime>,
    dir: PathBuf,
    pub manifest: TaskManifest,
    cache: std::sync::RwLock<HashMap<String, std::sync::Arc<Executable>>>,
}

impl TaskArtifacts {
    pub fn new(
        runtime: std::sync::Arc<Runtime>,
        manifest: &Manifest,
        task: &str,
    ) -> Result<Self> {
        let tm = manifest.task(task)?.clone();
        Ok(TaskArtifacts {
            runtime,
            dir: manifest.dir.clone(),
            manifest: tm,
            cache: Default::default(),
        })
    }

    /// Artifacts bound to a hand-built task manifest, with no artifact
    /// directory behind them. Used by simulation benches and tests that
    /// drive the round engine with [`crate::compression::sim`] clients
    /// (which never execute HLO); any executable lookup will fail.
    pub fn detached(manifest: TaskManifest) -> Result<Self> {
        Ok(TaskArtifacts {
            runtime: std::sync::Arc::new(Runtime::cpu()?),
            dir: PathBuf::from("."),
            manifest,
            cache: Default::default(),
        })
    }

    /// Get (compiling on first use) the executable for an artifact kind,
    /// e.g. "client_grad", "eval", "client_step_c4096", "fedavg_k2".
    pub fn executable(&self, kind: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.read().expect("artifact cache poisoned").get(kind) {
            return Ok(e.clone());
        }
        let file = self
            .manifest
            .artifacts
            .get(kind)
            .ok_or_else(|| anyhow!(
                "task '{}' has no artifact '{kind}' (have: {:?})",
                self.manifest.name,
                self.manifest.artifacts.keys().collect::<Vec<_>>()
            ))?;
        let exe = std::sync::Arc::new(self.runtime.load_hlo(&self.dir.join(file))?);
        self.cache
            .write()
            .expect("artifact cache poisoned")
            .insert(kind.to_string(), exe.clone());
        Ok(exe)
    }

    /// Load the initial weights vector.
    pub fn init_weights(&self) -> Result<Vec<f32>> {
        let w = crate::serialize::bin::read_f32(&self.dir.join(&self.manifest.init_weights))?;
        if w.len() != self.manifest.dim {
            bail!(
                "init weights len {} != manifest dim {}",
                w.len(),
                self.manifest.dim
            );
        }
        Ok(w)
    }

    /// The client_step artifact kind name for a sketch width.
    pub fn client_step_kind(cols: usize) -> String {
        format!("client_step_c{cols}")
    }

    /// The fedavg artifact kind name for a local-step count.
    pub fn fedavg_kind(local_steps: usize) -> String {
        format!("fedavg_k{local_steps}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_minimal_manifest() {
        let json = r#"{
          "spec_version": 1, "sketch_rows": 5,
          "tasks": [{
            "name": "t", "model": "m", "dim": 10, "batch": 2,
            "input_spec": {"x": {"shape": [2, 4], "dtype": "f32"}},
            "data": {"kind": "images", "image": [2, 2, 1], "classes": 3},
            "weight_seed": 1, "init_weights": "t_init.bin",
            "artifacts": {"eval": "t_eval.hlo.txt"},
            "sketch": {"rows": 5, "seed": 7, "cols": [64], "spec_version": 1},
            "fedavg_steps": [2]
          }]
        }"#;
        let v = parse(json).unwrap();
        let tm = Manifest::parse_task(&v.req_array("tasks").unwrap()[0]).unwrap();
        assert_eq!(tm.name, "t");
        assert_eq!(tm.dim, 10);
        assert_eq!(tm.inputs["x"].shape, vec![2, 4]);
        assert!(matches!(tm.data, DataSpec::Images { classes: 3, .. }));
        assert_eq!(tm.sketch.cols_options, vec![64]);
    }

    #[test]
    fn rejects_wrong_spec_version() {
        let dir = std::env::temp_dir().join(format!("fsgd_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"spec_version": 99, "tasks": []}"#)
            .unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
