//! The relay tier: an aggregator that turns round servers into a tree.
//!
//! A relay sits between a [`RoundServer`](crate::transport::server::RoundServer)
//! in relay mode (`relay_children > 0`) and a downstream pool that is
//! either ordinary workers (*leaf* mode, `relay_children == 0` here)
//! or — since protocol v4 — its own relay peers (*interior* mode,
//! `relay_children > 0` here), so depth-N trees compose from the same
//! two shapes at every level. Upstream a relay always looks like a
//! single client speaking the relay handshake (`relay-hello`);
//! downstream a leaf relay speaks the ordinary worker grammar —
//! workers `join` a relay with the same binary and the same `fetchsgd
//! join` command they would use against a flat server, and cannot tell
//! the difference — while an interior relay speaks the same
//! `subtree-assign`/`subtree-upload` grammar its own upstream speaks
//! to it.
//!
//! Per round, the flow is:
//!
//! 1. Upstream sends `SubtreeAssign`: the relay's slot *chain* — this
//!    relay's share of the round's global slots, each entry carrying
//!    the global slot id, the sampled client id, and the slot's
//!    *global* aggregation weight λ — plus the upload spec and the
//!    dense weights frame.
//! 2. The relay fans the chain over its downstream workers with a
//!    normal `RoundStart` (weights forwarded verbatim, global slot
//!    ids), and streams their upload frames into its own
//!    [`RoundPipeline`] via the zero-copy `offer_frame_bytes` path —
//!    the same absorb machinery the server and the in-process engine
//!    drive, configured as a single shard chain.
//! 3. It folds whatever arrived into **one** merged lossless `f32le`
//!    frame (`RoundPipeline::finalize_subtree`) and answers upstream
//!    with one `SubtreeUpload`: the merged frame plus a rolled-up
//!    [`SlotReport`] per assigned slot, in ascending slot order.
//! 4. Upstream closes the round and broadcasts `RoundEnd`; the relay
//!    forwards the broadcast verbatim to every downstream worker.
//!
//! # Determinism
//!
//! The tree reproduces the flat server bit for bit because weighted
//! subtree sums reassociate exactly (the sketch and the dense
//! accumulator are linear, and each tier folds in ascending slot
//! order): the root pins one shard chain per relay, relay `r` owns the
//! global slots `{s : s mod R == r}` — the same slots shard `r` of a
//! flat server with `shards = R` would own — folds them in ascending
//! order with the *global* λ shipped in the assignment, and the root
//! absorbs each merged frame into its shard with weight 1 before the
//! ordinary ordered shard reduce. An interior relay applies the same
//! rule one level down: its chain's local positions `{i : i mod K ==
//! k}` go to child `k`, which works out to global slots `{s : s mod
//! R·K == r + k·R}` — exactly the shards of a flat server whose
//! reduce is reassociated with `shard_tiers = RxK` (see
//! [`crate::compression::aggregate::reduce_shards_tree`]), so a
//! depth-N tree is bitwise identical to a flat server (and the
//! in-process engine) with the matching tier layout. Renormalization
//! over the arrived subset happens once, at the root, so a partial
//! round closed at quorum is also bitwise identical to the flat
//! server ending with the same surviving membership set.
//!
//! # Fault containment
//!
//! A downstream fault is *contained to its subtree*: a peer that
//! sends garbage or disconnects mid-round costs only its own unserved
//! slots (reported upstream as dropped, with the fault/disconnect/
//! deadline distinction preserved), never the relay's other slots and
//! never the sibling relays'. A relay composes quorum policy rather
//! than deciding it: under [`RelayOptions::quorum`] it closes its
//! chain at its own round deadline (stragglers report as
//! deadline-dropped) and reports a *partial* chain upstream, and an
//! interior relay with a retry budget re-offers a dead child's whole
//! sub-chain to a surviving child mid-round (the same re-assignment
//! the root performs), accumulating the retry counts in the roll-up.
//! Whether the round closes is still decided once, at the root, which
//! sees every slot's outcome. Upstream loss is survivable the same
//! way a worker survives it: with a `reconnect_attempts` budget the
//! relay re-dials under bounded exponential backoff, keeping its
//! downstream pool connected across the blip.

use anyhow::{bail, Context, Result};
use std::net::TcpListener;
#[cfg(unix)]
use std::os::unix::net::UnixListener;
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cohort::QuorumPolicy;
use crate::compression::aggregate::{PipelineOptions, RoundInFlight, RoundPipeline};
use crate::compression::UploadSpec;
use crate::metrics::{MetricsLogger, RoundRecord};
use crate::trace::{ms_since, ConnIo, Histogram, Phase, SlotEvent, TraceSink};
use crate::transport::client::ReconnectSchedule;
use crate::transport::framing::{
    read_msg, read_msg_timed, write_msg, write_msg_parts, DEFAULT_MAX_MSG_BYTES,
};
use crate::transport::proto::{
    Msg, SlotReport, OUTCOME_ARRIVED, OUTCOME_DROPPED_DEADLINE, OUTCOME_DROPPED_DISCONNECTED,
    OUTCOME_DROPPED_FAULTED, PROTO_VERSION,
};
use crate::transport::server::handshake;
use crate::transport::{Conn, Endpoint};
use crate::wire::{encode_dense_frame, encode_sketch_frame, F32LE};

/// Relay knobs. Defaults suit a loopback deployment.
pub struct RelayOptions {
    /// Downstream worker connections the relay waits for before
    /// serving a non-empty chain. Ignored in interior mode
    /// (`relay_children > 0`).
    pub workers: usize,
    /// Number of downstream *relay* peers this node aggregates over
    /// instead of direct workers. 0 (the default) = leaf relay serving
    /// workers. When set, the relay accepts `relay-hello` peers, hands
    /// each one a sub-chain of its own chain (nested `subtree-assign`,
    /// protocol v4), and its shard layout is pinned to the child count
    /// so the nested fold reassociates to the flat fold (see module
    /// docs).
    pub relay_children: usize,
    /// Relay-side round policy: `round_deadline` bounds the whole
    /// subtree round (stragglers past it report upstream as
    /// deadline-dropped — the partial-chain report), and
    /// `max_slot_retries >= 1` lets an interior relay re-offer a dead
    /// child's sub-chain to a surviving child mid-round. The quorum
    /// fraction itself is *not* enforced here — a relay always reports
    /// what it has; only the root decides whether the round closes.
    pub quorum: QuorumPolicy,
    /// Read deadline while waiting for the upstream server (None =
    /// block; the root controls round pacing, so the default is
    /// patient — mirroring a joined worker).
    pub upstream_timeout: Option<Duration>,
    /// Per-connection downstream read/write deadline. A worker that
    /// stalls longer than this mid-round drops its unserved slots
    /// instead of wedging the subtree.
    pub read_timeout: Duration,
    /// How long to wait for the downstream pool to fill.
    pub accept_timeout: Duration,
    /// Per-message size cap, both directions (mirrors the root's).
    pub max_msg: usize,
    /// How many times a lost *upstream* connection is re-dialed before
    /// the relay gives up; a connection that sees a round through to
    /// its broadcast resets the counter. 0 = fail on first loss.
    pub reconnect_attempts: usize,
    /// Backoff before the first upstream re-dial, in milliseconds;
    /// doubles per consecutive failure, capped at 10 s.
    pub reconnect_backoff_ms: u64,
    /// JSONL metrics log (`tier: "relay"` rows); None = no log.
    pub log_path: Option<std::path::PathBuf>,
    /// Structured trace output (`tier: "relay"` events, see
    /// [`crate::trace`]); None (the default) = tracing off, and the
    /// round hot path takes no extra clock reads or allocations.
    pub trace_path: Option<std::path::PathBuf>,
}

impl Default for RelayOptions {
    fn default() -> Self {
        RelayOptions {
            workers: 1,
            relay_children: 0,
            quorum: QuorumPolicy::strict(),
            upstream_timeout: None,
            read_timeout: Duration::from_secs(30),
            accept_timeout: Duration::from_secs(30),
            max_msg: DEFAULT_MAX_MSG_BYTES,
            reconnect_attempts: 0,
            reconnect_backoff_ms: 200,
            log_path: None,
            trace_path: None,
        }
    }
}

/// What a relay did over its lifetime.
#[derive(Clone, Debug, Default)]
pub struct RelaySummary {
    /// Rounds seen through to the upstream broadcast.
    pub rounds: usize,
    /// Merged subtree frames sent upstream (rounds with at least one
    /// arrived downstream slot).
    pub merged_uploads: usize,
    /// Upstream connections re-dialed after a loss.
    pub reconnects: usize,
    /// Total on-the-wire bytes on the upstream link, both directions.
    pub upstream_bytes: u64,
    /// Total on-the-wire bytes across all downstream links, both
    /// directions.
    pub downstream_bytes: u64,
}

enum ListenerKind {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

/// A round record staged in `run_subtree` and emitted when the
/// matching `RoundEnd` arrives (so the row can include the broadcast
/// bytes and the full per-round transport delta).
struct PendingRecord {
    round: u64,
    mean_loss: f64,
    lr: f32,
    wire_upload: u64,
    participants: usize,
    dropped_slots: usize,
    absorb_stalls: u64,
    parked_bytes: u64,
    chosen_shards: usize,
    /// `upstream_bytes + downstream_bytes` when the subtree round
    /// began; the delta at `RoundEnd` is this tier's transport bytes
    /// for the round.
    bytes_marker: u64,
    /// Wall-clock of the subtree round (assign received → upload
    /// staged; the upstream reply and `RoundEnd` forward land after
    /// staging, so they are not included).
    round_ms: f64,
    /// Time blocked waiting on downstream uploads.
    absorb_ms: f64,
    /// `finalize_subtree` + merged-frame encode time.
    reduce_ms: f64,
}

/// One relay node: upstream `Conn` per `serve_upstream` call,
/// persistent downstream pool, own round pipeline. See module docs.
pub struct Relay {
    listener: ListenerKind,
    opts: RelayOptions,
    conns: Vec<Conn>,
    /// The shared round-aggregation pipeline. Leaf mode: a single
    /// chain — every local slot folds into one accumulator in
    /// ascending global slot order, which is exactly this relay's
    /// shard chain of the root's fold. Interior mode: one shard per
    /// relay child, each absorbing that child's merged frame, reduced
    /// left-associated in child order.
    pipeline: RoundPipeline,
    logger: MetricsLogger,
    /// Trace sink (tier `"relay"`), shared with nothing — a relay's
    /// events all carry *global* slot ids so traces from every tier of
    /// a tree merge into one timeline (see `fetchsgd trace-summary`).
    trace: Option<Arc<TraceSink>>,
    pending: Option<PendingRecord>,
    sum: RelaySummary,
    #[cfg(unix)]
    uds_path: Option<PathBuf>,
}

impl Relay {
    /// Bind the downstream listener (TCP port 0 = ephemeral; a stale
    /// UDS socket file is removed first).
    pub fn bind(listen: &Endpoint, opts: RelayOptions) -> Result<Relay> {
        if opts.workers == 0 && opts.relay_children == 0 {
            bail!("RelayOptions.workers must be >= 1");
        }
        let listener = match listen {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr.as_str())
                    .with_context(|| format!("binding tcp:{addr}"))?;
                l.set_nonblocking(true).context("listener nonblocking")?;
                ListenerKind::Tcp(l)
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                if path.exists() {
                    std::fs::remove_file(path)
                        .with_context(|| format!("removing stale socket {}", path.display()))?;
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding uds:{}", path.display()))?;
                l.set_nonblocking(true).context("listener nonblocking")?;
                ListenerKind::Unix(l)
            }
        };
        // Leaf mode: every local slot folds into one chain. Interior
        // mode: one shard chain per relay child, exactly like the
        // relay-mode root — shard k folds child k's merged frame.
        let shard_override = if opts.relay_children > 0 { opts.relay_children } else { 1 };
        // Relays keep the adaptive controller and pinning off: the
        // fixed shard layout *is* the tree contract (shard k == child
        // k), so self-sizing here would change aggregation order.
        let pipeline = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: 1,
            shard_override,
            reduce_tiers: Vec::new(),
            ..Default::default()
        });
        let logger = MetricsLogger::new(opts.log_path.as_deref())?;
        let trace = match opts.trace_path.as_deref() {
            Some(p) => Some(Arc::new(
                TraceSink::create(p, "relay", &format!("{listen}"))
                    .context("RelayOptions.trace_path")?,
            )),
            None => None,
        };
        Ok(Relay {
            listener,
            opts,
            conns: Vec::new(),
            pipeline,
            logger,
            trace,
            pending: None,
            sum: RelaySummary::default(),
            #[cfg(unix)]
            uds_path: match listen {
                Endpoint::Unix(p) => Some(p.clone()),
                _ => None,
            },
        })
    }

    /// The downstream endpoint actually bound (resolves TCP port 0).
    pub fn local_endpoint(&self) -> Result<Endpoint> {
        match &self.listener {
            ListenerKind::Tcp(l) => {
                Ok(Endpoint::Tcp(l.local_addr().context("local_addr")?.to_string()))
            }
            #[cfg(unix)]
            ListenerKind::Unix(_) => {
                let path = self.uds_path.clone().context("uds path missing")?;
                Ok(Endpoint::Unix(path))
            }
        }
    }

    /// Currently connected downstream workers.
    pub fn connected(&self) -> usize {
        self.conns.len()
    }

    /// Dial upstream and serve subtree rounds until `Shutdown`, under
    /// the reconnect budget (see [`RelayOptions::reconnect_attempts`]).
    /// The downstream pool persists across upstream re-dials — workers
    /// never notice an upstream blip between rounds.
    pub fn run(&mut self, upstream: &Endpoint) -> Result<RelaySummary> {
        let mut sched =
            ReconnectSchedule::new(self.opts.reconnect_backoff_ms, self.opts.reconnect_attempts);
        loop {
            let rounds_before = self.sum.rounds;
            match self.serve_upstream(upstream) {
                Ok(()) => return Ok(self.sum.clone()),
                Err(e) => {
                    if self.sum.rounds > rounds_before {
                        sched.progress();
                    }
                    let Some(wait) = sched.next_delay() else {
                        return Err(e);
                    };
                    self.sum.reconnects += 1;
                    eprintln!(
                        "[relay] upstream lost ({e:#}); reconnecting in {} ms (attempt {}/{})",
                        wait.as_millis(),
                        sched.attempt(),
                        sched.budget()
                    );
                    std::thread::sleep(wait);
                }
            }
        }
    }

    /// One upstream connection lifetime: dial, `relay-hello`, serve
    /// subtree rounds until `Shutdown` (clean exit) or any error.
    fn serve_upstream(&mut self, upstream: &Endpoint) -> Result<()> {
        let mut up = Conn::connect(upstream)?;
        up.set_timeouts(self.opts.upstream_timeout, self.opts.upstream_timeout)?;
        self.sum.upstream_bytes +=
            write_msg(&mut up, &Msg::RelayHello { version: PROTO_VERSION }.encode())?;
        loop {
            let (bytes, n) = read_msg(&mut up, self.opts.max_msg).context("waiting for upstream")?;
            self.sum.upstream_bytes += n;
            match Msg::decode(bytes)? {
                Msg::SubtreeAssign {
                    round,
                    round_seed,
                    lr,
                    codec_id,
                    spec,
                    entries,
                    weights_frame,
                } => {
                    let reply = self
                        .run_subtree(round, round_seed, lr, codec_id, &spec, &entries, &weights_frame)
                        .with_context(|| format!("subtree round {round}"))?;
                    self.sum.upstream_bytes += write_msg(&mut up, &reply)
                        .with_context(|| format!("sending subtree upload, round {round}"))?;
                }
                Msg::RoundEnd { round, update_frame } => {
                    // Deterministic encode means the forwarded bytes
                    // are exactly what the root broadcast.
                    let wire_download = update_frame.len() as u64;
                    let fwd = Msg::RoundEnd { round, update_frame }.encode();
                    let bcast_start_us = self.trace.as_ref().map(|t| t.now_us());
                    self.broadcast_down(&fwd);
                    if let (Some(t), Some(b0)) = (&self.trace, bcast_start_us) {
                        t.span(round, Phase::Broadcast, b0, t.now_us());
                    }
                    self.sum.rounds += 1;
                    if let Some(p) = self.pending.take() {
                        if p.round == round {
                            self.log_round(p, wire_download);
                        }
                    }
                }
                Msg::Abort { reason } => {
                    // A round-level abort cascades: downstream workers
                    // are in this round too and must not wedge waiting
                    // for a broadcast that will never come.
                    let fwd =
                        Msg::Abort { reason: format!("upstream aborted: {reason}") }.encode();
                    self.broadcast_down(&fwd);
                    for c in self.conns.drain(..) {
                        c.shutdown();
                    }
                    self.pending = None;
                    bail!("upstream aborted: {reason}");
                }
                Msg::Shutdown => {
                    let fwd = Msg::Shutdown.encode();
                    self.broadcast_down(&fwd);
                    for c in self.conns.drain(..) {
                        c.shutdown();
                    }
                    self.logger.flush()?;
                    if let Some(t) = &self.trace {
                        // Per-round `hist` events already merge exactly
                        // to the run total; no run-level duplicate.
                        t.flush().context("flushing relay trace")?;
                    }
                    return Ok(());
                }
                other => bail!("unexpected {} message from upstream", other.kind_name()),
            }
        }
    }

    /// One subtree round: fan the chain downstream, absorb uploads,
    /// fold to one merged frame, return the encoded `SubtreeUpload`.
    #[allow(clippy::too_many_arguments)]
    fn run_subtree(
        &mut self,
        round: u64,
        round_seed: u64,
        lr: f32,
        codec_id: u8,
        spec: &UploadSpec,
        entries: &[(u32, u32, f32)],
        weights_frame: &[u8],
    ) -> Result<Vec<u8>> {
        let bytes_marker = self.sum.upstream_bytes + self.sum.downstream_bytes;
        let round_t0 = Instant::now();
        if entries.windows(2).any(|w| w[1].0 <= w[0].0) {
            bail!("subtree-assign slots must be strictly ascending");
        }
        let m = entries.len();
        if m == 0 {
            // Zero-participant subtree (fewer global slots than
            // relays this round): answer immediately, don't make the
            // root's round wait on our downstream pool.
            self.pending = Some(PendingRecord {
                round,
                mean_loss: 0.0,
                lr,
                wire_upload: 0,
                participants: 0,
                dropped_slots: 0,
                absorb_stalls: 0,
                parked_bytes: 0,
                chosen_shards: 0,
                bytes_marker,
                round_ms: ms_since(round_t0),
                absorb_ms: 0.0,
                reduce_ms: 0.0,
            });
            return Ok(Msg::SubtreeUpload { round, reports: Vec::new(), frame: Vec::new() }
                .encode());
        }
        self.ensure_workers()?;
        if self.opts.relay_children > 0 {
            return self.run_subtree_relay(
                round,
                round_seed,
                lr,
                codec_id,
                spec,
                entries,
                weights_frame,
                bytes_marker,
                round_t0,
            );
        }
        let trace = self.trace.clone();
        let round_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let nconns = self.conns.len();
        // The relay-side round deadline: the whole subtree round must
        // fit inside it, so each read below is bounded by whichever of
        // the per-read timeout and the remaining deadline is tighter.
        let deadline = self.opts.quorum.round_deadline().map(|d| Instant::now() + d);
        for conn in &self.conns {
            let t = self.opts.read_timeout;
            let _ = conn.set_timeouts(Some(t), Some(t));
        }

        // The chain's λs, in ascending global slot order == local slot
        // order. shard_override = 1 puts every local slot on one chain,
        // so absorbs fold in exactly the order the root's shard `r`
        // would have folded these slots in a flat run.
        let lambdas: Vec<f32> = entries.iter().map(|e| e.2).collect();
        let inflight = self.pipeline.begin(spec, lambdas)?;

        // Local slot → worker layout: round-robin, like the server's.
        // Workers see *global* slot ids (they echo them verbatim); the
        // absorb path uses local indices.
        let mut assignments: Vec<Vec<(u32, usize, u32)>> = vec![Vec::new(); nconns];
        for (local, &(gslot, client, _)) in entries.iter().enumerate() {
            assignments[local % nconns].push((gslot, local, client));
        }

        // RoundStart downstream, splicing the shared weights frame.
        let mut alive = vec![true; nconns];
        for (i, conn) in self.conns.iter_mut().enumerate() {
            let head = Msg::RoundStart {
                round,
                round_seed,
                lr,
                codec_id,
                assignments: assignments[i].iter().map(|&(g, _, c)| (g, c)).collect(),
                weights_frame: Vec::new(),
            }
            .encode();
            match write_msg_parts(conn, &head, weights_frame) {
                Ok(n) => self.sum.downstream_bytes += n,
                Err(_) => {
                    // A dead-at-start worker costs only its own slots;
                    // the rest of the subtree proceeds.
                    alive[i] = false;
                }
            }
        }
        if let Some(t) = &trace {
            t.span(round, Phase::Plan, round_start_us, t.now_us());
        }

        // One reader per downstream connection, offering frames
        // straight from the read buffer. Uploads on one connection
        // arrive in assignment order (the client contract); absorb
        // order across connections is enforced by the in-flight state.
        //
        // Trace events here carry *global* slot ids (workers echo them
        // anyway) so this tier's timeline merges with the root's — the
        // absorber's own per-slot instrumentation is left unattached
        // because it speaks local chain positions.
        struct DownRead {
            /// `(local_slot, loss)` for uploads absorbed, in order.
            done: Vec<(usize, f32)>,
            bytes_in: u64,
            /// Upload-arrival latencies on this connection (µs since
            /// round start; empty when untraced).
            arrivals: Histogram,
            /// Content fault (garbage frame, wrong slot, bad message)
            /// vs. plain disconnect.
            fault: bool,
            /// The failure was a read deadline, not a closed socket.
            timed_out: bool,
        }
        let absorber = &inflight;
        let max_msg = self.opts.max_msg;
        let read_timeout = self.opts.read_timeout;
        let wait_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let wait_t0 = Instant::now();
        let reads: Vec<DownRead> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(nconns);
            for (i, conn) in self.conns.iter_mut().enumerate() {
                let assigned = &assignments[i];
                let live = alive[i];
                let trace = trace.as_deref();
                handles.push(scope.spawn(move || {
                    let mut r = DownRead {
                        done: Vec::new(),
                        bytes_in: 0,
                        arrivals: Histogram::new(),
                        fault: false,
                        timed_out: false,
                    };
                    if !live {
                        return r;
                    }
                    let mut io = ConnIo::default();
                    for &(gslot, local, _client) in assigned {
                        if let Some(dl) = deadline {
                            let rem = dl.saturating_duration_since(Instant::now());
                            if rem.is_zero() {
                                // Straggler past the relay's round
                                // deadline: close the chain partial,
                                // report the tail deadline-dropped.
                                r.timed_out = true;
                                break;
                            }
                            let t = read_timeout.min(rem);
                            let _ = conn.set_timeouts(Some(t), Some(t));
                        }
                        let read = match trace {
                            Some(_) => read_msg_timed(conn, max_msg).map(|(b, n, st, rd)| {
                                io.stall_us += st;
                                io.read_us += rd;
                                (b, n)
                            }),
                            None => read_msg(conn, max_msg),
                        };
                        let bytes = match read {
                            Ok((bytes, n)) => {
                                r.bytes_in += n;
                                bytes
                            }
                            Err(e) => {
                                r.timed_out = e
                                    .downcast_ref::<std::io::Error>()
                                    .map(|io| {
                                        matches!(
                                            io.kind(),
                                            std::io::ErrorKind::WouldBlock
                                                | std::io::ErrorKind::TimedOut
                                        )
                                    })
                                    .unwrap_or(false)
                                    || deadline.is_some_and(|dl| Instant::now() >= dl);
                                break;
                            }
                        };
                        let ok = (|| -> Result<f32> {
                            match Msg::decode(bytes)? {
                                Msg::Upload { slot, loss, frame } => {
                                    if slot != gslot {
                                        bail!("expected upload for slot {gslot}, got {slot}");
                                    }
                                    if let Some(t) = trace {
                                        t.slot_event(
                                            round,
                                            gslot as usize,
                                            SlotEvent::Offered,
                                            Some(i),
                                        );
                                    }
                                    absorber.offer_frame_bytes(local, &frame)?;
                                    Ok(loss)
                                }
                                other => {
                                    bail!("expected upload, got {} message", other.kind_name())
                                }
                            }
                        })();
                        match ok {
                            Ok(loss) => {
                                if let Some(t) = trace {
                                    t.slot_event(
                                        round,
                                        gslot as usize,
                                        SlotEvent::Absorbed,
                                        Some(i),
                                    );
                                    r.arrivals
                                        .record(t.now_us().saturating_sub(round_start_us));
                                }
                                r.done.push((local, loss));
                            }
                            Err(_) => {
                                r.fault = true;
                                break;
                            }
                        }
                    }
                    if let Some(t) = trace {
                        t.conn(round, i, io.stall_us, io.read_us, io.write_us);
                    }
                    r
                }));
            }
            handles.into_iter().map(|h| h.join().expect("downstream reader panicked")).collect()
        });
        let absorb_ms = ms_since(wait_t0);
        if let Some(t) = &trace {
            t.span(round, Phase::AbsorbWait, wait_start_us, t.now_us());
        }
        let fin_start_us = trace.as_ref().map_or(0, |t| t.now_us());

        // Roll up outcomes: a worker's unserved tail is dropped with
        // the fault/disconnect/deadline distinction the root's
        // membership accounting preserves.
        let mut outcomes = vec![OUTCOME_DROPPED_DISCONNECTED; m];
        let mut losses = vec![0.0f32; m];
        let mut dead = vec![false; nconns];
        let mut round_arrivals = Histogram::new();
        for (i, r) in reads.iter().enumerate() {
            self.sum.downstream_bytes += r.bytes_in;
            round_arrivals.merge(&r.arrivals);
            for &(local, loss) in &r.done {
                outcomes[local] = OUTCOME_ARRIVED;
                losses[local] = loss;
            }
            if r.done.len() < assignments[i].len() {
                dead[i] = true;
                let reason = if r.fault {
                    OUTCOME_DROPPED_FAULTED
                } else if r.timed_out {
                    OUTCOME_DROPPED_DEADLINE
                } else {
                    OUTCOME_DROPPED_DISCONNECTED
                };
                for &(gslot, local, _) in &assignments[i][r.done.len()..] {
                    outcomes[local] = reason;
                    if let Some(t) = &trace {
                        t.slot_dropped(round, gslot as usize, outcome_str(reason));
                    }
                }
            }
        }

        // Prune failed workers (best-effort abort so a live-but-slow
        // peer learns the round moved on without it).
        let mut idx = 0;
        self.conns.retain_mut(|conn| {
            let keep = !dead[idx];
            idx += 1;
            if !keep {
                let abort = Msg::Abort { reason: "subtree slot faulted or straggled".into() }
                    .encode();
                let _ = write_msg(conn, &abort);
                conn.shutdown();
            }
            keep
        });

        let stats = inflight.absorb_stats();
        let participants = outcomes.iter().filter(|&&o| o == OUTCOME_ARRIVED).count();
        // Mean loss over arrived slots, reduced in ascending slot
        // order (scheduling-invariant, same convention as the server).
        let mean_loss = if participants > 0 {
            outcomes
                .iter()
                .zip(&losses)
                .filter(|(&o, _)| o == OUTCOME_ARRIVED)
                .map(|(_, &l)| l as f64)
                .sum::<f64>()
                / participants as f64
        } else {
            0.0
        };

        // Fold the arrived subset into one merged frame. Parked frames
        // past dropped slots drain here; global-λ weighting means the
        // root absorbs this frame with weight 1 and renormalizes once.
        let reduce_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        if let Some(t) = &trace {
            t.span(round, Phase::Finalize, fin_start_us, reduce_start_us);
        }
        let reduce_t0 = Instant::now();
        let frame = match self.pipeline.finalize_subtree(inflight)? {
            Some(merged) => {
                let bytes = match spec {
                    UploadSpec::Sketch { .. } => {
                        encode_sketch_frame(merged.as_sketch()?, &F32LE)
                    }
                    UploadSpec::Dense { .. } => encode_dense_frame(merged.as_dense()?, &F32LE),
                };
                self.pipeline.recycle(merged);
                self.sum.merged_uploads += 1;
                bytes
            }
            None => Vec::new(),
        };
        let reduce_ms = ms_since(reduce_t0);
        if let Some(t) = &trace {
            t.span(round, Phase::Reduce, reduce_start_us, t.now_us());
            t.histogram(Some(round), "slot_arrival_us", &round_arrivals);
        }

        let reports: Vec<SlotReport> = entries
            .iter()
            .enumerate()
            .map(|(local, &(gslot, _, _))| SlotReport {
                slot: gslot,
                outcome: outcomes[local],
                retries: 0,
                loss: losses[local],
            })
            .collect();

        self.pending = Some(PendingRecord {
            round,
            mean_loss,
            lr,
            wire_upload: frame.len() as u64,
            participants,
            dropped_slots: m - participants,
            absorb_stalls: stats.lock_stalls,
            parked_bytes: stats.parked_bytes,
            chosen_shards: stats.chosen_shards as usize,
            bytes_marker,
            round_ms: ms_since(round_t0),
            absorb_ms,
            reduce_ms,
        });
        Ok(Msg::SubtreeUpload { round, reports, frame }.encode())
    }

    /// One *interior* subtree round (`relay_children > 0`): partition
    /// the chain over relay children with nested `SubtreeAssign`s,
    /// absorb one merged frame per child into the matching shard, fold
    /// the shards, and roll the children's slot reports up verbatim
    /// (retry counts accumulate; outcome codes pass through).
    ///
    /// Child `k` owns the chain's local positions `{i : i mod K == k}`
    /// in ascending order — the same modulo rule the root applies to
    /// global slots — and the pipeline is pinned to one shard per
    /// child, so `offer_chain_frame(k, ...)` lands each merged frame
    /// on exactly the shard that would have folded those positions.
    ///
    /// Faults mirror the root's relay round: a dead child's sub-chain
    /// is re-offered whole to the lowest-index surviving child when
    /// the retry budget allows (charging one retry per slot), and
    /// drops with the fault/disconnect/deadline distinction otherwise.
    #[allow(clippy::too_many_arguments)]
    fn run_subtree_relay(
        &mut self,
        round: u64,
        round_seed: u64,
        lr: f32,
        codec_id: u8,
        spec: &UploadSpec,
        entries: &[(u32, u32, f32)],
        weights_frame: &[u8],
        bytes_marker: u64,
        round_t0: Instant,
    ) -> Result<Vec<u8>> {
        let trace = self.trace.clone();
        let round_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let m = entries.len();
        let nconns = self.conns.len();
        let deadline = self.opts.quorum.round_deadline().map(|d| Instant::now() + d);
        let read_timeout = self.opts.read_timeout;
        let max_msg = self.opts.max_msg;
        for conn in &self.conns {
            let t = read_timeout;
            let _ = conn.set_timeouts(Some(t), Some(t));
        }

        // Sub-chains: child k owns local positions {i : i % nchains ==
        // k}, ascending, paired with their global (slot, client, λ)
        // entries. With fewer positions than children the tail gets
        // empty sub-chains (and still must answer) — same convention
        // as the root, and consistent with `shard_of` because i < m
        // implies i % m == i.
        let nchains = nconns.min(m);
        let mut chains: Vec<Vec<(usize, (u32, u32, f32))>> = vec![Vec::new(); nconns];
        for (local, &e) in entries.iter().enumerate() {
            chains[local % nchains].push((local, e));
        }

        // Local λs order the in-shard fold; child frames themselves
        // absorb at weight 1 (they already carry the global λs applied
        // one level down).
        let lambdas: Vec<f32> = entries.iter().map(|e| e.2).collect();
        let inflight = self.pipeline.begin(spec, lambdas)?;

        let mut alive = vec![true; nconns];
        for (k, conn) in self.conns.iter_mut().enumerate() {
            let head = Msg::SubtreeAssign {
                round,
                round_seed,
                lr,
                codec_id,
                spec: spec.clone(),
                entries: chains[k].iter().map(|&(_, e)| e).collect(),
                weights_frame: Vec::new(),
            }
            .encode();
            match write_msg_parts(conn, &head, weights_frame) {
                Ok(n) => self.sum.downstream_bytes += n,
                Err(_) => alive[k] = false,
            }
        }
        if let Some(t) = &trace {
            t.span(round, Phase::Plan, round_start_us, t.now_us());
        }

        // One reader per child: a single subtree-upload each, bounded
        // by the tighter of the per-read timeout and the relay's round
        // deadline. Frames absorb on the sweep below, in child order.
        struct ChildRead {
            upload: Option<(u64, Vec<SlotReport>, Vec<u8>)>,
            bytes_in: u64,
            /// When the merged upload finished arriving (µs since
            /// round start; 0 when untraced or nothing arrived).
            arrival_us: u64,
            fault: bool,
            deadline_hit: bool,
        }
        let wait_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        let wait_t0 = Instant::now();
        let reads: Vec<ChildRead> = std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .conns
                .iter_mut()
                .enumerate()
                .map(|(k, conn)| {
                    let live = alive[k];
                    let trace = trace.as_deref();
                    scope.spawn(move || {
                        let mut out = ChildRead {
                            upload: None,
                            bytes_in: 0,
                            arrival_us: 0,
                            fault: false,
                            deadline_hit: false,
                        };
                        if !live {
                            return out;
                        }
                        if let Some(dl) = deadline {
                            let rem = dl.saturating_duration_since(Instant::now());
                            if rem.is_zero() {
                                out.deadline_hit = true;
                                return out;
                            }
                            let t = read_timeout.min(rem);
                            let _ = conn.set_timeouts(Some(t), Some(t));
                        }
                        let mut io = ConnIo::default();
                        let read = match trace {
                            Some(_) => read_msg_timed(conn, max_msg).map(|(b, n, st, rd)| {
                                io.stall_us += st;
                                io.read_us += rd;
                                (b, n)
                            }),
                            None => read_msg(conn, max_msg),
                        };
                        match read {
                            Ok((bytes, n)) => {
                                out.bytes_in = n;
                                match Msg::decode(bytes) {
                                    Ok(Msg::SubtreeUpload { round, reports, frame }) => {
                                        if let Some(t) = trace {
                                            out.arrival_us =
                                                t.now_us().saturating_sub(round_start_us);
                                        }
                                        out.upload = Some((round, reports, frame));
                                    }
                                    Ok(_) | Err(_) => out.fault = true,
                                }
                            }
                            Err(_) => {
                                out.deadline_hit =
                                    deadline.is_some_and(|dl| Instant::now() >= dl);
                            }
                        }
                        if let Some(t) = trace {
                            t.conn(round, k, io.stall_us, io.read_us, io.write_us);
                        }
                        out
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("child relay reader panicked")).collect()
        });
        let absorb_ms = ms_since(wait_t0);
        if let Some(t) = &trace {
            t.span(round, Phase::AbsorbWait, wait_start_us, t.now_us());
        }
        let fin_start_us = trace.as_ref().map_or(0, |t| t.now_us());

        // Sweep in child order; failures collect for the re-offer pass.
        let mut outcomes = vec![OUTCOME_DROPPED_DISCONNECTED; m];
        let mut retries = vec![0u32; m];
        let mut losses = vec![0.0f32; m];
        let mut dead = vec![false; nconns];
        let mut failed: Vec<(usize, u8)> = Vec::new();
        let mut round_arrivals = Histogram::new();
        for (k, cr) in reads.into_iter().enumerate() {
            self.sum.downstream_bytes += cr.bytes_in;
            let arrival_us = cr.arrival_us;
            let failure = match cr.upload {
                Some((up_round, reports, frame)) => {
                    match absorb_child_chain(
                        &inflight, k, &chains[k], up_round, round, &reports, &frame,
                    ) {
                        Ok(()) => {
                            if trace.is_some() {
                                round_arrivals.record(arrival_us);
                            }
                            for (rep, &(local, _)) in reports.iter().zip(&chains[k]) {
                                outcomes[local] = rep.outcome;
                                retries[local] += rep.retries as u32;
                                losses[local] = rep.loss;
                            }
                            None
                        }
                        Err(_) => Some(OUTCOME_DROPPED_FAULTED),
                    }
                }
                None => Some(if cr.fault {
                    OUTCOME_DROPPED_FAULTED
                } else if cr.deadline_hit {
                    OUTCOME_DROPPED_DEADLINE
                } else {
                    OUTCOME_DROPPED_DISCONNECTED
                }),
            };
            if let Some(reason) = failure {
                dead[k] = true;
                failed.push((k, reason));
            }
        }

        // Mid-round sub-chain re-assignment, one level down from the
        // root's: a dead child's chain is untouched (absorption is
        // all-or-nothing), so under a retry budget it is re-offered
        // whole to the lowest-index surviving child. An unrescued
        // chain drops with the original fault's reason.
        for (k, reason) in failed {
            let assigned = &chains[k];
            let mut rescued = false;
            if !assigned.is_empty()
                && self.opts.quorum.max_slot_retries() >= 1
                && !deadline.is_some_and(|dl| Instant::now() >= dl)
            {
                if let Some(s) = (0..nconns).find(|&i| !dead[i]) {
                    if let Some(t) = &trace {
                        for &(_, (gslot, _, _)) in assigned {
                            t.slot_event(round, gslot as usize, SlotEvent::Reassigned, Some(s));
                        }
                    }
                    let res = (|| -> Result<(Vec<SlotReport>, u64)> {
                        let conn = &mut self.conns[s];
                        if let Some(dl) = deadline {
                            let rem = dl.saturating_duration_since(Instant::now());
                            let t = read_timeout.min(rem);
                            let _ = conn.set_timeouts(Some(t), Some(t));
                        }
                        let head = Msg::SubtreeAssign {
                            round,
                            round_seed,
                            lr,
                            codec_id,
                            spec: spec.clone(),
                            entries: assigned.iter().map(|&(_, e)| e).collect(),
                            weights_frame: Vec::new(),
                        }
                        .encode();
                        let mut bytes = write_msg_parts(conn, &head, weights_frame)?;
                        let (msg, n) = read_msg(conn, max_msg)?;
                        bytes += n;
                        let (up_round, reports, frame) = match Msg::decode(msg)? {
                            Msg::SubtreeUpload { round, reports, frame } => {
                                (round, reports, frame)
                            }
                            other => {
                                bail!("expected a subtree upload, got {}", other.kind_name())
                            }
                        };
                        absorb_child_chain(
                            &inflight, k, assigned, up_round, round, &reports, &frame,
                        )?;
                        Ok((reports, bytes))
                    })();
                    match res {
                        Ok((reports, bytes)) => {
                            self.sum.downstream_bytes += bytes;
                            for (rep, &(local, _)) in reports.iter().zip(assigned) {
                                outcomes[local] = rep.outcome;
                                // +1: the re-offer itself was a retry.
                                retries[local] += rep.retries as u32 + 1;
                                losses[local] = rep.loss;
                            }
                            rescued = true;
                        }
                        Err(_) => dead[s] = true,
                    }
                }
            }
            if !rescued {
                for &(local, (gslot, _, _)) in assigned {
                    outcomes[local] = reason;
                    if let Some(t) = &trace {
                        t.slot_dropped(round, gslot as usize, outcome_str(reason));
                    }
                }
            }
        }

        // Prune failed children (best-effort abort, like the root).
        let mut idx = 0;
        self.conns.retain_mut(|conn| {
            let keep = !dead[idx];
            idx += 1;
            if !keep {
                let abort =
                    Msg::Abort { reason: "subtree chain faulted or straggled".into() }.encode();
                let _ = write_msg(conn, &abort);
                conn.shutdown();
            }
            keep
        });

        let stats = inflight.absorb_stats();
        let participants = outcomes.iter().filter(|&&o| o == OUTCOME_ARRIVED).count();
        let mean_loss = if participants > 0 {
            outcomes
                .iter()
                .zip(&losses)
                .filter(|(&o, _)| o == OUTCOME_ARRIVED)
                .map(|(_, &l)| l as f64)
                .sum::<f64>()
                / participants as f64
        } else {
            0.0
        };

        // Fold the child shards into one merged frame: left-associated
        // over children in index order, which is exactly the grouped
        // reduce `reduce_shards_tree` replays on the flat side.
        let reduce_start_us = trace.as_ref().map_or(0, |t| t.now_us());
        if let Some(t) = &trace {
            t.span(round, Phase::Finalize, fin_start_us, reduce_start_us);
        }
        let reduce_t0 = Instant::now();
        let frame = match self.pipeline.finalize_subtree(inflight)? {
            Some(merged) => {
                let bytes = match spec {
                    UploadSpec::Sketch { .. } => {
                        encode_sketch_frame(merged.as_sketch()?, &F32LE)
                    }
                    UploadSpec::Dense { .. } => encode_dense_frame(merged.as_dense()?, &F32LE),
                };
                self.pipeline.recycle(merged);
                self.sum.merged_uploads += 1;
                bytes
            }
            None => Vec::new(),
        };
        let reduce_ms = ms_since(reduce_t0);
        if let Some(t) = &trace {
            t.span(round, Phase::Reduce, reduce_start_us, t.now_us());
            t.histogram(Some(round), "slot_arrival_us", &round_arrivals);
        }

        let reports: Vec<SlotReport> = entries
            .iter()
            .enumerate()
            .map(|(local, &(gslot, _, _))| SlotReport {
                slot: gslot,
                outcome: outcomes[local],
                retries: retries[local].min(u16::MAX as u32) as u16,
                loss: losses[local],
            })
            .collect();

        self.pending = Some(PendingRecord {
            round,
            mean_loss,
            lr,
            wire_upload: frame.len() as u64,
            participants,
            dropped_slots: m - participants,
            absorb_stalls: stats.lock_stalls,
            parked_bytes: stats.parked_bytes,
            chosen_shards: stats.chosen_shards as usize,
            bytes_marker,
            round_ms: ms_since(round_t0),
            absorb_ms,
            reduce_ms,
        });
        Ok(Msg::SubtreeUpload { round, reports, frame }.encode())
    }

    /// Forward one encoded message to every downstream worker, pruning
    /// connections whose write fails.
    fn broadcast_down(&mut self, bytes: &[u8]) {
        let mut sent = 0u64;
        self.conns.retain_mut(|conn| match write_msg(conn, bytes) {
            Ok(n) => {
                sent += n;
                true
            }
            Err(_) => {
                conn.shutdown();
                false
            }
        });
        self.sum.downstream_bytes += sent;
    }

    fn log_round(&mut self, p: PendingRecord, wire_download: u64) {
        let transport =
            (self.sum.upstream_bytes + self.sum.downstream_bytes).saturating_sub(p.bytes_marker);
        self.logger.log_round(RoundRecord {
            round: p.round as usize,
            loss: p.mean_loss,
            lr: p.lr as f64,
            // Idealized byte accounting is the root's concern; relay
            // rows report only what this tier measured on the wire.
            upload_bytes: 0,
            download_bytes: 0,
            wire_upload_bytes: p.wire_upload,
            wire_download_bytes: wire_download,
            transport_bytes: transport,
            absorb_stalls: p.absorb_stalls,
            parked_bytes: p.parked_bytes,
            chosen_shards: p.chosen_shards,
            participants: p.participants,
            dropped_slots: p.dropped_slots,
            retried_slots: 0,
            update_nnz: 0,
            round_ms: p.round_ms,
            compute_ms: 0.0,
            absorb_ms: p.absorb_ms,
            reduce_ms: p.reduce_ms,
            tier: Some("relay"),
        });
    }

    /// The number of downstream peers a subtree round needs: relay
    /// children in interior mode, workers otherwise.
    fn want_peers(&self) -> usize {
        if self.opts.relay_children > 0 {
            self.opts.relay_children
        } else {
            self.opts.workers
        }
    }

    /// Accept + handshake until the downstream pool is full (workers
    /// in leaf mode, relay peers in interior mode). Same contract as
    /// the server's: peers failing the hello handshake are dropped and
    /// accepting continues until the deadline.
    fn ensure_workers(&mut self) -> Result<()> {
        let want = self.want_peers();
        let relay = self.opts.relay_children > 0;
        let deadline = Instant::now() + self.opts.accept_timeout;
        while self.conns.len() < want {
            if Instant::now() >= deadline {
                bail!(
                    "timed out waiting for downstream workers ({}/{} connected)",
                    self.conns.len(),
                    want
                );
            }
            let mut conn = self.accept_one(deadline)?;
            let remaining = deadline.saturating_duration_since(Instant::now());
            let hs = self.opts.read_timeout.min(remaining).max(Duration::from_millis(10));
            let _ = conn.set_timeouts(Some(hs), Some(hs));
            match handshake(&mut conn, self.opts.max_msg, relay) {
                Ok(()) => {
                    let t = self.opts.read_timeout;
                    conn.set_timeouts(Some(t), Some(t))?;
                    self.conns.push(conn);
                }
                Err(_) => {
                    let abort = Msg::Abort { reason: "handshake failed".into() }.encode();
                    let _ = write_msg(&mut conn, &abort);
                    conn.shutdown();
                }
            }
        }
        Ok(())
    }

    fn accept_one(&self, deadline: Instant) -> Result<Conn> {
        loop {
            let accepted = match &self.listener {
                ListenerKind::Tcp(l) => l.accept().map(|(s, _)| Conn::from_tcp(s)),
                #[cfg(unix)]
                ListenerKind::Unix(l) => l.accept().map(|(s, _)| Conn::from_unix(s)),
            };
            match accepted {
                Ok(conn) => {
                    conn.set_blocking()?;
                    let t = self.opts.read_timeout;
                    conn.set_timeouts(Some(t), Some(t))?;
                    return Ok(conn);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!(
                            "timed out waiting for downstream workers ({}/{} connected)",
                            self.conns.len(),
                            self.want_peers()
                        );
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e).context("accepting downstream connection"),
            }
        }
    }
}

/// Stable wire label for a dropped-slot outcome code, matching the
/// labels the root emits (see
/// `crate::transport::server::drop_reason_str`) so `trace-summary`
/// groups drops identically across tiers.
fn outcome_str(code: u8) -> &'static str {
    match code {
        OUTCOME_DROPPED_FAULTED => "faulted",
        OUTCOME_DROPPED_DEADLINE => "deadline",
        _ => "disconnect",
    }
}

/// Validate one child relay's `SubtreeUpload` against its assigned
/// sub-chain and absorb the merged frame at this relay's *local* slot
/// positions — the nested analogue of the root's chain absorption.
/// `assigned` pairs each local position with its global entry; the
/// reports must cover the sub-chain's global slots exactly, in order,
/// and the merged frame must be present iff at least one slot
/// arrived. All-or-nothing: any violation leaves the shard untouched.
fn absorb_child_chain(
    absorber: &RoundInFlight,
    chain: usize,
    assigned: &[(usize, (u32, u32, f32))],
    round: u64,
    expect_round: u64,
    reports: &[SlotReport],
    frame: &[u8],
) -> Result<()> {
    if round != expect_round {
        bail!("subtree upload for round {round}, expected round {expect_round}");
    }
    if reports.len() != assigned.len() {
        bail!("{} slot report(s) for a {}-slot chain", reports.len(), assigned.len());
    }
    for (rep, &(_, (gslot, _, _))) in reports.iter().zip(assigned) {
        if rep.slot != gslot {
            bail!("report for slot {}, expected slot {gslot}", rep.slot);
        }
        if rep.outcome > OUTCOME_DROPPED_DEADLINE {
            bail!("unknown slot outcome {} for slot {gslot}", rep.outcome);
        }
    }
    let arrived: Vec<usize> = reports
        .iter()
        .zip(assigned)
        .filter(|(rep, _)| rep.outcome == OUTCOME_ARRIVED)
        .map(|(_, &(local, _))| local)
        .collect();
    if arrived.is_empty() != frame.is_empty() {
        bail!(
            "merged frame presence ({} bytes) disagrees with {} arrived report(s)",
            frame.len(),
            arrived.len()
        );
    }
    if !arrived.is_empty() {
        absorber.offer_chain_frame(chain, &arrived, frame)?;
    }
    Ok(())
}

impl Drop for Relay {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Some(p) = &self.uds_path {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Bind a relay and serve an upstream round server until shutdown —
/// the library entry `fetchsgd relay` wraps.
pub fn relay(upstream: &Endpoint, listen: &Endpoint, opts: RelayOptions) -> Result<RelaySummary> {
    let mut node = Relay::bind(listen, opts)?;
    node.run(upstream)
}

/// Run a relay from a `TrainConfig` — the mid-tier of `fetchsgd serve`
/// / `fetchsgd relay` / `fetchsgd join`. Needs only the task manifest
/// (for message sizing), not the PJRT runtime: a relay never runs
/// client compute or applies updates, it only folds frames.
pub fn relay_training(cfg: &crate::config::TrainConfig) -> Result<RelaySummary> {
    use crate::runtime::artifact::Manifest;
    use crate::transport::server::duration_from_cfg_secs;

    let up_spec = cfg
        .transport
        .as_deref()
        .context("relay mode needs an upstream endpoint (transport=tcp:HOST:PORT | uds:/path)")?;
    let upstream = Endpoint::parse(up_spec)?;
    let listen_spec = cfg
        .relay_listen
        .as_deref()
        .context("relay mode needs a downstream endpoint (relay_listen=tcp:HOST:PORT | uds:/path)")?;
    let listen = Endpoint::parse(listen_spec)?;
    let manifest = Manifest::load(&cfg.artifacts_dir)?;
    let dim = manifest.task(&cfg.task)?.dim;
    let opts = RelayOptions {
        workers: cfg.transport_workers,
        relay_children: cfg.relay_children,
        quorum: cfg.quorum_policy()?,
        read_timeout: duration_from_cfg_secs(cfg.serve_read_timeout_s, "serve_read_timeout_s")?,
        accept_timeout: duration_from_cfg_secs(
            cfg.serve_accept_timeout_s,
            "serve_accept_timeout_s",
        )?,
        max_msg: crate::transport::effective_max_msg(cfg, dim)?,
        reconnect_attempts: cfg.reconnect_attempts,
        reconnect_backoff_ms: cfg.reconnect_backoff_ms,
        log_path: cfg.log_path.clone(),
        trace_path: cfg.trace_path.clone(),
        ..Default::default()
    };
    let mut node = Relay::bind(&listen, opts)?;
    if cfg.relay_children > 0 {
        eprintln!(
            "[relay] listening on {} for {} relay child(ren), upstream {}",
            node.local_endpoint()?,
            cfg.relay_children,
            upstream
        );
    } else {
        eprintln!(
            "[relay] listening on {} for {} worker(s), upstream {}",
            node.local_endpoint()?,
            cfg.transport_workers,
            upstream
        );
    }
    node.run(&upstream)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_relay() -> Relay {
        let ep = Endpoint::parse("tcp:127.0.0.1:0").unwrap();
        Relay::bind(&ep, RelayOptions::default()).unwrap()
    }

    #[test]
    fn empty_chain_answers_immediately() {
        let mut r = test_relay();
        // No downstream workers are connected — an empty chain must
        // not touch the pool at all.
        let reply = r
            .run_subtree(5, 99, 0.5, 1, &UploadSpec::Dense { dim: 16 }, &[], &[1, 2, 3])
            .unwrap();
        match Msg::decode(reply).unwrap() {
            Msg::SubtreeUpload { round, reports, frame } => {
                assert_eq!(round, 5);
                assert!(reports.is_empty());
                assert!(frame.is_empty());
            }
            _ => panic!("expected subtree-upload"),
        }
        // The staged record still logs a zero-participant round.
        let p = r.pending.take().unwrap();
        assert_eq!(p.round, 5);
        assert_eq!(p.participants, 0);
        assert_eq!(p.dropped_slots, 0);
    }

    #[test]
    fn non_ascending_chain_is_rejected() {
        let mut r = test_relay();
        let entries = [(2u32, 0u32, 1.0f32), (1, 1, 1.0)];
        let err = r
            .run_subtree(0, 0, 0.1, 1, &UploadSpec::Dense { dim: 16 }, &entries, &[1])
            .unwrap_err();
        assert!(err.to_string().contains("ascending"), "{err:#}");
        // Duplicate slots are equally malformed.
        let entries = [(3u32, 0u32, 1.0f32), (3, 1, 1.0)];
        assert!(r
            .run_subtree(0, 0, 0.1, 1, &UploadSpec::Dense { dim: 16 }, &entries, &[1])
            .is_err());
    }

    #[test]
    fn ephemeral_bind_resolves_port() {
        let r = test_relay();
        match r.local_endpoint().unwrap() {
            Endpoint::Tcp(addr) => assert!(!addr.ends_with(":0"), "{addr}"),
            #[cfg(unix)]
            _ => panic!("expected tcp endpoint"),
        }
    }
}
