//! Communication accounting — the x-axis of every figure in the paper.
//!
//! Conventions follow the paper:
//! - only non-zero f32 payloads count (footnote 5: an idealized sparse
//!   encoding with zero index overhead);
//! - compression is reported relative to uncompressed SGD run for the
//!   *baseline* round count: `baseline_bytes / observed_bytes`, split
//!   into upload, download, and overall (up + down);
//! - per-round download for sparse methods is the round's broadcast
//!   nnz; FedAvg/uncompressed download the full model.
//!
//! ## Measured vs. idealized
//!
//! The numbers above are an accounting *fiction*: footnote 5 assumes a
//! zero-overhead sparse index encoding and no framing. When wire mode
//! is on (`TrainConfig.wire`), every upload and broadcast additionally
//! passes through the real framed encoding (`crate::wire`) and the
//! **measured** frame bytes — header, shape, explicit `u32` indices,
//! codec payload — are recorded in [`CommStats::wire_upload_bytes`] /
//! [`CommStats::wire_download_bytes`]. Measured is always ≥ idealized
//! under `f32le` (pure overhead); a lossy codec like `f16le` can dip
//! below it on dense payloads (2 bytes/value). Figures can then show
//! both conventions side by side.
//!
//! [`StalenessTracker`] implements the stricter model the paper
//! discusses qualitatively in §5: a client downloads the union of all
//! sparse updates since it last held the current model, so infrequent
//! participants pay more. Both numbers are logged.

/// Running communication totals for one training run.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    /// Total bytes uploaded across all clients and rounds.
    pub upload_bytes: u64,
    /// Total bytes downloaded (per-round convention).
    pub download_bytes: u64,
    /// Total bytes downloaded (staleness-aware convention).
    pub download_bytes_stale: u64,
    /// Total *measured* wire-frame bytes uploaded (0 when wire mode is
    /// off; see the module docs on measured vs. idealized).
    pub wire_upload_bytes: u64,
    /// Total *measured* wire-frame bytes broadcast.
    pub wire_download_bytes: u64,
    pub rounds: u64,
    pub client_rounds: u64,
}

impl CommStats {
    pub fn record_round(
        &mut self,
        participants: usize,
        upload_per_client: u64,
        download_per_client: u64,
        stale_download: u64,
        wire_upload_per_client: u64,
        wire_download_per_client: u64,
    ) {
        self.rounds += 1;
        self.client_rounds += participants as u64;
        self.upload_bytes += upload_per_client * participants as u64;
        self.download_bytes += download_per_client * participants as u64;
        self.download_bytes_stale += stale_download;
        self.wire_upload_bytes += wire_upload_per_client * participants as u64;
        self.wire_download_bytes += wire_download_per_client * participants as u64;
    }

    /// Compression ratios vs an uncompressed run of `baseline_rounds`
    /// rounds with `participants` clients per round over a model of
    /// `dim` parameters (both directions dense).
    pub fn ratios(&self, baseline_rounds: u64, participants: u64, dim: usize) -> Ratios {
        let dense = 4 * dim as u64 * baseline_rounds * participants;
        let up = dense as f64 / self.upload_bytes.max(1) as f64;
        let down = dense as f64 / self.download_bytes.max(1) as f64;
        let overall = (2 * dense) as f64 / (self.upload_bytes + self.download_bytes).max(1) as f64;
        Ratios { upload: up, download: down, overall }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct Ratios {
    pub upload: f64,
    pub download: f64,
    pub overall: f64,
}

/// Staleness-aware download accounting: tracks, per client, the set of
/// model coordinates changed since that client last synced. A client
/// that participates must first download every stale coordinate.
///
/// Exact per-coordinate tracking over 50k clients × 1M params is
/// infeasible, so we track per client the *round* at which it last
/// synced, plus a ring of per-round update supports; the stale set is
/// the union of supports since last sync (with the union's size capped
/// at `dim` — a fully stale client just re-downloads the model).
pub struct StalenessTracker {
    dim: usize,
    /// round index at which each client last synced (or None).
    last_sync: Vec<Option<u64>>,
    /// per-round update nnz history (prefix-summed for O(1) range size
    /// upper bound) — an upper bound of the union size.
    nnz_prefix: Vec<u64>,
}

impl StalenessTracker {
    pub fn new(num_clients: usize, dim: usize) -> Self {
        StalenessTracker { dim, last_sync: vec![None; num_clients], nnz_prefix: vec![0] }
    }

    /// Record a round's broadcast update and charge download bytes to the
    /// participants. Returns total staleness-aware download bytes.
    pub fn round(&mut self, round: u64, participants: &[usize], update_nnz: usize) -> u64 {
        debug_assert_eq!(self.nnz_prefix.len() as u64, round + 1);
        let mut total = 0u64;
        for &c in participants {
            let stale_from = self.last_sync[c];
            let stale_coords = match stale_from {
                None => self.dim as u64, // first participation: full model
                Some(r) => {
                    let span = self.nnz_prefix[round as usize] - self.nnz_prefix[r as usize];
                    span.min(self.dim as u64)
                }
            };
            // ... plus this round's own update (they must apply it too).
            let this_round = (update_nnz as u64).min(self.dim as u64);
            total += 4 * (stale_coords + this_round);
            self.last_sync[c] = Some(round + 1);
        }
        self.nnz_prefix.push(self.nnz_prefix[round as usize] + update_nnz as u64);
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::RoundUpdate;
    use crate::sketch::SparseVec;

    #[test]
    fn ratios_vs_dense_baseline() {
        let mut c = CommStats::default();
        let update = RoundUpdate::Sparse(SparseVec::from_pairs(100, vec![(1, 1.0), (2, 2.0)]));
        // 10 rounds, 2 clients, 40-byte uploads (10 floats)
        for _ in 0..10 {
            c.record_round(2, 40, update.payload_bytes(), 0, 64, 48);
        }
        let r = c.ratios(10, 2, 100);
        // dense: 4*100*10*2 = 8000 bytes each way
        assert!((r.upload - 8000.0 / 800.0).abs() < 1e-9);
        assert!((r.download - 8000.0 / 160.0).abs() < 1e-9);
        assert!((r.overall - 16000.0 / 960.0).abs() < 1e-9);
        // measured frame bytes accumulate independently of the estimate
        assert_eq!(c.wire_upload_bytes, 64 * 2 * 10);
        assert_eq!(c.wire_download_bytes, 48 * 2 * 10);
        assert!(c.wire_upload_bytes >= c.upload_bytes);
    }

    #[test]
    fn staleness_first_participation_costs_full_model() {
        let mut t = StalenessTracker::new(3, 1000);
        let bytes = t.round(0, &[0], 10);
        assert_eq!(bytes, 4 * (1000 + 10));
        // client 0 again next round: only the missed round (none) + new
        let bytes = t.round(1, &[0], 10);
        assert_eq!(bytes, 4 * 10);
        // client 1 first time at round 2: full model + this update
        let bytes = t.round(2, &[1], 10);
        assert_eq!(bytes, 4 * (1000 + 10));
    }

    #[test]
    fn staleness_accumulates_missed_updates() {
        let mut t = StalenessTracker::new(2, 10_000);
        t.round(0, &[0], 100);
        t.round(1, &[1], 100); // client 0 misses this
        t.round(2, &[1], 100); // and this
        let bytes = t.round(3, &[0], 100);
        // client 0 missed rounds 1,2 (200 coords) + round 3's 100
        assert_eq!(bytes, 4 * (200 + 100));
    }

    #[test]
    fn staleness_caps_at_full_model() {
        let mut t = StalenessTracker::new(1, 50);
        t.round(0, &[0], 40);
        for r in 1..10 {
            t.round(r, &[], 40);
        }
        let bytes = t.round(10, &[0], 40);
        // union capped at dim=50, plus this round's 40
        assert_eq!(bytes, 4 * (50 + 40));
    }
}
