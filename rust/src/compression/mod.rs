//! Compression strategies: FetchSGD and every baseline the paper
//! compares against.
//!
//! Each strategy is split into two halves, mirroring where the work
//! physically runs in a federated deployment:
//!
//! - [`ClientCompute`] — the stateless, `Send + Sync` per-client map:
//!   `(artifacts, weights, batch) -> ClientUpload`. The round engine
//!   (`crate::coordinator::engine`) fans these out over a worker pool.
//! - [`ServerAggregator`] — the stateful server half: it declares the
//!   shape of the uploads it consumes ([`UploadSpec`]) and the per-slot
//!   aggregation weights ([`ServerAggregator::begin_round`]); the round
//!   pipeline ([`aggregate::RoundPipeline`]) folds uploads into shard
//!   accumulators ([`aggregate::RoundAccum`]) the moment they arrive —
//!   driven in-process by the engine and over sockets by the transport
//!   server — and [`ServerAggregator::finish`] turns the merged
//!   weighted sum into a model update (momentum, error feedback, top-k
//!   — the strategy's actual math).
//!
//! Every strategy's fan-in is a *weighted sum* of uploads (FetchSGD:
//! uniform `1/W` over sketches — sketch linearity; FedAvg: dataset-size
//! weights over dense deltas; top-k/uncompressed: uniform mean), which
//! is what makes the merge step strategy-agnostic and shardable.
//!
//! | strategy       | client compute artifact   | upload            | server state |
//! |----------------|---------------------------|-------------------|--------------|
//! | `fetchsgd`     | `client_step_c{cols}`     | R×C sketch        | S_u, S_e sketches |
//! | `local_topk`   | `client_grad`             | k-sparse grad     | optional global momentum |
//! | `fedavg`       | `fedavg_k{K}`             | dense delta       | optional global momentum |
//! | `uncompressed` | `client_grad`             | dense grad        | optional global momentum |
//! | `true_topk`    | `client_grad`             | dense grad        | dense momentum + error vectors |
//!
//! Byte accounting follows the paper's convention (footnote 5): only
//! non-zero f32 payloads count, assuming a zero-overhead sparse index
//! encoding. [`accounting`] additionally implements staleness-aware
//! download tracking (clients fetch the union of sparse updates since
//! their last participation) as a stricter alternative. When wire mode
//! is on (`TrainConfig.wire`), uploads and broadcasts additionally
//! round-trip through the framed binary encoding in [`crate::wire`] and
//! the *measured* frame bytes are recorded next to the estimates.
//!
//! [`RoundUpdate`] is the broadcast message itself — [`ServerAggregator::finish`]
//! produces it without touching the model, and the caller applies it
//! with [`RoundUpdate::apply`] (possibly after a wire encode→decode, so
//! lossy codecs affect the applied update exactly as a real deployment
//! would).

pub mod accounting;
pub mod aggregate;
pub mod fedavg;
pub mod fetchsgd;
pub mod local_topk;
pub mod sim;
pub mod timing;
pub mod true_topk;
pub mod uncompressed;

use anyhow::Result;

use crate::compression::aggregate::RoundAccum;
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::Batch;
use crate::sketch::{CountSketch, SparseVec};

/// What a client sends to the aggregator.
#[derive(Clone, Debug)]
pub enum ClientUpload {
    Sketch(CountSketch),
    Sparse(SparseVec),
    Dense(Vec<f32>),
}

impl ClientUpload {
    /// Upload payload bytes under the paper's accounting convention.
    pub fn payload_bytes(&self) -> u64 {
        match self {
            ClientUpload::Sketch(s) => s.payload_bytes(),
            ClientUpload::Sparse(sv) => sv.payload_bytes(),
            ClientUpload::Dense(v) => 4 * v.len() as u64,
        }
    }
}

/// The model update the server broadcasts after a round. This is the
/// actual broadcast *message*: it carries the step values, applies to a
/// weight vector via [`RoundUpdate::apply`], and encodes onto the wire
/// via [`crate::wire::encode_update`].
pub enum RoundUpdate {
    /// k-sparse step (FetchSGD, local/true top-k): `w -= Δ`.
    Sparse(SparseVec),
    /// Dense step vector (uncompressed, FedAvg): `w -= step`.
    Dense(Vec<f32>),
}

impl RoundUpdate {
    /// Apply the broadcast to a weight vector: `w -= update`.
    pub fn apply(&self, w: &mut [f32]) {
        match self {
            RoundUpdate::Sparse(sv) => sv.add_into(w, -1.0),
            RoundUpdate::Dense(step) => {
                assert_eq!(step.len(), w.len(), "dense update dim mismatch");
                for (wi, &s) in w.iter_mut().zip(step) {
                    *wi -= s;
                }
            }
        }
    }

    /// Download payload bytes under the paper's idealized accounting
    /// convention (non-zero f32 values only, zero-overhead indices).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            RoundUpdate::Sparse(sv) => sv.payload_bytes(),
            RoundUpdate::Dense(step) => 4 * step.len() as u64,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            RoundUpdate::Sparse(sv) => sv.nnz(),
            RoundUpdate::Dense(step) => step.len(),
        }
    }
}

/// Outcome of one client's local computation.
pub struct ClientResult {
    pub loss: f32,
    pub upload: ClientUpload,
}

/// Shape of a strategy's uploads — what the engine pre-allocates for
/// shard accumulation. Sparse uploads fold into a dense accumulator
/// (their weighted sum is generally much denser than any one upload).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum UploadSpec {
    Sketch { rows: usize, cols: usize, dim: usize, seed: u64 },
    Dense { dim: usize },
}

impl UploadSpec {
    /// Validate a parsed wire frame against the shape this aggregator
    /// consumes. Kind, geometry, dimension, and hash-seed mismatches all
    /// fail loudly — a client on a stale sketch seed must never be
    /// silently folded into the round. (Frame-level integrity — magic,
    /// version, lengths, index bounds — is already enforced by
    /// [`crate::wire::Frame::parse`].)
    pub fn validate_frame(&self, frame: &crate::wire::Frame<'_>) -> Result<()> {
        use crate::wire::Body;
        match (self, &frame.body) {
            (
                UploadSpec::Sketch { rows, cols, dim, seed },
                Body::Sketch { rows: fr, cols: fc, dim: fd, seed: fs, .. },
            ) => {
                if (fr, fc, fd, fs) != (rows, cols, dim, seed) {
                    anyhow::bail!(
                        "sketch frame {fr}x{fc} (dim {fd}, seed {fs}) incompatible with \
                         expected {rows}x{cols} (dim {dim}, seed {seed})"
                    );
                }
                Ok(())
            }
            (UploadSpec::Sketch { .. }, _) => {
                anyhow::bail!("aggregator expects sketch frames, got a {:?} frame", frame.kind())
            }
            (UploadSpec::Dense { dim }, Body::Dense { dim: fd, .. })
            | (UploadSpec::Dense { dim }, Body::Sparse { dim: fd, .. }) => {
                if fd != dim {
                    anyhow::bail!("frame dim {fd} != aggregator dim {dim}");
                }
                Ok(())
            }
            (UploadSpec::Dense { .. }, Body::Sketch { .. }) => {
                anyhow::bail!("aggregator expects dense/sparse frames, got a sketch frame")
            }
        }
    }
}

/// The client half of a strategy: one client's local work for a round.
///
/// Implementations must be stateless with respect to the round (`&self`,
/// `Send + Sync`): the engine calls them concurrently from worker
/// threads. `lr` is the current scheduled learning rate (used by
/// FedAvg's local steps; sketch/gradient methods apply lr on the
/// server).
pub trait ClientCompute: Send + Sync {
    fn name(&self) -> &'static str;

    /// Whether this strategy needs stacked FedAvg-style local batches.
    fn wants_stacked_batches(&self) -> Option<usize> {
        None
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        client: usize,
        stacked: Option<(crate::runtime::Tensor, crate::runtime::Tensor, crate::runtime::Tensor)>,
        lr: f32,
    ) -> Result<ClientResult>;
}

/// The server half of a strategy: consumes the round's merged weighted
/// upload sum and updates the model.
pub trait ServerAggregator: Send {
    fn name(&self) -> &'static str;

    /// Start a round. `client_sizes` are the participants' local dataset
    /// sizes, in slot order; the return value is the per-slot
    /// aggregation weight `λ_i` such that the strategy consumes
    /// `Σ_i λ_i · upload_i` (FedAvg weights by dataset size, everything
    /// else averages uniformly).
    fn begin_round(&mut self, client_sizes: &[f32]) -> Vec<f32>;

    /// The upload shape this aggregator consumes (drives shard scratch
    /// allocation and upload validation in [`aggregate::RoundAccum`]).
    fn upload_spec(&self) -> UploadSpec;

    /// Consume the merged weighted sum (by reference — the accumulator's
    /// allocation is reused across rounds) and produce the broadcast
    /// update. Must NOT touch the model: the caller applies the update
    /// via [`RoundUpdate::apply`], optionally after a wire round-trip.
    fn finish(&mut self, merged: &RoundAccum, lr: f32) -> Result<RoundUpdate>;
}
