//! Compression strategies: FetchSGD and every baseline the paper
//! compares against, behind a common [`Strategy`] interface so the
//! coordinator's round loop is strategy-agnostic.
//!
//! | strategy       | client compute artifact   | upload            | server state |
//! |----------------|---------------------------|-------------------|--------------|
//! | `fetchsgd`     | `client_step_c{cols}`     | R×C sketch        | S_u, S_e sketches |
//! | `local_topk`   | `client_grad`             | k-sparse grad     | optional global momentum |
//! | `fedavg`       | `fedavg_k{K}`             | dense delta       | optional global momentum |
//! | `uncompressed` | `client_grad`             | dense grad        | optional global momentum |
//! | `true_topk`    | `client_grad`             | dense grad        | dense momentum + error vectors |
//!
//! Byte accounting follows the paper's convention (footnote 5): only
//! non-zero f32 payloads count, assuming a zero-overhead sparse index
//! encoding. [`accounting`] additionally implements staleness-aware
//! download tracking (clients fetch the union of sparse updates since
//! their last participation) as a stricter alternative.

pub mod accounting;
pub mod fedavg;
pub mod fetchsgd;
pub mod local_topk;
pub mod timing;
pub mod true_topk;
pub mod uncompressed;

use anyhow::Result;

use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::Batch;
use crate::sketch::{CountSketch, SparseVec};

/// What a client sends to the aggregator.
pub enum ClientUpload {
    Sketch(CountSketch),
    Sparse(SparseVec),
    Dense(Vec<f32>),
}

impl ClientUpload {
    /// Upload payload bytes under the paper's accounting convention.
    pub fn payload_bytes(&self) -> u64 {
        match self {
            ClientUpload::Sketch(s) => s.payload_bytes(),
            ClientUpload::Sparse(sv) => sv.payload_bytes(),
            ClientUpload::Dense(v) => 4 * v.len() as u64,
        }
    }
}

/// The model update the server broadcasts after a round.
pub enum RoundUpdate {
    /// k-sparse update (FetchSGD, local/true top-k).
    Sparse(SparseVec),
    /// Dense update (uncompressed, FedAvg).
    Dense,
}

impl RoundUpdate {
    pub fn download_bytes(&self, dim: usize) -> u64 {
        match self {
            RoundUpdate::Sparse(sv) => sv.payload_bytes(),
            RoundUpdate::Dense => 4 * dim as u64,
        }
    }

    pub fn nnz(&self, dim: usize) -> usize {
        match self {
            RoundUpdate::Sparse(sv) => sv.nnz(),
            RoundUpdate::Dense => dim,
        }
    }
}

/// Outcome of one client's local computation.
pub struct ClientResult {
    pub loss: f32,
    pub upload: ClientUpload,
}

/// A federated optimization strategy: how clients compress, how the
/// server aggregates and updates the model.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// Execute one client's local work for this round. `lr` is the
    /// current scheduled learning rate (used by FedAvg's local steps;
    /// sketch/gradient methods apply lr on the server).
    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        client: usize,
        stacked: Option<(crate::runtime::Tensor, crate::runtime::Tensor, crate::runtime::Tensor)>,
        lr: f32,
    ) -> Result<ClientResult>;

    /// Whether this strategy needs stacked FedAvg-style local batches.
    fn wants_stacked_batches(&self) -> Option<usize> {
        None
    }

    /// Called before client work each round with the participants' local
    /// dataset sizes (FedAvg uses them as aggregation weights).
    fn begin_round(&mut self, _client_sizes: &[f32]) {}

    /// Aggregate uploads and update `w` in place; returns the broadcast
    /// update for download accounting.
    fn server_round(&mut self, uploads: Vec<ClientUpload>, w: &mut [f32], lr: f32)
        -> Result<RoundUpdate>;
}
