//! Uncompressed distributed SGD — the accuracy ceiling baseline.
//!
//! Clients upload dense gradients; the server averages, applies global
//! momentum, and takes a dense step. "Compression" for this method in
//! the paper's figures comes from simply training for fewer epochs; the
//! experiment drivers sweep `rounds` for that.
//!
//! The client half is [`crate::compression::true_topk::DenseGradClient`]
//! (plain dense gradient upload) — only the server half differs.

use anyhow::Result;

use crate::compression::aggregate::RoundAccum;
use crate::compression::{ClientUpload, RoundUpdate, ServerAggregator, UploadSpec};

/// Server half: dense mean + optional global momentum, lr-scaled step.
pub struct UncompressedServer {
    dim: usize,
    rho_g: f32,
    momentum: Vec<f32>,
}

impl UncompressedServer {
    pub fn new(dim: usize, rho_g: f32) -> Self {
        UncompressedServer { dim, rho_g, momentum: vec![0f32; dim] }
    }
}

impl ServerAggregator for UncompressedServer {
    fn name(&self) -> &'static str {
        "uncompressed"
    }

    fn begin_round(&mut self, client_sizes: &[f32]) -> Vec<f32> {
        let w = client_sizes.len().max(1) as f32;
        vec![1.0 / w; client_sizes.len()]
    }

    fn upload_spec(&self) -> UploadSpec {
        UploadSpec::Dense { dim: self.dim }
    }

    fn finish(&mut self, merged: &RoundAccum, lr: f32) -> Result<RoundUpdate> {
        let mean = merged.as_dense()?;
        let step: Vec<f32> = if self.rho_g > 0.0 {
            for (m, &g) in self.momentum.iter_mut().zip(mean) {
                *m = self.rho_g * *m + g;
            }
            self.momentum.iter().map(|&m| lr * m).collect()
        } else {
            mean.iter().map(|&g| lr * g).collect()
        };
        Ok(RoundUpdate::Dense(step))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::aggregate::run_server_round;

    fn server_round(
        s: &mut UncompressedServer,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        lr: f32,
    ) -> RoundUpdate {
        let sizes = vec![1.0f32; uploads.len()];
        run_server_round(s, &sizes, uploads, w, lr).unwrap()
    }

    #[test]
    fn plain_sgd_step() {
        let mut s = UncompressedServer::new(3, 0.0);
        let mut w = vec![1.0f32; 3];
        let u = vec![
            ClientUpload::Dense(vec![1.0, 0.0, 2.0]),
            ClientUpload::Dense(vec![3.0, 0.0, 0.0]),
        ];
        let up = server_round(&mut s, u, &mut w, 0.5);
        assert_eq!(w, vec![0.0, 1.0, 0.5]);
        assert!(matches!(up, RoundUpdate::Dense(_)));
        assert_eq!(up.payload_bytes(), 12);
        assert_eq!(up.nnz(), 3);
    }

    #[test]
    fn momentum_accumulates() {
        let mut s = UncompressedServer::new(1, 0.5);
        let mut w = vec![0.0f32];
        for _ in 0..3 {
            server_round(&mut s, vec![ClientUpload::Dense(vec![1.0])], &mut w, 1.0);
        }
        // updates: 1, 1.5, 1.75 => w = -4.25
        assert!((w[0] + 4.25).abs() < 1e-6);
    }
}
