//! Uncompressed distributed SGD — the accuracy ceiling baseline.
//!
//! Clients upload dense gradients; the server averages, applies global
//! momentum, and takes a dense step. "Compression" for this method in
//! the paper's figures comes from simply training for fewer epochs; the
//! experiment drivers sweep `rounds` for that.

use anyhow::Result;

use crate::compression::{ClientResult, ClientUpload, RoundUpdate, Strategy};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_client_grad, Batch};
use crate::runtime::Tensor;

pub struct Uncompressed {
    dim: usize,
    rho_g: f32,
    momentum: Vec<f32>,
}

impl Uncompressed {
    pub fn new(dim: usize, rho_g: f32) -> Self {
        Uncompressed { dim, rho_g, momentum: vec![0f32; dim] }
    }
}

impl Strategy for Uncompressed {
    fn name(&self) -> &'static str {
        "uncompressed"
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        _client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let exe = artifacts.executable("client_grad")?;
        let (loss, grad) = run_client_grad(&exe, w, batch)?;
        Ok(ClientResult { loss, upload: ClientUpload::Dense(grad) })
    }

    fn server_round(
        &mut self,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        lr: f32,
    ) -> Result<RoundUpdate> {
        let count = uploads.len().max(1) as f32;
        let mut mean = vec![0f32; self.dim];
        for u in uploads {
            match u {
                ClientUpload::Dense(g) => {
                    for (m, &gi) in mean.iter_mut().zip(&g) {
                        *m += gi / count;
                    }
                }
                _ => anyhow::bail!("uncompressed expects dense uploads"),
            }
        }
        if self.rho_g > 0.0 {
            for (m, &g) in self.momentum.iter_mut().zip(&mean) {
                *m = self.rho_g * *m + g;
            }
            for (wi, &m) in w.iter_mut().zip(&self.momentum) {
                *wi -= lr * m;
            }
        } else {
            for (wi, &g) in w.iter_mut().zip(&mean) {
                *wi -= lr * g;
            }
        }
        Ok(RoundUpdate::Dense)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_sgd_step() {
        let mut s = Uncompressed::new(3, 0.0);
        let mut w = vec![1.0f32; 3];
        let u = vec![
            ClientUpload::Dense(vec![1.0, 0.0, 2.0]),
            ClientUpload::Dense(vec![3.0, 0.0, 0.0]),
        ];
        let up = s.server_round(u, &mut w, 0.5).unwrap();
        assert_eq!(w, vec![0.0, 1.0, 0.5]);
        assert!(matches!(up, RoundUpdate::Dense));
        assert_eq!(up.download_bytes(3), 12);
    }

    #[test]
    fn momentum_accumulates() {
        let mut s = Uncompressed::new(1, 0.5);
        let mut w = vec![0.0f32];
        for _ in 0..3 {
            s.server_round(vec![ClientUpload::Dense(vec![1.0])], &mut w, 1.0).unwrap();
        }
        // updates: 1, 1.5, 1.75 => w = -4.25
        assert!((w[0] + 4.25).abs() < 1e-6);
    }
}
