//! FetchSGD (paper Algorithm 1): the contribution.
//!
//! Clients upload `S(g_i)` (computed *inside* the AOT HLO graph by the
//! Pallas kernel); the server keeps a momentum sketch `S_u` and an error
//! accumulation sketch `S_e` and extracts a k-sparse model update per
//! round:
//!
//! ```text
//! S^t   = (1/W) Σ S(g_i)
//! S_u   = ρ·S_u + S^t
//! S_e  += η·S_u
//! Δ     = Top-k(U(S_e))
//! S_e   ← zero-out(S_e, Δ)        (paper §5; or exact subtract)
//! w    -= Δ
//! ```
//!
//! Momentum factor masking (Lin et al. 2017, used by the paper for all
//! methods) zeroes the momentum signal at Δ's coordinates — in sketch
//! space, by zeroing the cells of `S_u` that `S(Δ)` touches.
//!
//! Split per the `compression` module contract: [`FetchSgdClient`] is
//! the stateless per-client map (runs on the engine's worker pool);
//! [`FetchSgdServer`] consumes the round's merged sketch `S^t` — the
//! `(1/W) Σ S(g_i)` fan-in happens incrementally in the engine's shard
//! accumulators, which is exactly the linearity the paper's aggregator
//! exploits.

use anyhow::{Context, Result};

use crate::compression::aggregate::RoundAccum;
use crate::compression::{
    ClientCompute, ClientResult, ClientUpload, RoundUpdate, ServerAggregator, UploadSpec,
};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_client_step, Batch};
use crate::runtime::Tensor;
use crate::sketch::count_sketch::CountSketch;
use crate::sketch::sliding::{make_accumulator, ErrorAccumulator};

/// Error-feedback update rule (§5 empirical note).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorUpdate {
    /// Zero out the sketch cells touched by S(Δ) — what the paper runs.
    ZeroOut,
    /// Exact Algorithm-1 subtraction S_e -= S(Δ).
    Subtract,
}

/// Client half: execute the fused grad+sketch artifact for one client.
pub struct FetchSgdClient {
    rows: usize,
    cols: usize,
    seed: u64,
}

impl FetchSgdClient {
    pub fn new(rows: usize, cols: usize, seed: u64) -> Self {
        FetchSgdClient { rows, cols, seed }
    }
}

impl ClientCompute for FetchSgdClient {
    fn name(&self) -> &'static str {
        "fetchsgd"
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        _client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let exe = artifacts.executable(&TaskArtifacts::client_step_kind(self.cols))?;
        let (loss, sketch) = run_client_step(&exe, w, batch, self.rows, self.cols, self.seed)?;
        Ok(ClientResult { loss, upload: ClientUpload::Sketch(sketch) })
    }
}

/// Server half: sketch-space momentum + error feedback + top-k extract.
pub struct FetchSgdServer {
    rows: usize,
    cols: usize,
    seed: u64,
    dim: usize,
    k: usize,
    rho: f32,
    error_update: ErrorUpdate,
    masking: bool,
    momentum: CountSketch,
    error: Box<dyn ErrorAccumulator>,
}

impl FetchSgdServer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rows: usize,
        cols: usize,
        seed: u64,
        dim: usize,
        k: usize,
        rho: f32,
        error_update: ErrorUpdate,
        masking: bool,
        error_window: &str,
    ) -> Result<Self> {
        let momentum = CountSketch::zeros(rows, cols, dim, seed)?;
        let error = make_accumulator(error_window, rows, cols, dim, seed)
            .context("building error accumulator")?;
        Ok(FetchSgdServer {
            rows,
            cols,
            seed,
            dim,
            k,
            rho,
            error_update,
            masking,
            momentum,
            error,
        })
    }

    pub fn sketch_cells(&self) -> usize {
        self.rows * self.cols
    }
}

impl ServerAggregator for FetchSgdServer {
    fn name(&self) -> &'static str {
        "fetchsgd"
    }

    fn begin_round(&mut self, client_sizes: &[f32]) -> Vec<f32> {
        // S^t = (1/W) Σ S(g_i) — uniform mean, by sketch linearity.
        let w = client_sizes.len().max(1) as f32;
        vec![1.0 / w; client_sizes.len()]
    }

    fn upload_spec(&self) -> UploadSpec {
        UploadSpec::Sketch { rows: self.rows, cols: self.cols, dim: self.dim, seed: self.seed }
    }

    fn finish(&mut self, merged: &RoundAccum, lr: f32) -> Result<RoundUpdate> {
        let round = merged.as_sketch()?;
        // Momentum in sketch space.
        self.momentum.scale(self.rho);
        self.momentum.add_scaled(round, 1.0);
        // Error feedback in sketch space.
        self.error.add_scaled(&self.momentum, lr);
        // Extract Δ and apply the error update rule.
        let delta = self.error.top_k(self.k);
        match self.error_update {
            ErrorUpdate::ZeroOut => self.error.zero_out(&delta),
            ErrorUpdate::Subtract => self.error.subtract(&delta),
        }
        if self.masking {
            // Momentum factor masking, sketch-space analog.
            self.momentum.zero_out_sparse(&delta);
        }
        self.error.advance();
        // The broadcast Δ; the caller applies w -= Δ.
        Ok(RoundUpdate::Sparse(delta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::aggregate::run_server_round;
    use crate::sketch::CountSketch;

    /// Uniform-size shim over [`run_server_round`] (no PJRT needed).
    fn server_round(
        strat: &mut FetchSgdServer,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        lr: f32,
    ) -> RoundUpdate {
        let sizes = vec![1.0f32; uploads.len()];
        run_server_round(strat, &sizes, uploads, w, lr).unwrap()
    }

    /// Drive the server side with hand-built sketches (no PJRT needed):
    /// a persistent heavy gradient coordinate must end up dominating the
    /// extracted updates.
    #[test]
    fn server_extracts_persistent_signal() {
        let (rows, cols, seed, d, k) = (5, 512, 42, 2000, 4);
        let mut strat =
            FetchSgdServer::new(rows, cols, seed, d, k, 0.9, ErrorUpdate::ZeroOut, true, "vanilla")
                .unwrap();
        let mut w = vec![0f32; d];
        let mut total_update_at_7 = 0.0f32;
        for _ in 0..10 {
            // Three clients, all with gradient mass at coordinate 7.
            let uploads: Vec<ClientUpload> = (0..3)
                .map(|_| {
                    let mut g = vec![0f32; d];
                    g[7] = 1.0;
                    g[100] = 0.01;
                    ClientUpload::Sketch(CountSketch::encode(rows, cols, seed, &g).unwrap())
                })
                .collect();
            server_round(&mut strat, uploads, &mut w, 0.1);
            total_update_at_7 = -w[7];
        }
        assert!(total_update_at_7 > 0.1, "coordinate 7 should be repeatedly extracted");
        // other coordinates barely move
        let others: f32 = w.iter().enumerate().filter(|(i, _)| *i != 7).map(|(_, &v)| v.abs()).sum();
        assert!(others < total_update_at_7, "others {others} vs w7 {total_update_at_7}");
    }

    #[test]
    fn momentum_accelerates_persistent_direction() {
        let (rows, cols, seed, d, k) = (5, 512, 7, 500, 2);
        let run = |rho: f32| {
            let mut strat = FetchSgdServer::new(
                rows, cols, seed, d, k, rho, ErrorUpdate::ZeroOut, false, "vanilla",
            )
            .unwrap();
            let mut w = vec![0f32; d];
            for _ in 0..8 {
                let mut g = vec![0f32; d];
                g[3] = 1.0;
                let u =
                    vec![ClientUpload::Sketch(CountSketch::encode(rows, cols, seed, &g).unwrap())];
                server_round(&mut strat, u, &mut w, 0.1);
            }
            -w[3]
        };
        let no_mom = run(0.0);
        let with_mom = run(0.9);
        assert!(
            with_mom > no_mom * 1.5,
            "momentum should amplify: {with_mom} vs {no_mom}"
        );
    }

    #[test]
    fn subtract_and_zero_out_both_extract_signal() {
        for update in [ErrorUpdate::ZeroOut, ErrorUpdate::Subtract] {
            let (rows, cols, seed, d, k) = (5, 512, 3, 300, 1);
            let mut strat =
                FetchSgdServer::new(rows, cols, seed, d, k, 0.0, update, false, "vanilla").unwrap();
            let mut w = vec![0f32; d];
            let mut g = vec![0f32; d];
            g[42] = 2.0;
            let u = vec![ClientUpload::Sketch(CountSketch::encode(rows, cols, seed, &g).unwrap())];
            let up = server_round(&mut strat, u, &mut w, 1.0);
            match up {
                RoundUpdate::Sparse(sv) => assert_eq!(sv.idx, vec![42]),
                _ => panic!("expected sparse update"),
            }
            assert!(w[42] < -1.5, "w[42]={}", w[42]);
        }
    }

    #[test]
    fn error_accumulation_recovers_subthreshold_signal() {
        // A coordinate too weak to win top-k in one round must
        // accumulate in S_e and eventually be extracted.
        let (rows, cols, seed, d) = (5, 1024, 11, 1000);
        let mut strat =
            FetchSgdServer::new(rows, cols, seed, d, 1, 0.0, ErrorUpdate::ZeroOut, false, "vanilla")
                .unwrap();
        let mut w = vec![0f32; d];
        let mut extracted_weak = false;
        for t in 0..12 {
            let mut g = vec![0f32; d];
            g[5] = 0.3; // weak persistent signal
            g[800 + t] = 1.0; // strong one-shot signal at varying coords
            let u = vec![ClientUpload::Sketch(CountSketch::encode(rows, cols, seed, &g).unwrap())];
            let up = server_round(&mut strat, u, &mut w, 1.0);
            if let RoundUpdate::Sparse(sv) = up {
                if sv.idx.contains(&5) {
                    extracted_weak = true;
                }
            }
        }
        assert!(extracted_weak, "error feedback should eventually surface coord 5");
    }

    #[test]
    fn sliding_window_accumulator_variant_runs() {
        let mut strat =
            FetchSgdServer::new(3, 256, 5, 200, 2, 0.9, ErrorUpdate::ZeroOut, true, "ring:4")
                .unwrap();
        let mut w = vec![0f32; 200];
        for _ in 0..5 {
            let mut g = vec![0f32; 200];
            g[9] = 1.0;
            let u = vec![ClientUpload::Sketch(CountSketch::encode(3, 256, 5, &g).unwrap())];
            server_round(&mut strat, u, &mut w, 0.5);
        }
        assert!(w[9] < 0.0);
    }
}
