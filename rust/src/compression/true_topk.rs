//! True top-k (paper Appendix A.3, Figure 10): the idealized method
//! FetchSGD approximates.
//!
//! Clients upload *full* gradients; the server averages them exactly,
//! carries dense momentum and a dense error accumulation vector, and
//! updates the model with only the k highest-magnitude elements of the
//! accumulated error, keeping the remainder for later rounds. With
//! momentum factor masking, exactly as §5 runs it. This is a diagnostic
//! upper bound: FetchSGD = true top-k with the dense vectors replaced by
//! Count Sketches.

use anyhow::Result;

use crate::compression::aggregate::RoundAccum;
use crate::compression::{
    ClientCompute, ClientResult, ClientUpload, RoundUpdate, ServerAggregator, UploadSpec,
};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_client_grad, Batch};
use crate::runtime::Tensor;
use crate::sketch::topk::{top_k_indices, SparseVec};

/// Client half: plain dense gradient upload, shared shape with
/// `uncompressed` but kept as its own type so `name()` reports the
/// strategy driving the round.
pub struct DenseGradClient {
    name: &'static str,
}

impl DenseGradClient {
    pub fn new(name: &'static str) -> Self {
        DenseGradClient { name }
    }
}

impl ClientCompute for DenseGradClient {
    fn name(&self) -> &'static str {
        self.name
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        _client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let exe = artifacts.executable("client_grad")?;
        let (loss, grad) = run_client_grad(&exe, w, batch)?;
        Ok(ClientResult { loss, upload: ClientUpload::Dense(grad) })
    }
}

/// Server half: dense momentum + error feedback, exact top-k extract.
pub struct TrueTopKServer {
    dim: usize,
    k: usize,
    rho: f32,
    masking: bool,
    momentum: Vec<f32>,
    error: Vec<f32>,
}

impl TrueTopKServer {
    pub fn new(dim: usize, k: usize, rho: f32, masking: bool) -> Self {
        TrueTopKServer {
            dim,
            k,
            rho,
            masking,
            momentum: vec![0f32; dim],
            error: vec![0f32; dim],
        }
    }
}

impl ServerAggregator for TrueTopKServer {
    fn name(&self) -> &'static str {
        "true_topk"
    }

    fn begin_round(&mut self, client_sizes: &[f32]) -> Vec<f32> {
        let w = client_sizes.len().max(1) as f32;
        vec![1.0 / w; client_sizes.len()]
    }

    fn upload_spec(&self) -> UploadSpec {
        UploadSpec::Dense { dim: self.dim }
    }

    fn finish(&mut self, merged: &RoundAccum, lr: f32) -> Result<RoundUpdate> {
        let mean = merged.as_dense()?;
        // Dense momentum + error feedback — the exact (unsketched)
        // counterpart of FetchSGD's server update.
        for (m, &g) in self.momentum.iter_mut().zip(mean) {
            *m = self.rho * *m + g;
        }
        for (e, &m) in self.error.iter_mut().zip(&self.momentum) {
            *e += lr * m;
        }
        let idx = top_k_indices(&self.error, self.k);
        let mut pairs = Vec::with_capacity(idx.len());
        for &i in &idx {
            pairs.push((i, self.error[i as usize]));
            self.error[i as usize] = 0.0; // keep the rest accumulated
            if self.masking {
                self.momentum[i as usize] = 0.0;
            }
        }
        let delta = SparseVec::from_pairs(self.dim, pairs);
        Ok(RoundUpdate::Sparse(delta))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::aggregate::run_server_round;

    fn server_round(
        s: &mut TrueTopKServer,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        lr: f32,
    ) -> RoundUpdate {
        let sizes = vec![1.0f32; uploads.len()];
        run_server_round(s, &sizes, uploads, w, lr).unwrap()
    }

    #[test]
    fn extracts_exact_topk_and_keeps_residual() {
        let mut s = TrueTopKServer::new(5, 1, 0.0, false);
        let mut w = vec![0f32; 5];
        let u = vec![ClientUpload::Dense(vec![0.1, 0.5, 0.2, 0.0, 0.3])];
        let up = server_round(&mut s, u, &mut w, 1.0);
        match up {
            RoundUpdate::Sparse(sv) => {
                assert_eq!(sv.idx, vec![1]);
                assert!((sv.val[0] - 0.5).abs() < 1e-6);
            }
            _ => panic!(),
        }
        assert_eq!(s.error[1], 0.0);
        assert!((s.error[4] - 0.3).abs() < 1e-6, "residual kept");
        // second round with zero grads: residual 0.3 should win now
        let u = vec![ClientUpload::Dense(vec![0.0; 5])];
        let up = server_round(&mut s, u, &mut w, 1.0);
        match up {
            RoundUpdate::Sparse(sv) => assert_eq!(sv.idx, vec![4]),
            _ => panic!(),
        }
    }

    #[test]
    fn masking_zeroes_momentum_at_extracted() {
        let mut s = TrueTopKServer::new(3, 1, 0.9, true);
        let mut w = vec![0f32; 3];
        let u = vec![ClientUpload::Dense(vec![1.0, 0.0, 0.0])];
        server_round(&mut s, u, &mut w, 1.0);
        assert_eq!(s.momentum[0], 0.0);
    }
}
