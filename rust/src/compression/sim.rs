//! Simulated clients and dataset: drive the round engine with no PJRT
//! backend and no AOT artifacts.
//!
//! Used by `benches/bench_round.rs` (single- vs multi-thread round
//! throughput) and `rust/tests/parallel_determinism.rs`. A sim client
//! synthesizes a deterministic pseudo-gradient from
//! `(client id, round seed)` — heavy planted coordinates over Gaussian
//! noise, the regime FetchSGD targets — and uploads it in the
//! strategy's wire format. Every value is a pure function of the seeds,
//! so runs are bitwise reproducible at any parallelism.
//!
//! The round seed travels to the client through the [`Batch`] the
//! dataset hands the engine ([`SimDataset`] packs it into an i32 tensor;
//! [`batch_round_seed`] unpacks it), mirroring how real datasets
//! decorrelate batches across rounds.

use anyhow::Result;

use crate::compression::{ClientCompute, ClientResult, ClientUpload};
use crate::data::FedDataset;
use crate::runtime::artifact::{DataSpec, SketchSpec, TaskArtifacts, TaskManifest};
use crate::runtime::exec::Batch;
use crate::runtime::Tensor;
use crate::sketch::CountSketch;
use crate::util::rng::{derive_seed, Rng};

/// Deterministic synthetic gradient for `(client, round_seed)`:
/// `heavy` planted coordinates of magnitude ~2 over 0.05-sigma noise.
pub fn synth_grad(dim: usize, heavy: usize, client: usize, round_seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(derive_seed(round_seed ^ 0x51D_C0DE, client as u64));
    let mut g: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() as f32 * 0.05).collect();
    for j in 0..heavy {
        let at = (client.wrapping_mul(31).wrapping_add(j.wrapping_mul(97))) % dim;
        g[at] += if j % 2 == 0 { 2.0 } else { -2.0 };
    }
    g
}

fn sim_loss(g: &[f32]) -> f32 {
    // Sequential f32 reduction: deterministic, order-independent of
    // thread count because it happens inside one client's compute.
    let mut s = 0f32;
    for &x in g {
        s += x.abs();
    }
    s / g.len().max(1) as f32
}

/// Unpack the round seed a [`SimDataset`] batch carries.
pub fn batch_round_seed(batch: &Batch) -> u64 {
    match &batch.x {
        Tensor::I32 { data, .. } if data.len() == 2 => {
            ((data[1] as u32 as u64) << 32) | (data[0] as u32 as u64)
        }
        _ => panic!("batch does not come from a SimDataset"),
    }
}

/// Minimal federated dataset whose batches only carry the round seed.
pub struct SimDataset {
    pub num_clients: usize,
}

impl FedDataset for SimDataset {
    fn num_clients(&self) -> usize {
        self.num_clients
    }

    fn client_size(&self, client: usize) -> usize {
        1 + client % 5
    }

    fn client_batch(&self, _client: usize, round_seed: u64) -> Batch {
        let lo = round_seed as u32 as i32;
        let hi = (round_seed >> 32) as u32 as i32;
        Batch {
            x: Tensor::i32(vec![lo, hi], &[2]),
            y: Tensor::i32(vec![0], &[1]),
            mask: Tensor::f32(vec![1.0], &[1]),
        }
    }

    fn client_batches_stacked(
        &self,
        client: usize,
        _k: usize,
        round_seed: u64,
    ) -> (Tensor, Tensor, Tensor) {
        let b = self.client_batch(client, round_seed);
        (b.x, b.y, b.mask)
    }

    fn num_eval_batches(&self) -> usize {
        0
    }

    fn eval_batch(&self, _idx: usize) -> Batch {
        unreachable!("SimDataset has no eval set")
    }
}

/// A hand-built manifest entry for [`TaskArtifacts::detached`], so sim
/// runs satisfy the engine's artifact parameter without any files.
pub fn sim_manifest(dim: usize, rows: usize, cols: usize, seed: u64) -> TaskManifest {
    TaskManifest {
        name: "sim".into(),
        model: "sim".into(),
        dim,
        batch: 1,
        inputs: Default::default(),
        data: DataSpec::Images { image: [1, 1, 1], classes: 2 },
        init_weights: String::new(),
        artifacts: Default::default(),
        sketch: SketchSpec { rows, seed, cols_options: vec![cols] },
        fedavg_steps: Vec::new(),
    }
}

/// Detached artifacts for a sim run (never executed, only threaded
/// through the engine's signature).
pub fn sim_artifacts(dim: usize, rows: usize, cols: usize, seed: u64) -> Result<TaskArtifacts> {
    TaskArtifacts::detached(sim_manifest(dim, rows, cols, seed))
}

/// FetchSGD-shaped sim client: sketches the synthetic gradient
/// client-side (the CPU-heavy map the engine parallelizes).
pub struct SimSketchClient {
    pub rows: usize,
    pub cols: usize,
    pub seed: u64,
    pub dim: usize,
    pub heavy: usize,
}

impl ClientCompute for SimSketchClient {
    fn name(&self) -> &'static str {
        "sim_fetchsgd"
    }

    fn client_round(
        &self,
        _artifacts: &TaskArtifacts,
        _w: &[f32],
        batch: &Batch,
        client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let g = synth_grad(self.dim, self.heavy, client, batch_round_seed(batch));
        let sketch = CountSketch::encode(self.rows, self.cols, self.seed, &g)?;
        Ok(ClientResult { loss: sim_loss(&g), upload: ClientUpload::Sketch(sketch) })
    }
}

/// Local-top-k-shaped sim client: sparse (k-sparse gradient) uploads —
/// the third wire payload kind, exercised by the wire-mode tests.
pub struct SimTopKClient {
    pub dim: usize,
    pub heavy: usize,
    pub k: usize,
}

impl ClientCompute for SimTopKClient {
    fn name(&self) -> &'static str {
        "sim_local_topk"
    }

    fn client_round(
        &self,
        _artifacts: &TaskArtifacts,
        _w: &[f32],
        batch: &Batch,
        client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let g = synth_grad(self.dim, self.heavy, client, batch_round_seed(batch));
        let sparse = crate::sketch::topk::top_k_sparse(&g, self.k);
        Ok(ClientResult { loss: sim_loss(&g), upload: ClientUpload::Sparse(sparse) })
    }
}

/// Dense-baseline sim client (uncompressed / true top-k shape).
pub struct SimDenseClient {
    pub dim: usize,
    pub heavy: usize,
}

impl ClientCompute for SimDenseClient {
    fn name(&self) -> &'static str {
        "sim_dense"
    }

    fn client_round(
        &self,
        _artifacts: &TaskArtifacts,
        _w: &[f32],
        batch: &Batch,
        client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let g = synth_grad(self.dim, self.heavy, client, batch_round_seed(batch));
        Ok(ClientResult { loss: sim_loss(&g), upload: ClientUpload::Dense(g) })
    }
}

/// Wraps any sim client and deterministically fails a chosen set of
/// client ids — the flaky-client scenario the cohort subsystem's quorum
/// rounds exist for. Failure is a pure function of the client id, so
/// the dropped-slot set (and therefore the surviving membership) is
/// identical at any parallelism.
pub struct SimFlakyClient<C: ClientCompute> {
    pub inner: C,
    /// Client ids whose compute always errors.
    pub fail: std::collections::BTreeSet<usize>,
}

impl<C: ClientCompute> ClientCompute for SimFlakyClient<C> {
    fn name(&self) -> &'static str {
        "sim_flaky"
    }

    fn wants_stacked_batches(&self) -> Option<usize> {
        self.inner.wants_stacked_batches()
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        client: usize,
        stacked: Option<(Tensor, Tensor, Tensor)>,
        lr: f32,
    ) -> Result<ClientResult> {
        if self.fail.contains(&client) {
            anyhow::bail!("sim flaky client {client} refused the round");
        }
        self.inner.client_round(artifacts, w, batch, client, stacked, lr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_grad_is_deterministic_and_seed_sensitive() {
        let a = synth_grad(1000, 4, 7, 99);
        let b = synth_grad(1000, 4, 7, 99);
        assert_eq!(a, b);
        let c = synth_grad(1000, 4, 8, 99);
        assert_ne!(a, c);
        let d = synth_grad(1000, 4, 7, 100);
        assert_ne!(a, d);
    }

    #[test]
    fn round_seed_roundtrips_through_batch() {
        let ds = SimDataset { num_clients: 10 };
        for seed in [0u64, 1, u32::MAX as u64, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            let b = ds.client_batch(3, seed);
            assert_eq!(batch_round_seed(&b), seed);
        }
    }
}
