//! Network-time model: translates byte counts into wallclock estimates.
//!
//! The paper's motivation (§1) is that federated clients sit behind slow
//! (~1 Mbps) and *asymmetric* residential links (§2.2, citing Goga &
//! Teixeira 2012: uploads are far slower than downloads). Compression
//! ratios alone hide this asymmetry; this model turns per-round bytes
//! into per-round seconds so experiments can report *time-to-accuracy*
//! under realistic link profiles.
//!
//! The model is deliberately simple and fully documented: per round,
//! every participant uploads its payload in parallel (the round waits
//! for the slowest, but payloads are equal-sized, so one transfer time)
//! and downloads the broadcast update; a fixed per-round handshake
//! latency covers connection setup. Compute time is not modeled (it is
//! hardware-dependent and orthogonal to the paper's claim).

/// A client link profile.
#[derive(Clone, Copy, Debug)]
pub struct LinkProfile {
    /// Uplink bandwidth, bits per second.
    pub uplink_bps: f64,
    /// Downlink bandwidth, bits per second.
    pub downlink_bps: f64,
    /// Per-round fixed latency (connection + coordination), seconds.
    pub round_latency_s: f64,
}

impl LinkProfile {
    /// The paper's motivating scenario: ~1 Mbps uplink, asymmetric
    /// residential connection (≈8x faster downlink), 300 ms round setup.
    pub fn residential() -> Self {
        LinkProfile { uplink_bps: 1e6, downlink_bps: 8e6, round_latency_s: 0.3 }
    }

    /// A fast-WiFi profile (the favorable case for dense methods).
    pub fn wifi() -> Self {
        LinkProfile { uplink_bps: 20e6, downlink_bps: 100e6, round_latency_s: 0.1 }
    }

    /// Time for one round's communication given per-client payloads.
    pub fn round_seconds(&self, upload_bytes_per_client: u64, download_bytes_per_client: u64) -> f64 {
        let up = upload_bytes_per_client as f64 * 8.0 / self.uplink_bps;
        let down = download_bytes_per_client as f64 * 8.0 / self.downlink_bps;
        self.round_latency_s + up + down
    }
}

/// Accumulated communication-time estimate for a run.
#[derive(Clone, Debug, Default)]
pub struct CommTime {
    pub total_s: f64,
    pub upload_s: f64,
    pub download_s: f64,
    pub latency_s: f64,
}

impl CommTime {
    pub fn record_round(
        &mut self,
        profile: &LinkProfile,
        upload_bytes_per_client: u64,
        download_bytes_per_client: u64,
    ) {
        let up = upload_bytes_per_client as f64 * 8.0 / profile.uplink_bps;
        let down = download_bytes_per_client as f64 * 8.0 / profile.downlink_bps;
        self.upload_s += up;
        self.download_s += down;
        self.latency_s += profile.round_latency_s;
        self.total_s += up + down + profile.round_latency_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residential_is_upload_bound_for_dense_methods() {
        let p = LinkProfile::residential();
        // 6.5M-param model, dense both ways (uncompressed SGD client).
        let bytes = 6_500_000u64 * 4;
        let t = p.round_seconds(bytes, bytes);
        let up_only = bytes as f64 * 8.0 / p.uplink_bps;
        assert!(t > up_only, "total includes download + latency");
        // upload dominates: > 85% of transfer time
        let down_only = bytes as f64 * 8.0 / p.downlink_bps;
        assert!(up_only > 5.0 * down_only);
        // ~208s upload at 1Mbps — matches the paper's "slow connections"
        assert!((up_only - 208.0).abs() < 2.0);
    }

    #[test]
    fn sketch_upload_beats_dense_by_its_compression_ratio() {
        let p = LinkProfile::residential();
        let d = 6_500_000u64;
        let sketch_cells = 5 * 650_000u64; // paper-ish geometry
        let dense = p.round_seconds(d * 4, 0);
        let sketched = p.round_seconds(sketch_cells * 4, 0);
        let ratio = (dense - p.round_latency_s) / (sketched - p.round_latency_s);
        assert!((ratio - 2.0).abs() < 0.01); // d / cells = 2.0
    }

    #[test]
    fn comm_time_accumulates() {
        let p = LinkProfile::wifi();
        let mut ct = CommTime::default();
        for _ in 0..10 {
            ct.record_round(&p, 1_000_000, 100_000);
        }
        assert!((ct.latency_s - 1.0).abs() < 1e-9);
        assert!((ct.upload_s - 10.0 * 8e6 / 20e6).abs() < 1e-9);
        assert!((ct.total_s - (ct.upload_s + ct.download_s + ct.latency_s)).abs() < 1e-9);
    }
}
