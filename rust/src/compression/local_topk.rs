//! Local top-k baseline (gradient sparsification, Lin et al. 2017 style).
//!
//! Each client computes its dense gradient (via the `client_grad`
//! artifact) and uploads only its k largest-magnitude entries. The
//! server averages the sparse uploads (the sum is generally much denser
//! than k — the paper's point about poor download compression), applies
//! optional *global* momentum `ρ_g ∈ {0, 0.9}` (paper §5), and a
//! dense-ish sparse update.
//!
//! Local error accumulation is optional and OFF by default: it requires
//! client state, which the paper argues is infeasible when clients
//! participate once (§2.2); the flag exists for ablations in the regime
//! where clients do re-participate. The per-client error vectors live
//! behind a mutex on the (otherwise stateless, `Send + Sync`) client
//! half, since workers read them concurrently.

use anyhow::Result;
use std::collections::HashMap;
use std::sync::Mutex;

use crate::compression::aggregate::RoundAccum;
use crate::compression::{
    ClientCompute, ClientResult, ClientUpload, RoundUpdate, ServerAggregator, UploadSpec,
};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_client_grad, Batch};
use crate::runtime::Tensor;
use crate::sketch::topk::{top_k_sparse, SparseVec};

/// Client half: dense gradient → top-k sparse upload.
pub struct LocalTopKClient {
    k: usize,
    /// local error accumulation (requires client state; default off).
    local_error: bool,
    /// per-client error vectors, only if local_error (ablation only).
    errors: Mutex<HashMap<usize, Vec<f32>>>,
}

impl LocalTopKClient {
    pub fn new(k: usize, local_error: bool) -> Self {
        LocalTopKClient { k, local_error, errors: Mutex::new(HashMap::new()) }
    }

    /// Record client-side error for the local_error ablation (called
    /// between rounds; client_round itself stays read-only).
    pub fn record_local_error(&self, client: usize, grad_minus_sent: Vec<f32>) {
        if self.local_error {
            self.errors.lock().expect("error map poisoned").insert(client, grad_minus_sent);
        }
    }
}

impl ClientCompute for LocalTopKClient {
    fn name(&self) -> &'static str {
        "local_topk"
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let exe = artifacts.executable("client_grad")?;
        let (loss, mut grad) = run_client_grad(&exe, w, batch)?;
        if self.local_error {
            if let Some(e) = self.errors.lock().expect("error map poisoned").get(&client) {
                for (g, &ev) in grad.iter_mut().zip(e) {
                    *g += ev;
                }
            }
        }
        let sparse = top_k_sparse(&grad, self.k);
        Ok(ClientResult { loss, upload: ClientUpload::Sparse(sparse) })
    }
}

/// Server half: mean of sparse uploads + optional global momentum.
pub struct LocalTopKServer {
    dim: usize,
    /// global (server-side) momentum ρ_g; 0 disables.
    rho_g: f32,
    /// Reserved for the stateful client-side-momentum variant; the
    /// stateless server path intentionally does not mask (see the NOTE
    /// in `finish`).
    #[allow(dead_code)]
    masking: bool,
    momentum: Vec<f32>,
}

impl LocalTopKServer {
    pub fn new(dim: usize, rho_g: f32, masking: bool) -> Self {
        LocalTopKServer { dim, rho_g, masking, momentum: vec![0f32; dim] }
    }

    #[cfg(test)]
    fn momentum(&self) -> &[f32] {
        &self.momentum
    }
}

impl ServerAggregator for LocalTopKServer {
    fn name(&self) -> &'static str {
        "local_topk"
    }

    fn begin_round(&mut self, client_sizes: &[f32]) -> Vec<f32> {
        let w = client_sizes.len().max(1) as f32;
        vec![1.0 / w; client_sizes.len()]
    }

    fn upload_spec(&self) -> UploadSpec {
        UploadSpec::Dense { dim: self.dim }
    }

    fn finish(&mut self, merged: &RoundAccum, lr: f32) -> Result<RoundUpdate> {
        let mean = merged.as_dense()?;
        // Global momentum on the aggregated sparse update.
        let update: &[f32] = if self.rho_g > 0.0 {
            for (m, &g) in self.momentum.iter_mut().zip(mean) {
                *m = self.rho_g * *m + g;
            }
            &self.momentum
        } else {
            mean
        };
        // The broadcast update: non-zero coords of `update` scaled by lr.
        let mut pairs = Vec::new();
        for (i, &v) in update.iter().enumerate() {
            if v != 0.0 {
                pairs.push((i as u32, lr * v));
            }
        }
        let sparse = SparseVec::from_pairs(self.dim, pairs);
        // NOTE: momentum factor masking is NOT applied to the *global*
        // momentum here. Unlike FetchSGD/true-top-k — where the server
        // extracts a k-sparse subset of an accumulated signal and
        // masking prevents the extracted part from re-applying — the
        // local-top-k server applies its entire aggregated update each
        // round, so masking the update's support would zero the whole
        // momentum buffer and silently turn ρ_g=0.9 into ρ_g=0. The
        // paper's ρ_g sweep (Figure 5: momentum *hurts* local top-k on
        // PersonaChat) only makes sense with momentum intact. The
        // `masking` flag is kept for the client-side (local-momentum)
        // variant, which we do not run for stateless clients.
        Ok(RoundUpdate::Sparse(sparse))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::aggregate::run_server_round;

    fn server_round(
        s: &mut LocalTopKServer,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        lr: f32,
    ) -> RoundUpdate {
        let sizes = vec![1.0f32; uploads.len()];
        run_server_round(s, &sizes, uploads, w, lr).unwrap()
    }

    #[test]
    fn server_averages_sparse_uploads() {
        let mut s = LocalTopKServer::new(10, 0.0, false);
        let mut w = vec![0f32; 10];
        let u1 = ClientUpload::Sparse(SparseVec::from_pairs(10, vec![(1, 2.0), (3, -4.0)]));
        let u2 = ClientUpload::Sparse(SparseVec::from_pairs(10, vec![(1, 2.0), (5, 6.0)]));
        let up = server_round(&mut s, vec![u1, u2], &mut w, 0.5);
        // mean: idx1=2.0, idx3=-2.0, idx5=3.0; update = lr*mean
        assert!((w[1] - -1.0).abs() < 1e-6);
        assert!((w[3] - 1.0).abs() < 1e-6);
        assert!((w[5] - -1.5).abs() < 1e-6);
        match up {
            RoundUpdate::Sparse(sv) => assert_eq!(sv.nnz(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn union_of_disjoint_topk_is_denser_than_k() {
        // The paper's observation: summing sparse gradients from clients
        // with very different data gives a nearly dense update.
        let mut s = LocalTopKServer::new(100, 0.0, false);
        let mut w = vec![0f32; 100];
        let uploads: Vec<ClientUpload> = (0..10)
            .map(|c| {
                let pairs: Vec<(u32, f32)> =
                    (0..5).map(|j| ((c * 10 + j) as u32, 1.0)).collect();
                ClientUpload::Sparse(SparseVec::from_pairs(100, pairs))
            })
            .collect();
        let up = server_round(&mut s, uploads, &mut w, 1.0);
        assert_eq!(up.nnz(), 50, "disjoint supports union");
    }

    #[test]
    fn global_momentum_persists_and_amplifies() {
        // Regression test: masking must NOT nullify global momentum (the
        // update support covers the whole momentum support, so masking
        // there would silently disable ρ_g — see the NOTE in `finish`).
        let mut s = LocalTopKServer::new(4, 0.9, true);
        let mut w = vec![0f32; 4];
        for _ in 0..3 {
            let u = ClientUpload::Sparse(SparseVec::from_pairs(4, vec![(2, 1.0)]));
            server_round(&mut s, vec![u], &mut w, 1.0);
        }
        assert!(s.momentum()[2] > 1.5, "momentum should accumulate: {}", s.momentum()[2]);
        // momentum path moved w further than 3 plain steps would
        assert!(w[2] < -3.0, "w[2]={}", w[2]);
    }

    #[test]
    fn local_error_map_is_thread_safe_and_gated() {
        let c = LocalTopKClient::new(3, false);
        c.record_local_error(0, vec![1.0]);
        assert!(c.errors.lock().unwrap().is_empty(), "disabled flag must not store state");
        let c = LocalTopKClient::new(3, true);
        c.record_local_error(0, vec![1.0]);
        assert_eq!(c.errors.lock().unwrap().len(), 1);
    }
}
