//! Local top-k baseline (gradient sparsification, Lin et al. 2017 style).
//!
//! Each client computes its dense gradient (via the `client_grad`
//! artifact) and uploads only its k largest-magnitude entries. The
//! server averages the sparse uploads (the sum is generally much denser
//! than k — the paper's point about poor download compression), applies
//! optional *global* momentum `ρ_g ∈ {0, 0.9}` (paper §5), momentum
//! factor masking, and a dense-ish sparse update.
//!
//! Local error accumulation is optional and OFF by default: it requires
//! client state, which the paper argues is infeasible when clients
//! participate once (§2.2); the flag exists for ablations in the regime
//! where clients do re-participate.

use anyhow::Result;
use std::collections::HashMap;

use crate::compression::{ClientResult, ClientUpload, RoundUpdate, Strategy};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_client_grad, Batch};
use crate::runtime::Tensor;
use crate::sketch::topk::{top_k_sparse, SparseVec};

pub struct LocalTopK {
    dim: usize,
    k: usize,
    /// global (server-side) momentum ρ_g; 0 disables.
    rho_g: f32,
    /// Reserved for the stateful client-side-momentum variant; the
    /// stateless server path intentionally does not mask (see the NOTE
    /// in `server_round`).
    #[allow(dead_code)]
    masking: bool,
    /// local error accumulation (requires client state; default off).
    local_error: bool,
    momentum: Vec<f32>,
    /// per-client error vectors, only if local_error
    errors: HashMap<usize, Vec<f32>>,
}

impl LocalTopK {
    pub fn new(dim: usize, k: usize, rho_g: f32, masking: bool, local_error: bool) -> Self {
        LocalTopK {
            dim,
            k,
            rho_g,
            masking,
            local_error,
            momentum: vec![0f32; dim],
            errors: HashMap::new(),
        }
    }
}

impl Strategy for LocalTopK {
    fn name(&self) -> &'static str {
        "local_topk"
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        batch: &Batch,
        client: usize,
        _stacked: Option<(Tensor, Tensor, Tensor)>,
        _lr: f32,
    ) -> Result<ClientResult> {
        let exe = artifacts.executable("client_grad")?;
        let (loss, mut grad) = run_client_grad(&exe, w, batch)?;
        if self.local_error {
            if let Some(e) = self.errors.get(&client) {
                for (g, &ev) in grad.iter_mut().zip(e) {
                    *g += ev;
                }
            }
        }
        let sparse = top_k_sparse(&grad, self.k);
        Ok(ClientResult { loss, upload: ClientUpload::Sparse(sparse) })
    }

    fn server_round(
        &mut self,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        lr: f32,
    ) -> Result<RoundUpdate> {
        let count = uploads.len().max(1) as f32;
        let mut mean = vec![0f32; self.dim];
        for u in uploads {
            match u {
                ClientUpload::Sparse(sv) => sv.add_into(&mut mean, 1.0 / count),
                _ => anyhow::bail!("local_topk expects sparse uploads"),
            }
        }
        // Global momentum on the aggregated sparse update.
        let update: Vec<f32> = if self.rho_g > 0.0 {
            for (m, &g) in self.momentum.iter_mut().zip(&mean) {
                *m = self.rho_g * *m + g;
            }
            self.momentum.clone()
        } else {
            mean
        };
        // The broadcast update: non-zero coords of `update` scaled by lr.
        let mut pairs = Vec::new();
        for (i, &v) in update.iter().enumerate() {
            if v != 0.0 {
                pairs.push((i as u32, lr * v));
            }
        }
        let sparse = SparseVec::from_pairs(self.dim, pairs);
        sparse.add_into(w, -1.0);
        // NOTE: momentum factor masking is NOT applied to the *global*
        // momentum here. Unlike FetchSGD/true-top-k — where the server
        // extracts a k-sparse subset of an accumulated signal and
        // masking prevents the extracted part from re-applying — the
        // local-top-k server applies its entire aggregated update each
        // round, so masking the update's support would zero the whole
        // momentum buffer and silently turn ρ_g=0.9 into ρ_g=0. The
        // paper's ρ_g sweep (Figure 5: momentum *hurts* local top-k on
        // PersonaChat) only makes sense with momentum intact. The
        // `masking` flag is kept for the client-side (local-momentum)
        // variant, which we do not run for stateless clients.
        Ok(RoundUpdate::Sparse(sparse))
    }
}

/// Record client-side error for the local_error ablation (called by the
/// trainer after the round so the strategy remains `&self` in
/// client_round).
impl LocalTopK {
    pub fn record_local_error(&mut self, client: usize, grad_minus_sent: Vec<f32>) {
        if self.local_error {
            self.errors.insert(client, grad_minus_sent);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_averages_sparse_uploads() {
        let mut s = LocalTopK::new(10, 2, 0.0, false, false);
        let mut w = vec![0f32; 10];
        let u1 = ClientUpload::Sparse(SparseVec::from_pairs(10, vec![(1, 2.0), (3, -4.0)]));
        let u2 = ClientUpload::Sparse(SparseVec::from_pairs(10, vec![(1, 2.0), (5, 6.0)]));
        let up = s.server_round(vec![u1, u2], &mut w, 0.5).unwrap();
        // mean: idx1=2.0, idx3=-2.0, idx5=3.0; update = lr*mean
        assert!((w[1] - -1.0).abs() < 1e-6);
        assert!((w[3] - 1.0).abs() < 1e-6);
        assert!((w[5] - -1.5).abs() < 1e-6);
        match up {
            RoundUpdate::Sparse(sv) => assert_eq!(sv.nnz(), 3),
            _ => panic!(),
        }
    }

    #[test]
    fn union_of_disjoint_topk_is_denser_than_k() {
        // The paper's observation: summing sparse gradients from clients
        // with very different data gives a nearly dense update.
        let mut s = LocalTopK::new(100, 5, 0.0, false, false);
        let mut w = vec![0f32; 100];
        let uploads: Vec<ClientUpload> = (0..10)
            .map(|c| {
                let pairs: Vec<(u32, f32)> =
                    (0..5).map(|j| ((c * 10 + j) as u32, 1.0)).collect();
                ClientUpload::Sparse(SparseVec::from_pairs(100, pairs))
            })
            .collect();
        let up = s.server_round(uploads, &mut w, 1.0).unwrap();
        assert_eq!(up.nnz(100), 50, "disjoint supports union");
    }

    #[test]
    fn global_momentum_persists_and_amplifies() {
        // Regression test: masking must NOT nullify global momentum (the
        // update support covers the whole momentum support, so masking
        // there would silently disable ρ_g — see server_round NOTE).
        let mut s = LocalTopK::new(4, 1, 0.9, true, false);
        let mut w = vec![0f32; 4];
        for _ in 0..3 {
            let u = ClientUpload::Sparse(SparseVec::from_pairs(4, vec![(2, 1.0)]));
            s.server_round(vec![u], &mut w, 1.0).unwrap();
        }
        assert!(s.momentum[2] > 1.5, "momentum should accumulate: {}", s.momentum[2]);
        // momentum path moved w further than 3 plain steps would
        assert!(w[2] < -3.0, "w[2]={}", w[2]);
    }
}
