//! The round pipeline: incremental, shardable, absorb-on-arrival upload
//! aggregation — the *single* fan-in implementation shared by the
//! in-process round engine and the transport server.
//!
//! Every strategy's fan-in is a weighted sum `Σ_i λ_i · upload_i`
//! (see `compression` module docs), so the merge machinery lives here
//! once, strategy-agnostic. [`RoundPipeline`] owns the three pieces:
//!
//! - **the shard layout** ([`shard_count`] / [`shard_of`], capped at
//!   [`MAX_SHARDS`]) — a pure function of the cohort, never of thread
//!   count or arrival order;
//! - **the scratch-accumulator pool** — shard [`RoundAccum`]s are reset
//!   in place and reused across rounds instead of re-allocating up to
//!   `MAX_SHARDS` tables a round;
//! - **absorb-on-arrival** — [`RoundPipeline::begin`] hands out a
//!   [`RoundInFlight`] whose `offer`/`offer_frame` fold each upload into
//!   its shard the moment it completes (parking early arrivals until
//!   their in-shard turn), and [`RoundPipeline::finish`] runs the
//!   **row-strip-parallel** shard reduction.
//!
//! Absorption is **shard-parallel**: every shard owns its accumulator
//! and parking buffer behind its own `Mutex`, with a thin lock-free
//! layer (atomic per-slot claim bits + an absorbed counter) on top, so
//! all of [`RoundInFlight`]'s offer methods take `&self` and concurrent
//! workers folding into different shards never contend. Wire frames are
//! parsed and validated ([`UploadSpec::validate_frame`]) *before* any
//! lock is taken, so a corrupt peer is rejected without ever holding
//! round state.
//!
//! Uploads arrive in one of three forms:
//!
//! - [`RoundInFlight::offer`] — an in-memory [`ClientUpload`] (the
//!   in-process engine's default path);
//! - [`RoundInFlight::offer_frame`] — an owned encoded wire frame
//!   (`crate::wire`), decoded *streaming* into the accumulator via
//!   [`RoundAccum::absorb_frame`]. Under the lossless `f32le` codec the
//!   paths perform bit-identical arithmetic in the same order;
//! - [`RoundInFlight::offer_frame_bytes`] — the zero-copy variant:
//!   absorbs straight from a borrowed transport read buffer when the
//!   frame arrives in-shard-order, copying to an owned parking buffer
//!   only for truly-early arrivals;
//! - [`RoundInFlight::offer_chain_frame`] — a *merged* frame from an
//!   aggregator relay covering one whole shard chain (tree
//!   aggregation). Sketches and dense accumulators are linear, so a
//!   relay's λ-weighted partial sum absorbs with weight 1.0 into an
//!   untouched shard and reproduces the per-slot fold bit for bit;
//!   `PipelineOptions::shard_override` pins the layout so flat and tree
//!   drivers agree on which chain holds which slots.
//!
//! Determinism contract: for a fixed *shard layout*, the merged result
//! is bitwise identical no matter how many workers produced the uploads,
//! in what order they arrived, or how many threads reduced the shards,
//! because (a) each shard absorbs its slots in increasing slot order
//! (early arrivals are parked), (b) shards are reduced strictly in shard
//! order, and (c) the reduction's strip partition is a pure function of
//! accumulator geometry — a worker count only changes *which thread*
//! folds a strip, never the per-cell floating-point op order. Per-shard
//! locking does not weaken (a): in-shard order is enforced by each
//! shard's done-counter under that shard's own lock.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};

use crate::util::kernels;

use crate::cohort::RoundMembership;
use crate::compression::{ClientUpload, RoundUpdate, ServerAggregator, UploadSpec};
use crate::sketch::CountSketch;
use crate::trace::{SlotEvent, TraceSink};
use crate::wire::{Body, Frame, F32LE};

/// Upper bound on shard accumulators per round. Bounds both the final
/// fan-in cost and the scratch memory (`MAX_SHARDS` dense vectors /
/// sketch tables), and is deliberately independent of the machine's
/// core count so the reduction tree is machine-invariant.
pub const MAX_SHARDS: usize = 16;

/// Ceiling for the *adaptive* shard layout
/// ([`PipelineOptions::adaptive_shards`]): the controller may grow a
/// round's shard count past [`MAX_SHARDS`] (that bound keeps the
/// *default* layout machine-invariant; the adaptive layout is
/// explicitly allowed to drift), but never past this — the fan-in cost
/// and scratch memory stay bounded however hot contention runs.
pub const ADAPTIVE_MAX_SHARDS: usize = 64;

/// Adaptive controller thresholds, in lock stalls per absorbed upload:
/// above the hot rate the layout doubles (one boost step per round, up
/// to [`ADAPTIVE_MAX_BOOST`] doublings), below the cool rate it halves
/// back toward the default. The hysteresis band between them keeps the
/// layout stable under ordinary jitter.
const ADAPTIVE_HOT_STALL_RATE: f64 = 0.25;
const ADAPTIVE_COOL_STALL_RATE: f64 = 0.05;

/// Max doublings above the default layout: `16 << 2 = 64 =`
/// [`ADAPTIVE_MAX_SHARDS`].
const ADAPTIVE_MAX_BOOST: u32 = 2;

/// Cells per strip when the *dense* shard reduction is parallelized
/// (sketch reductions strip by table row instead). A pure function of
/// nothing — the dense strip partition depends only on the accumulator
/// length, so the reduction tree never varies with worker count.
pub const DENSE_REDUCE_STRIP: usize = 1 << 15;

/// Below this many total cells a parallel reduce costs more in thread
/// spawns than it saves; stay sequential (a pure perf heuristic — the
/// bits are identical either way).
const PARALLEL_REDUCE_MIN_CELLS: usize = 1 << 16;

/// Number of shard accumulators for a cohort of `participants` clients.
pub fn shard_count(participants: usize) -> usize {
    participants.clamp(1, MAX_SHARDS)
}

/// The shard that owns participant slot `slot`. This layout is the
/// *single* source of truth for the whole pipeline: every consumer
/// absorbs a shard's slots in increasing slot order and reduces shards
/// in shard order, so the floating-point reduction tree — and therefore
/// the merged bits — is a pure function of the cohort, never of
/// scheduling or of upload arrival order.
pub fn shard_of(slot: usize, shards: usize) -> usize {
    slot % shards
}

/// Resolve a configured parallelism knob: 0 = all available cores.
pub fn resolve_parallelism(configured: usize) -> usize {
    if configured > 0 {
        configured
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

enum Acc {
    Sketch(CountSketch),
    Dense(Vec<f32>),
}

/// A partial weighted sum of uploads (one shard's scratch, or the
/// whole round's merged result).
pub struct RoundAccum {
    spec: UploadSpec,
    acc: Acc,
    absorbed: usize,
}

impl RoundAccum {
    pub fn new(spec: &UploadSpec) -> Result<RoundAccum> {
        let acc = match spec {
            UploadSpec::Sketch { rows, cols, dim, seed } => {
                Acc::Sketch(CountSketch::zeros(*rows, *cols, *dim, *seed)?)
            }
            UploadSpec::Dense { dim } => Acc::Dense(vec![0f32; *dim]),
        };
        Ok(RoundAccum { spec: spec.clone(), acc, absorbed: 0 })
    }

    /// The upload shape this accumulator was built for.
    pub fn spec(&self) -> &UploadSpec {
        &self.spec
    }

    /// Whether this accumulator can be reused for `spec` (same shape).
    pub fn matches_spec(&self, spec: &UploadSpec) -> bool {
        &self.spec == spec
    }

    /// Zero in place, keeping the allocation — the cross-round reuse
    /// path (don't re-allocate up to 16 accumulators a round).
    pub fn reset(&mut self) {
        match &mut self.acc {
            Acc::Sketch(s) => s.clear_rows(0..s.rows()),
            Acc::Dense(v) => v.fill(0.0),
        }
        self.absorbed = 0;
    }

    /// Number of f32 cells in the accumulator table/vector.
    fn cells(&self) -> usize {
        match &self.acc {
            Acc::Sketch(s) => s.table().len(),
            Acc::Dense(v) => v.len(),
        }
    }

    /// Number of uploads absorbed (across merges).
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// `self += weight * upload`. Consumes the upload — nothing is
    /// buffered.
    pub fn absorb(&mut self, upload: ClientUpload, weight: f32) -> Result<()> {
        match (&mut self.acc, upload) {
            (Acc::Sketch(acc), ClientUpload::Sketch(s)) => {
                if s.rows() != acc.rows()
                    || s.cols() != acc.cols()
                    || s.seed() != acc.seed()
                    || s.dim() != acc.dim()
                {
                    bail!(
                        "upload sketch {}x{} (seed {}, dim {}) incompatible with \
                         aggregator {}x{} (seed {}, dim {})",
                        s.rows(), s.cols(), s.seed(), s.dim(),
                        acc.rows(), acc.cols(), acc.seed(), acc.dim()
                    );
                }
                acc.add_scaled(&s, weight);
            }
            (Acc::Sketch(_), _) => bail!("aggregator expects sketch uploads"),
            (Acc::Dense(acc), ClientUpload::Dense(g)) => {
                if g.len() != acc.len() {
                    bail!("dense upload dim {} != aggregator dim {}", g.len(), acc.len());
                }
                kernels::axpy(acc, &g, weight);
            }
            (Acc::Dense(acc), ClientUpload::Sparse(sv)) => {
                if sv.dim != acc.len() {
                    bail!("sparse upload dim {} != aggregator dim {}", sv.dim, acc.len());
                }
                sv.add_into(acc, weight);
            }
            (Acc::Dense(_), ClientUpload::Sketch(_)) => {
                bail!("aggregator expects dense/sparse uploads, got a sketch")
            }
        }
        self.absorbed += 1;
        Ok(())
    }

    /// `self += weight * decode(frame_bytes)` — parse then
    /// [`RoundAccum::absorb_frame`].
    pub fn absorb_bytes(&mut self, frame_bytes: &[u8], weight: f32) -> Result<()> {
        let frame = Frame::parse(frame_bytes)?;
        self.absorb_frame(&frame, weight)
    }

    /// `self += weight * decode(frame)` without materializing the
    /// upload: values fold straight from the (already length- and
    /// index-validated) frame payload into the accumulator via the
    /// blocked [`crate::wire::Values::axpy_into`] kernel. Shape, seed,
    /// and kind mismatches fail loudly via
    /// [`UploadSpec::validate_frame`]; under `f32le` this performs the
    /// same additions in the same order as [`RoundAccum::absorb`], so
    /// wire mode is bitwise identical to in-memory aggregation.
    pub fn absorb_frame(&mut self, frame: &Frame<'_>, weight: f32) -> Result<()> {
        self.spec.validate_frame(frame)?;
        match (&mut self.acc, &frame.body) {
            (Acc::Sketch(acc), Body::Sketch { values, .. }) => {
                values.axpy_into(weight, acc.table_mut());
            }
            (Acc::Dense(acc), Body::Dense { values, .. }) => {
                values.axpy_into(weight, acc);
            }
            (Acc::Dense(acc), Body::Sparse { idx, values, .. }) => {
                // Parse validated the index array (strictly increasing,
                // < dim), so the paired walk cannot write out of bounds.
                let mut cursor = idx.chunks_exact(4);
                values.for_each(&mut |v| {
                    let chunk = cursor.next().expect("frame parse matched idx to values");
                    let i = u32::from_le_bytes(chunk.try_into().unwrap());
                    acc[i as usize] += weight * v;
                });
            }
            _ => unreachable!("validate_frame pinned the frame kind"),
        }
        self.absorbed += 1;
        Ok(())
    }

    /// `self *= s`, every cell. The finalize-at-quorum path uses this
    /// to renormalize a partial round's weighted sum over the slots
    /// that actually arrived (`Σ_{i∈S} λ_i·u_i → (Σ λ_i·u_i)/Σ λ_i`).
    pub fn scale(&mut self, s: f32) {
        match &mut self.acc {
            Acc::Sketch(t) => t.scale(s),
            Acc::Dense(v) => {
                for x in v.iter_mut() {
                    *x *= s;
                }
            }
        }
    }

    /// The merged sketch (fetchsgd). Errors for dense aggregators.
    pub fn as_sketch(&self) -> Result<&CountSketch> {
        match &self.acc {
            Acc::Sketch(s) => Ok(s),
            Acc::Dense(_) => bail!("round accumulator holds a dense sum, not a sketch"),
        }
    }

    /// The merged dense vector (all baselines). Errors for sketch
    /// aggregators.
    pub fn as_dense(&self) -> Result<&[f32]> {
        match &self.acc {
            Acc::Dense(v) => Ok(v),
            Acc::Sketch(_) => bail!("round accumulator holds a sketch, not a dense sum"),
        }
    }

    /// Consuming form of [`RoundAccum::as_sketch`] (tests/diagnostics).
    pub fn into_sketch(self) -> Result<CountSketch> {
        match self.acc {
            Acc::Sketch(s) => Ok(s),
            Acc::Dense(_) => bail!("round accumulator holds a dense sum, not a sketch"),
        }
    }

    /// Consuming form of [`RoundAccum::as_dense`] (tests/diagnostics).
    pub fn into_dense(self) -> Result<Vec<f32>> {
        match self.acc {
            Acc::Dense(v) => Ok(v),
            Acc::Sketch(_) => bail!("round accumulator holds a sketch, not a dense sum"),
        }
    }
}

/// Fan-in: reduce shard accumulators **in slice order** into
/// `shards[0]`, leaving the tail shards' allocations intact for reuse.
///
/// `parallelism > 1` splits the work over **row strips** (one strip per
/// sketch table row; [`DENSE_REDUCE_STRIP`]-cell chunks for dense
/// accumulators): each worker folds its disjoint strips from every tail
/// shard strictly in shard order, via [`CountSketch::add_rows_to`]. The
/// strip partition is a pure function of the accumulator geometry —
/// never of `parallelism` — and every cell still accumulates
/// `((s0 + s1) + s2) + …` exactly as sequential absorbs would, so the
/// result is bitwise identical at any worker count (including 1).
pub fn reduce_shards_in_place(shards: &mut [RoundAccum], parallelism: usize) -> Result<()> {
    reduce_shards_pinned(shards, parallelism, false)
}

/// [`reduce_shards_in_place`] with optional core pinning for the strip
/// workers ([`PipelineOptions::pin_shards`]); pinning is a placement
/// hint only and never changes bits.
fn reduce_shards_pinned(shards: &mut [RoundAccum], parallelism: usize, pin: bool) -> Result<()> {
    if shards.is_empty() {
        bail!("reduce_shards_in_place: no shards");
    }
    if shards.len() == 1 {
        // Single-shard rounds have nothing to fan in — don't pay the
        // strip workers' spawn cost for an empty fold.
        return Ok(());
    }
    let cells = shards[0].cells();
    let threads = if cells < PARALLEL_REDUCE_MIN_CELLS { 1 } else { parallelism.max(1) };
    let (head, rest) = shards.split_at_mut(1);
    let tail_absorbed: usize = rest.iter().map(|s| s.absorbed).sum();
    match &mut head[0].acc {
        Acc::Sketch(base) => {
            let mut refs = Vec::with_capacity(rest.len());
            for sh in rest.iter() {
                match &sh.acc {
                    Acc::Sketch(s) => refs.push(s),
                    Acc::Dense(_) => bail!("mixed shard kinds in reduce_shards_in_place"),
                }
            }
            if threads <= 1 || base.rows() <= 1 {
                base.merge_shard_refs(&refs);
            } else {
                for sh in &refs {
                    if sh.hasher() != base.hasher() || sh.dim() != base.dim() {
                        bail!("sketch shard geometry mismatch in reduce_shards_in_place");
                    }
                }
                let cols = base.cols();
                let refs = &refs;
                // One strip per table row; workers fold disjoint rows.
                parallel_strips(base.table_mut(), cols, threads, pin, &|row, dst| {
                    for sh in refs {
                        sh.add_rows_to(dst, row..row + 1);
                    }
                });
            }
        }
        Acc::Dense(base) => {
            let mut refs: Vec<&[f32]> = Vec::with_capacity(rest.len());
            for sh in rest.iter() {
                match &sh.acc {
                    Acc::Dense(v) => {
                        if v.len() != base.len() {
                            bail!("shard dim mismatch in reduce_shards_in_place");
                        }
                        refs.push(v);
                    }
                    Acc::Sketch(_) => bail!("mixed shard kinds in reduce_shards_in_place"),
                }
            }
            if threads <= 1 {
                for sh in &refs {
                    kernels::add(base, sh);
                }
            } else {
                let refs = &refs;
                parallel_strips(base, DENSE_REDUCE_STRIP, threads, pin, &|strip, dst| {
                    let start = strip * DENSE_REDUCE_STRIP;
                    for sh in refs {
                        kernels::add(dst, &sh[start..start + dst.len()]);
                    }
                });
            }
        }
    }
    head[0].absorbed += tail_absorbed;
    Ok(())
}

/// Grouped (tree-shaped) fan-in: reduce `accs` with the *association of
/// a relay tree* whose per-tier fan-outs are `tiers`, returning the
/// merged head and parking every drained accumulator in `spares`.
///
/// Why this exists: IEEE f32 addition is not associative, and the
/// reduction association of a depth-N relay tree is the tree shape
/// itself — each relay left-assoc folds its children, then its parent
/// left-assoc folds the relay heads. A genuinely flat left-assoc fold
/// over the same shards produces different bits. So a flat server (or
/// the in-process engine) that wants to bitwise-match a tree adopts the
/// tree's grouping here instead.
///
/// Layout contract: `accs[j]` is flat shard `j` of `L = Π tiers` shards
/// (slot → shard is `slot % L`). Nested chain striping composes to
/// exactly that modulus: the root gives chain `r` the slots
/// `≡ r (mod n1)`, an interior relay with fan-out `n2` gives child `k`
/// its chain *positions* `≡ k (mod n2)`, so a depth-3 leaf `(r, k)`
/// owns the globals `≡ r + k·n1 (mod n1·n2)` — flat shard
/// `j = r + k·n1`. Grouping shards by `j % n1` (ascending `j` within a
/// group, then recursing on `j / n1` with the remaining tiers)
/// therefore rebuilds each subtree's fold exactly; `tiers = [R]`
/// degenerates to the flat left-assoc fold. `parallelism` only sets the
/// row-strip worker count inside each fold ([`reduce_shards_in_place`])
/// and never changes bits.
pub fn reduce_shards_tree(
    accs: Vec<RoundAccum>,
    tiers: &[usize],
    parallelism: usize,
    spares: &mut Vec<RoundAccum>,
) -> Result<RoundAccum> {
    reduce_shards_tree_pinned(accs, tiers, parallelism, false, spares)
}

/// [`reduce_shards_tree`] with optional core pinning for the strip
/// workers inside each fold; a placement hint only, never bits.
fn reduce_shards_tree_pinned(
    accs: Vec<RoundAccum>,
    tiers: &[usize],
    parallelism: usize,
    pin: bool,
    spares: &mut Vec<RoundAccum>,
) -> Result<RoundAccum> {
    if tiers.iter().any(|&n| n == 0) {
        bail!("tier fan-outs must be nonzero, got {tiers:?}");
    }
    let want: usize = tiers.iter().product::<usize>().max(1);
    if accs.len() != want {
        bail!("tier layout {tiers:?} needs {want} shards, got {}", accs.len());
    }
    if tiers.len() <= 1 {
        let mut shards = accs;
        reduce_shards_pinned(&mut shards, parallelism, pin)?;
        let merged = shards.swap_remove(0);
        spares.extend(shards);
        return Ok(merged);
    }
    let n1 = tiers[0];
    // Group r collects flat shards j ≡ r (mod n1); pushing in ascending
    // j order makes each group's sub-index j / n1 ascend too.
    let mut groups: Vec<Vec<RoundAccum>> = (0..n1).map(|_| Vec::new()).collect();
    for (j, a) in accs.into_iter().enumerate() {
        groups[j % n1].push(a);
    }
    let mut heads = Vec::with_capacity(n1);
    for g in groups {
        heads.push(reduce_shards_tree_pinned(g, &tiers[1..], parallelism, pin, spares)?);
    }
    reduce_shards_pinned(&mut heads, parallelism, pin)?;
    let merged = heads.swap_remove(0);
    spares.extend(heads);
    Ok(merged)
}

/// Split `dst` into `strip_len`-cell strips (the last may be short) and
/// fold each exactly once, distributing strips round-robin over up to
/// `threads` scoped workers. Which worker runs a strip is the *only*
/// thing `threads` changes — each cell is written by exactly one call of
/// `fold`, so the result is bitwise identical at any worker count.
fn parallel_strips(
    dst: &mut [f32],
    strip_len: usize,
    threads: usize,
    pin: bool,
    fold: &(dyn Fn(usize, &mut [f32]) + Sync),
) {
    let strips: Vec<(usize, &mut [f32])> = dst.chunks_mut(strip_len).enumerate().collect();
    let threads = threads.clamp(1, strips.len().max(1));
    if threads <= 1 {
        for (i, strip) in strips {
            fold(i, strip);
        }
        return;
    }
    let mut per_worker: Vec<Vec<(usize, &mut [f32])>> = Vec::new();
    per_worker.resize_with(threads, Vec::new);
    for (j, s) in strips.into_iter().enumerate() {
        per_worker[j % threads].push(s);
    }
    std::thread::scope(|scope| {
        for (wi, list) in per_worker.into_iter().enumerate() {
            scope.spawn(move || {
                if pin {
                    // Placement hint only: worker wi's strip set is
                    // already fixed, pinning just keeps it on one core.
                    crate::util::affinity::pin_current_thread(wi);
                }
                for (i, strip) in list {
                    fold(i, strip);
                }
            });
        }
    });
}

/// Knobs for [`RoundPipeline`].
#[derive(Clone, Debug, Default)]
pub struct PipelineOptions {
    /// Worker threads for the row-strip shard reduction at round finish
    /// (0 = all available cores). Any value produces bitwise-identical
    /// merged results — the strip partition is a pure function of the
    /// accumulator geometry; this only sets how many threads fold the
    /// strips.
    pub reduce_parallelism: usize,
    /// Fixed shard count (0 = the default [`shard_count`] layout).
    /// Changing the layout changes which bits come out — this exists so
    /// *different drivers can agree on one layout*: a relay-tree root
    /// sets it to the relay fan-in `R` (each relay then owns exactly
    /// one shard chain, see [`RoundInFlight::offer_chain_frame`]), a
    /// relay sets it to 1 (its whole subtree is one chain), and a flat
    /// server or the in-process engine sets it to the same `R` to
    /// reproduce the tree's merged bits exactly. Capped at the slot
    /// count, not at [`MAX_SHARDS`].
    pub shard_override: usize,
    /// Per-tier relay fan-outs for the tree-shaped reduction
    /// ([`reduce_shards_tree`]): empty = flat left-assoc reduce.
    /// A flat server or the in-process engine sets this to the tree's
    /// fan-outs (root first, e.g. `[2, 2]` for a depth-3 tree of two
    /// relays with two relay children each) to reproduce a nested
    /// tree's merged bits exactly. Non-empty tiers *pin* the shard
    /// layout to `Π tiers` shards — `shard_override` must be 0 or agree
    /// with the product, and rounds with fewer slots than leaves are
    /// rejected (a capped layout would break the tree shape).
    pub reduce_tiers: Vec<usize>,
    /// Opt-in self-sizing of the shard layout from the previous rounds'
    /// [`AbsorbStats::lock_stalls`]: when stalls run hot the next
    /// round's shard count doubles (up to `min(slots,`
    /// [`ADAPTIVE_MAX_SHARDS`]`)`), decaying back toward the default
    /// [`shard_count`] layout when contention subsides; every layout
    /// change is logged with the stall rate that drove it. Only applies
    /// when nothing else pins the layout — a `shard_override` or
    /// non-empty `reduce_tiers` wins, and the controller stays inert.
    /// Off by default, deliberately: the shard count *is* the
    /// floating-point reduction tree, so two runs only merge
    /// bitwise-identically if their stall history matches. The
    /// determinism matrix runs with this off, and any run meant to be
    /// bitwise-comparable across machines or topologies must keep it
    /// off.
    pub adaptive_shards: bool,
    /// Opt-in shard→core pinning: the row-strip reduce workers (and the
    /// engine's absorb workers) pin themselves round-robin to cores via
    /// [`crate::util::affinity`], so the accumulator strips a worker
    /// folds stay in one cache domain. Purely a placement hint — which
    /// worker folds which strip is already fixed, so bits never depend
    /// on this — and best-effort: a failed affinity call (non-Linux, or
    /// a container cpuset that refuses) is silently ignored.
    pub pin_shards: bool,
}

/// The one round-aggregation pipeline, shared by the in-process engine
/// (`coordinator::engine`) and the transport server
/// (`transport::server`). Owns the shard layout, the reusable
/// scratch-accumulator pool, and the row-strip-parallel reduction; per
/// round it hands out a [`RoundInFlight`] that absorbs uploads on
/// arrival.
///
/// Lifecycle per round:
///
/// ```text
/// begin(spec, λ)  →  offer/offer_frame per slot (any order, any thread
///                    behind a lock)  →  finish() → merged RoundAccum
///                    →  …server consumes it…  →  recycle(merged)
/// ```
///
/// On a failed round, [`RoundPipeline::abort`] returns every shard to
/// the pool so the fault costs no reallocation.
pub struct RoundPipeline {
    opts: PipelineOptions,
    pool: Vec<RoundAccum>,
    /// Adaptive layout state: how many doublings above the default
    /// [`shard_count`] layout the next round will use. Stays 0 unless
    /// [`PipelineOptions::adaptive_shards`] is on and the closed
    /// rounds' stall rates have driven it up.
    adaptive_boost: u32,
}

impl RoundPipeline {
    pub fn new(opts: PipelineOptions) -> RoundPipeline {
        RoundPipeline { opts, pool: Vec::new(), adaptive_boost: 0 }
    }

    pub fn options(&self) -> &PipelineOptions {
        &self.opts
    }

    /// Accumulators currently parked in the pool (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }

    /// Start a round of `weights.len()` slots: take
    /// `shard_count(slots)` accumulators from the pool (spec-compatible
    /// ones are reset in place — in parallel for large tables — and
    /// anything else is dropped and rebuilt) and hand back the
    /// in-flight round state. `PipelineOptions::shard_override`
    /// replaces the default layout with a fixed shard count (capped at
    /// the slot count — a shard chain cannot be emptier than empty).
    pub fn begin(&mut self, spec: &UploadSpec, weights: Vec<f32>) -> Result<RoundInFlight> {
        if weights.is_empty() {
            bail!("a round needs at least one participant slot");
        }
        let shards = if !self.opts.reduce_tiers.is_empty() {
            let tiers = &self.opts.reduce_tiers;
            if tiers.iter().any(|&n| n == 0) {
                bail!("tier fan-outs must be nonzero, got {tiers:?}");
            }
            let leaves: usize = tiers.iter().product();
            if self.opts.shard_override != 0 && self.opts.shard_override != leaves {
                let o = self.opts.shard_override;
                bail!("shard_override {o} disagrees with tier layout {tiers:?} ({leaves} leaves)");
            }
            if weights.len() < leaves {
                bail!(
                    "round of {} slots cannot fill the {leaves}-leaf tier layout {tiers:?}",
                    weights.len()
                );
            }
            leaves
        } else if self.opts.shard_override > 0 {
            self.opts.shard_override.min(weights.len())
        } else if self.opts.adaptive_shards {
            // Self-sizing layout: start from the default and apply the
            // boost the closed rounds' stall rates have accumulated
            // (`observe_absorb`), capped by the slot count (a shard
            // chain cannot be emptier than empty) and the adaptive
            // ceiling.
            let base = shard_count(weights.len());
            (base << self.adaptive_boost).min(weights.len()).min(ADAPTIVE_MAX_SHARDS)
        } else {
            shard_count(weights.len())
        };
        self.pool.retain(|a| a.matches_spec(spec));
        while self.pool.len() < shards {
            self.pool.push(RoundAccum::new(spec)?);
        }
        let mut accs: Vec<RoundAccum> = self.pool.drain(..shards).collect();
        let threads = resolve_parallelism(self.opts.reduce_parallelism).min(accs.len());
        if threads <= 1 || accs[0].cells() < PARALLEL_REDUCE_MIN_CELLS {
            for a in &mut accs {
                a.reset();
            }
        } else {
            // Zeroing up to MAX_SHARDS large tables is measurable;
            // resets are independent, so parallelize them.
            let chunk = accs.len().div_ceil(threads);
            std::thread::scope(|scope| {
                for group in accs.chunks_mut(chunk) {
                    scope.spawn(move || {
                        for a in group {
                            a.reset();
                        }
                    });
                }
            });
        }
        let slots = weights.len();
        Ok(RoundInFlight {
            spec: spec.clone(),
            shards: accs
                .into_iter()
                .map(|accum| Mutex::new(ShardState { accum, done: 0, pending: BTreeMap::new() }))
                .collect(),
            weights,
            seen: (0..slots).map(|_| AtomicBool::new(false)).collect(),
            absorbed: AtomicUsize::new(0),
            lock_stalls: AtomicU64::new(0),
            parked_bytes: AtomicU64::new(0),
            trace: None,
        })
    }

    /// Fan-in: reduce the round's shard accumulators (strictly in shard
    /// order, row-strip-parallel per [`reduce_shards_in_place`]) into
    /// the merged round sum, returning tail shards to the pool for
    /// reuse. Errors if any slot is still outstanding — in that case
    /// every shard still goes back to the pool (they reset on reuse), so
    /// an aborted round costs no reallocation.
    pub fn finish(&mut self, round: RoundInFlight) -> Result<RoundAccum> {
        self.observe_absorb(round.absorb_stats(), round.absorbed());
        if !round.is_complete() {
            let (absorbed, slots, parked) =
                (round.absorbed(), round.slots(), round.buffered());
            self.pool.extend(round.into_accums());
            bail!(
                "round incomplete: absorbed {absorbed} of {slots} uploads \
                 ({parked} parked out of order)"
            );
        }
        let shards = round.into_accums();
        self.reduce_round(shards)
    }

    /// Reduce a round's shards into the merged sum, honoring
    /// [`PipelineOptions::reduce_tiers`] (tree-shaped association) when
    /// set, and park the drained tail shards in the pool.
    fn reduce_round(&mut self, mut shards: Vec<RoundAccum>) -> Result<RoundAccum> {
        let par = resolve_parallelism(self.opts.reduce_parallelism);
        let pin = self.opts.pin_shards;
        if !self.opts.reduce_tiers.is_empty() {
            let tiers = self.opts.reduce_tiers.clone();
            return reduce_shards_tree_pinned(shards, &tiers, par, pin, &mut self.pool);
        }
        reduce_shards_pinned(&mut shards, par, pin)?;
        let merged = shards.swap_remove(0);
        self.pool.extend(shards);
        Ok(merged)
    }

    /// Feed one closing round's contention counters into the adaptive
    /// shard controller. A no-op unless
    /// [`PipelineOptions::adaptive_shards`] is on and nothing else pins
    /// the layout (`shard_override` / `reduce_tiers` win). One boost
    /// step per round at most, with hysteresis between the hot and cool
    /// stall-rate thresholds; every change is logged with the rate that
    /// drove it so the decision trail is auditable next to the
    /// `chosen_shards` / `lock_stalls` pair in the round JSONL.
    fn observe_absorb(&mut self, stats: AbsorbStats, absorbed: usize) {
        if !self.opts.adaptive_shards
            || self.opts.shard_override != 0
            || !self.opts.reduce_tiers.is_empty()
            || absorbed == 0
        {
            return;
        }
        let rate = stats.lock_stalls as f64 / absorbed as f64;
        let old = self.adaptive_boost;
        if rate > ADAPTIVE_HOT_STALL_RATE && self.adaptive_boost < ADAPTIVE_MAX_BOOST {
            self.adaptive_boost += 1;
        } else if rate < ADAPTIVE_COOL_STALL_RATE && self.adaptive_boost > 0 {
            self.adaptive_boost -= 1;
        }
        if self.adaptive_boost != old {
            eprintln!(
                "[pipeline] adaptive shards: stall rate {rate:.3} \
                 ({} stalls / {absorbed} uploads) -> boost {old} -> {} \
                 ({}x the default layout next round, ceiling {ADAPTIVE_MAX_SHARDS})",
                stats.lock_stalls,
                self.adaptive_boost,
                1usize << self.adaptive_boost,
            );
        }
    }

    /// Finalize-at-quorum: close the round with only the slots the
    /// membership tracker recorded as arrived, renormalizing the
    /// aggregation weights over the actual participants.
    ///
    /// Uploads were absorbed with their *planned* weights λ; closing
    /// over the arrived subset `S` therefore scales the merged sum by
    /// `1 / Σ_{i∈S} λ_i` ([`RoundMembership::renormalization_scale`]) —
    /// for uniform 1/W weights that recovers the mean over `|S|`, for
    /// FedAvg's size weights the size-weighted mean over `S`. Everything
    /// here is a pure function of the final membership set: parked
    /// arrivals whose in-shard predecessors were dropped are drained in
    /// increasing slot order (exactly where the full-cohort path would
    /// have absorbed them), shards reduce in shard order, and the scale
    /// depends only on (weights, set). Two runs ending with the same
    /// set — in-process or served, any parallelism — merge to identical
    /// bits.
    ///
    /// A fully-arrived membership defers to [`RoundPipeline::finish`]
    /// verbatim (no scale), so quorum config on a healthy cohort
    /// changes nothing. Errors if the quorum is not met or the
    /// membership disagrees with the offered slots; shards return to
    /// the pool either way.
    pub fn finalize_partial(
        &mut self,
        mut round: RoundInFlight,
        membership: &RoundMembership,
    ) -> Result<RoundAccum> {
        if membership.slots() != round.slots() {
            let (m, r) = (membership.slots(), round.slots());
            self.pool.extend(round.into_accums());
            bail!("membership tracks {m} slots but the round has {r}");
        }
        if !membership.quorum_met() {
            let (arrived, slots, target) =
                (membership.arrived(), membership.slots(), membership.quorum_target());
            self.pool.extend(round.into_accums());
            bail!("quorum not met: {arrived} of {slots} uploads arrived (target {target})");
        }
        if membership.is_full() {
            return self.finish(round);
        }
        self.observe_absorb(round.absorb_stats(), round.absorbed());
        for slot in 0..round.slots() {
            if round.seen_slot(slot) != membership.is_arrived(slot) {
                let (offered, arrived) = (round.seen_slot(slot), membership.is_arrived(slot));
                self.pool.extend(round.into_accums());
                bail!(
                    "slot {slot}: upload offered={offered} but membership records \
                     arrived={arrived}"
                );
            }
        }
        // Compute the scale before consuming the round so error paths
        // can still return the shards to the pool.
        let scale = match membership.renormalization_scale(&round.weights) {
            Ok(s) => s,
            Err(e) => {
                self.pool.extend(round.into_accums());
                return Err(e);
            }
        };
        if let Err(e) = round.drain_parked() {
            self.pool.extend(round.into_accums());
            return Err(e);
        }
        debug_assert_eq!(round.absorbed(), membership.arrived());
        let shards = round.into_accums();
        let mut merged = self.reduce_round(shards)?;
        merged.scale(scale);
        Ok(merged)
    }

    /// Close a *relay's* subtree round: merge whatever arrived, with no
    /// quorum check and no renormalization — both belong to the root,
    /// which sees the whole cohort. A relay only reports; `Ok(None)`
    /// means a zero-participant subtree (nothing arrived, nothing to
    /// forward). Parked arrivals whose in-shard predecessors dropped
    /// are drained in increasing slot order first, exactly as
    /// [`RoundPipeline::finalize_partial`] would, so the partial sum the
    /// relay forwards is the same pure function of (weights, arrived
    /// set) the root would have computed over those slots itself.
    pub fn finalize_subtree(&mut self, mut round: RoundInFlight) -> Result<Option<RoundAccum>> {
        self.observe_absorb(round.absorb_stats(), round.absorbed());
        if let Err(e) = round.drain_parked() {
            self.pool.extend(round.into_accums());
            return Err(e);
        }
        if round.absorbed() == 0 {
            self.pool.extend(round.into_accums());
            return Ok(None);
        }
        let shards = round.into_accums();
        self.reduce_round(shards).map(Some)
    }

    /// Abandon a round, returning every shard accumulator to the pool —
    /// the error-path counterpart of [`RoundPipeline::finish`] (partial
    /// sums are fine: accumulators reset in place on reuse).
    pub fn abort(&mut self, round: RoundInFlight) {
        self.pool.extend(round.into_accums());
    }

    /// Return the merged accumulator once the server half is done with
    /// it — the caller's return-to-pool step after
    /// `ServerAggregator::finish`.
    pub fn recycle(&mut self, merged: RoundAccum) {
        self.pool.push(merged);
    }
}

/// An upload waiting for an earlier slot of its shard.
enum Parked {
    Upload(ClientUpload),
    Frame(Vec<u8>),
}

/// Frame bytes offered to the round: owned (`offer_frame`) or borrowed
/// straight from a transport read buffer (`offer_frame_bytes`). Borrowed
/// bytes are only copied when the frame must park.
enum FrameBytes<'a> {
    Owned(Vec<u8>),
    Borrowed(&'a [u8]),
}

impl FrameBytes<'_> {
    fn as_slice(&self) -> &[u8] {
        match self {
            FrameBytes::Owned(v) => v,
            FrameBytes::Borrowed(b) => b,
        }
    }

    fn into_owned(self) -> Vec<u8> {
        match self {
            FrameBytes::Owned(v) => v,
            FrameBytes::Borrowed(b) => b.to_vec(),
        }
    }
}

/// Contention and parking counters for one round's absorb phase —
/// surfaced per round in `RoundRecord` / `ServeSummary` JSONL so lock
/// contention on the absorb path is observable, not guessed at.
#[derive(Clone, Copy, Debug, Default)]
pub struct AbsorbStats {
    /// Shard-lock acquisitions that found the lock already held (the
    /// blocking slow path was taken). Zero means workers never
    /// contended.
    pub lock_stalls: u64,
    /// Bytes copied into the parking buffer for out-of-order arrivals:
    /// frame bytes on the wire path, idealized payload bytes for
    /// in-memory uploads. Zero means every upload absorbed on arrival.
    pub parked_bytes: u64,
    /// Shard accumulators the round actually ran with — the default
    /// [`shard_count`] layout unless `shard_override`/`reduce_tiers`
    /// pinned it or the adaptive controller resized it. Surfaced so
    /// adaptive-layout decisions are auditable in the round JSONL next
    /// to the stall counter that drives them.
    pub chosen_shards: u64,
}

/// One shard's absorb state — accumulator, in-shard progress, and
/// parked early arrivals — everything guarded by that shard's own lock.
struct ShardState {
    accum: RoundAccum,
    /// Slots absorbed so far. The next slot this shard accepts is
    /// `shard + done * nshards`.
    done: usize,
    /// Early uploads, parked by slot until the shard catches up.
    pending: BTreeMap<usize, Parked>,
}

/// One round's absorb-on-arrival state, handed out by
/// [`RoundPipeline::begin`].
///
/// Neither the engine's worker pool nor a socket server can choose
/// upload completion order, but the determinism contract (module docs)
/// requires each shard to absorb its slots in increasing slot order.
/// `RoundInFlight` reconciles the two: an upload whose slot is the next
/// expected one for its shard is absorbed immediately (and may unblock
/// parked successors); one that arrives early is parked — as owned
/// frame bytes on the wire path, as the in-memory upload on the engine
/// path — until its turn. In the common case of roughly slot-ordered
/// completion everything absorbs on arrival and nothing waits for the
/// cohort; in the worst case the parking buffer holds at most the
/// cohort's uploads, and the merged result is bitwise identical either
/// way.
///
/// All offer methods take `&self`: each shard's state sits behind its
/// own `Mutex`, and the per-slot claim bits and absorbed counter are
/// atomics, so concurrent workers folding into different shards never
/// contend and need no outer lock.
///
/// Slot bookkeeping doubles as integrity protection: out-of-range and
/// duplicate slots are rejected before any values reach an accumulator,
/// so a malicious peer cannot scribble over another client's
/// contribution.
pub struct RoundInFlight {
    /// The round's upload shape — used to validate wire frames before
    /// any shard lock is taken.
    spec: UploadSpec,
    /// Shard absorb states, `shard_count(slots)` of them, each behind
    /// its own lock.
    shards: Vec<Mutex<ShardState>>,
    /// Per-slot aggregation weights λ (also fixes the slot count).
    weights: Vec<f32>,
    /// Which slots have been offered (duplicate protection). A slot is
    /// claimed by the atomic swap before its shard lock is touched and
    /// released on validation/absorb failure so retries stay legal.
    seen: Vec<AtomicBool>,
    absorbed: AtomicUsize,
    lock_stalls: AtomicU64,
    parked_bytes: AtomicU64,
    /// Trace sink plus the round index to stamp, attached by the driver
    /// after [`RoundPipeline::begin`]. Strictly observational — every
    /// hook is a single `if let Some` branch when absent, and nothing a
    /// hook records feeds back into absorb order or values.
    trace: Option<(Arc<TraceSink>, u64)>,
}

impl RoundInFlight {
    /// Attach a trace sink: subsequent offers stamp the slot-timeline
    /// events (`validated` / `absorbed` / `parked` / `folded`) into it,
    /// tagged with `round`.
    pub fn attach_trace(&mut self, sink: Arc<TraceSink>, round: u64) {
        self.trace = Some((sink, round));
    }

    /// Stamp one slot-timeline event if a sink is attached — the single
    /// guard every hook goes through.
    #[inline]
    fn trace_slot(&self, slot: usize, ev: SlotEvent, peer: Option<usize>) {
        if let Some((t, round)) = &self.trace {
            t.slot_event(*round, slot, ev, peer);
        }
    }
    /// Total slots this round.
    pub fn slots(&self) -> usize {
        self.weights.len()
    }

    /// Uploads absorbed into shard accumulators so far.
    pub fn absorbed(&self) -> usize {
        self.absorbed.load(Ordering::SeqCst)
    }

    /// Uploads parked waiting for an earlier slot of their shard.
    pub fn buffered(&self) -> usize {
        self.shards.iter().map(|s| s.lock().expect("shard state poisoned").pending.len()).sum()
    }

    pub fn is_complete(&self) -> bool {
        self.absorbed() == self.weights.len()
    }

    /// The round's contention/parking counters so far.
    pub fn absorb_stats(&self) -> AbsorbStats {
        AbsorbStats {
            lock_stalls: self.lock_stalls.load(Ordering::SeqCst),
            parked_bytes: self.parked_bytes.load(Ordering::SeqCst),
            chosen_shards: self.shards.len() as u64,
        }
    }

    /// Hand the round `slot`'s in-memory upload — the engine path.
    /// Absorbs immediately when the slot is next in its shard's order
    /// (then drains any parked successors), parks the upload otherwise.
    pub fn offer(&self, slot: usize, upload: ClientUpload) -> Result<()> {
        self.claim(slot)?;
        let nshards = self.shards.len();
        let shard = shard_of(slot, nshards);
        let mut st = self.lock_shard(shard);
        if slot != shard + st.done * nshards {
            // Early for its shard (slot < expected is impossible: that
            // slot would already be claimed). In-memory uploads carry
            // their own shape and are validated at absorb time.
            self.parked_bytes.fetch_add(upload.payload_bytes(), Ordering::Relaxed);
            st.pending.insert(slot, Parked::Upload(upload));
            self.trace_slot(slot, SlotEvent::Parked, None);
            return Ok(());
        }
        self.absorb_into(&mut st, slot, Parked::Upload(upload))?;
        self.trace_slot(slot, SlotEvent::Absorbed, None);
        self.drain_successors(&mut st, shard)
    }

    /// Hand the round `slot`'s encoded upload frame (owned) — the wire
    /// path. The frame is parsed and validated before any lock is
    /// taken; a bad frame fails its own offer and counts nothing.
    pub fn offer_frame(&self, slot: usize, frame: Vec<u8>) -> Result<()> {
        self.route_frame(slot, FrameBytes::Owned(frame))
    }

    /// Zero-copy variant of [`RoundInFlight::offer_frame`]: absorb
    /// straight from a borrowed buffer (the transport's read buffer)
    /// when the frame arrives in-shard-order; only a truly-early
    /// arrival is copied into the parking buffer.
    pub fn offer_frame_bytes(&self, slot: usize, frame: &[u8]) -> Result<()> {
        self.route_frame(slot, FrameBytes::Borrowed(frame))
    }

    fn route_frame(&self, slot: usize, fb: FrameBytes<'_>) -> Result<()> {
        self.claim(slot)?;
        // Parse + validate BEFORE taking any lock: rejecting a corrupt
        // or mismatched frame never holds round state, so a hostile
        // peer cannot stall healthy absorbs — and fault attribution
        // (plus any retry of this slot) lands on the right peer whether
        // the frame would have absorbed now or parked.
        let frame = match Frame::parse(fb.as_slice())
            .and_then(|f| self.spec.validate_frame(&f).map(|()| f))
        {
            Ok(frame) => frame,
            Err(e) => {
                self.release(slot);
                return Err(e.context(format!("validating upload frame for slot {slot}")));
            }
        };
        self.trace_slot(slot, SlotEvent::Validated, None);
        let nshards = self.shards.len();
        let shard = shard_of(slot, nshards);
        let mut st = self.lock_shard(shard);
        if slot != shard + st.done * nshards {
            // Truly early: park owned bytes (the only copy a borrowed
            // frame ever pays). The deferred absorb re-parses the same
            // bytes, so it cannot fail on anything validated here.
            drop(frame);
            let bytes = fb.into_owned();
            self.parked_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
            st.pending.insert(slot, Parked::Frame(bytes));
            self.trace_slot(slot, SlotEvent::Parked, None);
            return Ok(());
        }
        // In-shard-order arrival: fold straight out of the caller's
        // buffer — no copy, no re-parse.
        if let Err(e) = st.accum.absorb_frame(&frame, self.weights[slot]) {
            self.release(slot);
            return Err(e.context(format!("absorbing upload for slot {slot}")));
        }
        st.done += 1;
        self.absorbed.fetch_add(1, Ordering::SeqCst);
        self.trace_slot(slot, SlotEvent::Absorbed, None);
        self.drain_successors(&mut st, shard)
    }

    /// Hand shard chain `chain` a *merged* frame covering the `arrived`
    /// slots — the relay-tree root's path. A relay folded its
    /// downstream uploads, each weighted by its global λ, into one
    /// accumulator in increasing global-slot order; because the root's
    /// shard layout assigns exactly the slots `{s : s % nshards ==
    /// chain}` to shard `chain`, absorbing that partial sum with weight
    /// 1.0 into the untouched shard reproduces, bit for bit, the
    /// per-slot fold the shard would have performed itself (`1.0 · x`
    /// is exact, and the relay↔root hop is required to be lossless
    /// `f32le`).
    ///
    /// `arrived` must list the chain's delivered slots in strictly
    /// increasing order; every one is claimed in the lock-free
    /// membership layer (so a slot delivered by two subtrees is a loud
    /// duplicate, not silent double-counting), and the shard must be
    /// untouched — a merged frame owns its whole chain and cannot mix
    /// with per-slot uploads. On any failure nothing is absorbed and
    /// every claim is released, so fault attribution stays on this
    /// chain: the caller drops the subtree's slot range and the round
    /// can still close at quorum.
    pub fn offer_chain_frame(&self, chain: usize, arrived: &[usize], frame: &[u8]) -> Result<()> {
        let nshards = self.shards.len();
        if chain >= nshards {
            bail!("chain {chain} out of range (round has {nshards} shard chains)");
        }
        if arrived.is_empty() {
            bail!("a merged chain frame must cover at least one arrived slot");
        }
        let mut prev: Option<usize> = None;
        for &slot in arrived {
            if slot >= self.weights.len() {
                let slots = self.weights.len();
                bail!("chain {chain} reports slot {slot} out of range (round has {slots})");
            }
            if shard_of(slot, nshards) != chain {
                let owner = shard_of(slot, nshards);
                bail!("chain {chain} reports slot {slot}, which belongs to chain {owner}");
            }
            if prev.is_some_and(|p| p >= slot) {
                bail!("chain {chain} reports arrived slots out of order");
            }
            prev = Some(slot);
        }
        // Parse + validate before claiming anything (same policy as
        // route_frame: a corrupt frame never holds round state).
        let parsed = match Frame::parse(frame)
            .and_then(|f| self.spec.validate_frame(&f).map(|()| f))
            .and_then(|f| {
                if f.codec.id() != F32LE.id() {
                    bail!("merged chain frames must use the lossless f32le codec");
                }
                if matches!(self.spec, UploadSpec::Dense { .. })
                    && matches!(f.body, Body::Sparse { .. })
                {
                    bail!("a merged chain frame over a dense accumulator cannot be sparse");
                }
                Ok(f)
            }) {
            Ok(f) => f,
            Err(e) => {
                return Err(e.context(format!("validating merged frame for chain {chain}")))
            }
        };
        for (i, &slot) in arrived.iter().enumerate() {
            if self.seen[slot].swap(true, Ordering::AcqRel) {
                for &s in &arrived[..i] {
                    self.release(s);
                }
                bail!("chain {chain}: slot {slot} was already delivered by another peer");
            }
        }
        let mut st = self.lock_shard(chain);
        if st.done != 0 || !st.pending.is_empty() {
            drop(st);
            for &s in arrived {
                self.release(s);
            }
            bail!(
                "chain {chain} already received per-slot uploads; a merged frame \
                 must own its whole chain"
            );
        }
        if let Err(e) = st.accum.absorb_frame(&parsed, 1.0) {
            drop(st);
            for &s in arrived {
                self.release(s);
            }
            return Err(e.context(format!("absorbing merged frame for chain {chain}")));
        }
        st.done += arrived.len();
        drop(st);
        self.absorbed.fetch_add(arrived.len(), Ordering::SeqCst);
        // One merged frame delivered the whole chain: stamp each covered
        // slot's absorb with the chain as its peer, so the merged tree
        // timeline attributes them to the delivering subtree.
        for &slot in arrived {
            self.trace_slot(slot, SlotEvent::Absorbed, Some(chain));
        }
        Ok(())
    }

    /// Claim `slot` in the lock-free membership layer: range check plus
    /// the atomic test-and-set duplicate guard.
    fn claim(&self, slot: usize) -> Result<()> {
        let slots = self.weights.len();
        if slot >= slots {
            bail!("upload slot {slot} out of range (round has {slots} slots)");
        }
        if self.seen[slot].swap(true, Ordering::AcqRel) {
            bail!("duplicate upload for slot {slot}");
        }
        Ok(())
    }

    /// Un-claim a slot whose validation or absorb failed — nothing
    /// reached an accumulator, so a retry / reassignment may
    /// legitimately offer it again.
    fn release(&self, slot: usize) {
        self.seen[slot].store(false, Ordering::Release);
    }

    /// Lock one shard, counting the acquisitions that actually blocked.
    fn lock_shard(&self, shard: usize) -> MutexGuard<'_, ShardState> {
        match self.shards[shard].try_lock() {
            Ok(guard) => guard,
            Err(TryLockError::WouldBlock) => {
                self.lock_stalls.fetch_add(1, Ordering::Relaxed);
                self.shards[shard].lock().expect("shard state poisoned")
            }
            Err(TryLockError::Poisoned(_)) => panic!("shard state poisoned"),
        }
    }

    /// Absorb one in-order item into its (already locked) shard,
    /// keeping the lock-free layer consistent on failure.
    fn absorb_into(&self, st: &mut ShardState, slot: usize, item: Parked) -> Result<()> {
        let lam = self.weights[slot];
        let absorbed = match item {
            Parked::Upload(u) => st.accum.absorb(u, lam),
            Parked::Frame(f) => st.accum.absorb_bytes(&f, lam),
        };
        if let Err(e) = absorbed {
            // A failed absorb touches no accumulator cell (validation
            // runs before any add), so un-claim the slot for retry.
            self.release(slot);
            return Err(e.context(format!("absorbing upload for slot {slot}")));
        }
        st.done += 1;
        self.absorbed.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// Absorb any parked successors the latest absorb unblocked (the
    /// caller holds the shard's lock).
    fn drain_successors(&self, st: &mut ShardState, shard: usize) -> Result<()> {
        let nshards = self.shards.len();
        loop {
            let next = shard + st.done * nshards;
            let Some(parked) = st.pending.remove(&next) else { break };
            self.absorb_into(st, next, parked)?;
            self.trace_slot(next, SlotEvent::Folded, None);
        }
        Ok(())
    }

    /// Absorb every parked upload in increasing slot order — the
    /// finalize-at-quorum path, where a dropped in-shard predecessor
    /// will never arrive to unblock its successors. Ascending slot
    /// order globally implies ascending order within each shard, so the
    /// per-shard absorb sequence over the arrived set is exactly what a
    /// full-cohort round would have performed on those slots.
    fn drain_parked(&mut self) -> Result<()> {
        let nshards = self.shards.len();
        let mut all: BTreeMap<usize, Parked> = BTreeMap::new();
        for st in &mut self.shards {
            let st = st.get_mut().expect("shard state poisoned");
            all.append(&mut st.pending);
        }
        for (slot, item) in all {
            let shard = shard_of(slot, nshards);
            let mut st = self.shards[shard].lock().expect("shard state poisoned");
            self.absorb_into(&mut st, slot, item)?;
            self.trace_slot(slot, SlotEvent::Folded, None);
        }
        Ok(())
    }

    /// Whether `slot` has been offered (and not released by a failure).
    fn seen_slot(&self, slot: usize) -> bool {
        self.seen[slot].load(Ordering::SeqCst)
    }

    /// Tear down into the shard accumulators, in shard order — the
    /// pipeline's reduce/abort path.
    fn into_accums(self) -> Vec<RoundAccum> {
        self.shards
            .into_iter()
            .map(|m| m.into_inner().expect("shard state poisoned").accum)
            .collect()
    }
}

/// Sequential convenience: absorb `uploads[i]` with `weights[i]`, in
/// order, into a fresh accumulator. Used by strategy unit tests and the
/// server-cost benches; the trainer goes through the round engine
/// instead.
pub fn accumulate_uploads(
    spec: &UploadSpec,
    uploads: Vec<ClientUpload>,
    weights: &[f32],
) -> Result<RoundAccum> {
    if uploads.len() != weights.len() {
        bail!("{} uploads but {} weights", uploads.len(), weights.len());
    }
    let mut acc = RoundAccum::new(spec)?;
    for (u, &lam) in uploads.into_iter().zip(weights) {
        acc.absorb(u, lam)?;
    }
    Ok(acc)
}

/// Sequential convenience driving one full server round —
/// `begin_round → absorb each upload in order → finish → apply` —
/// exactly the pipeline the round engine runs in sharded form. Used by
/// strategy unit tests and the server-cost benches so the contract
/// lives in one place.
pub fn run_server_round(
    agg: &mut dyn ServerAggregator,
    client_sizes: &[f32],
    uploads: Vec<ClientUpload>,
    w: &mut [f32],
    lr: f32,
) -> Result<RoundUpdate> {
    let weights = agg.begin_round(client_sizes);
    let merged = accumulate_uploads(&agg.upload_spec(), uploads, &weights)?;
    let update = agg.finish(&merged, lr)?;
    update.apply(w);
    Ok(update)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::topk::SparseVec;
    use crate::wire::{encode_upload, F32LE};

    fn sketch_spec() -> UploadSpec {
        UploadSpec::Sketch { rows: 3, cols: 128, dim: 200, seed: 11 }
    }

    fn pipeline() -> RoundPipeline {
        RoundPipeline::new(PipelineOptions::default())
    }

    #[test]
    fn sketch_absorb_matches_direct_weighted_merge() {
        let mut rng = crate::util::Rng::new(5);
        let grads: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..200).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let uploads: Vec<ClientUpload> = grads
            .iter()
            .map(|g| ClientUpload::Sketch(CountSketch::encode(3, 128, 11, g).unwrap()))
            .collect();
        let acc = accumulate_uploads(&sketch_spec(), uploads, &[0.25; 4]).unwrap();
        assert_eq!(acc.absorbed(), 4);
        let merged = acc.into_sketch().unwrap();

        let mut direct = CountSketch::zeros(3, 128, 200, 11).unwrap();
        for g in &grads {
            direct.add_scaled(&CountSketch::encode(3, 128, 11, g).unwrap(), 0.25);
        }
        for (a, b) in merged.table().iter().zip(direct.table()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn absorb_bytes_is_bitwise_identical_to_absorb_under_f32le() {
        let mut rng = crate::util::Rng::new(13);
        let make_upload = |rng: &mut crate::util::Rng, kind: usize| -> ClientUpload {
            let g: Vec<f32> = (0..200).map(|_| rng.next_gaussian() as f32).collect();
            match kind {
                0 => ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap()),
                1 => ClientUpload::Dense(g),
                _ => ClientUpload::Sparse(crate::sketch::topk::top_k_sparse(&g, 17)),
            }
        };
        // Sketch spec path.
        let mut via_mem = RoundAccum::new(&sketch_spec()).unwrap();
        let mut via_wire = RoundAccum::new(&sketch_spec()).unwrap();
        for i in 0..3 {
            let u = make_upload(&mut rng, 0);
            let frame = encode_upload(&u, &F32LE);
            via_wire.absorb_bytes(&frame, 0.3 + i as f32).unwrap();
            via_mem.absorb(u, 0.3 + i as f32).unwrap();
        }
        assert_eq!(via_wire.absorbed(), 3);
        let (mem, wire) = (via_mem.as_sketch().unwrap(), via_wire.as_sketch().unwrap());
        for (a, b) in mem.table().iter().zip(wire.table()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Dense spec path folds dense and sparse frames alike.
        let spec = UploadSpec::Dense { dim: 200 };
        let mut via_mem = RoundAccum::new(&spec).unwrap();
        let mut via_wire = RoundAccum::new(&spec).unwrap();
        for kind in [1usize, 2] {
            let u = make_upload(&mut rng, kind);
            let frame = encode_upload(&u, &F32LE);
            via_wire.absorb_bytes(&frame, 0.5).unwrap();
            via_mem.absorb(u, 0.5).unwrap();
        }
        for (a, b) in via_mem.as_dense().unwrap().iter().zip(via_wire.as_dense().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn absorb_bytes_rejects_mismatched_frames() {
        let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
        // wrong seed
        let s = CountSketch::zeros(3, 128, 200, 999).unwrap();
        let frame = encode_upload(&ClientUpload::Sketch(s), &F32LE);
        assert!(acc.absorb_bytes(&frame, 1.0).is_err());
        // wrong kind
        let frame = encode_upload(&ClientUpload::Dense(vec![0.0; 200]), &F32LE);
        assert!(acc.absorb_bytes(&frame, 1.0).is_err());
        // wrong dim on a dense aggregator
        let mut acc = RoundAccum::new(&UploadSpec::Dense { dim: 10 }).unwrap();
        let frame = encode_upload(&ClientUpload::Dense(vec![0.0; 4]), &F32LE);
        assert!(acc.absorb_bytes(&frame, 1.0).is_err());
        assert_eq!(acc.absorbed(), 0, "failed absorbs must not count");
    }

    #[test]
    fn reset_reuses_allocation_and_zeroes_state() {
        let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
        let g = vec![1f32; 200];
        acc.absorb(ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap()), 1.0)
            .unwrap();
        assert_eq!(acc.absorbed(), 1);
        assert!(acc.as_sketch().unwrap().table().iter().any(|&x| x != 0.0));
        acc.reset();
        assert_eq!(acc.absorbed(), 0);
        assert!(acc.as_sketch().unwrap().table().iter().all(|&x| x == 0.0));
        assert!(acc.matches_spec(&sketch_spec()));
        assert!(!acc.matches_spec(&UploadSpec::Dense { dim: 200 }));
    }

    #[test]
    fn sharded_reduce_is_bitwise_stable_across_parallelism() {
        // The row-strip contract: reducing the same shard list at any
        // worker count gives identical bits (strip partition is pure
        // geometry). Checked for sketch and dense shard kinds.
        let mut rng = crate::util::Rng::new(9);
        let make_sketch_shards = |rng: &mut crate::util::Rng| {
            (0..3)
                .map(|_| {
                    let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
                    for _ in 0..2 {
                        let g: Vec<f32> =
                            (0..200).map(|_| rng.next_gaussian() as f32).collect();
                        acc.absorb(
                            ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap()),
                            0.5,
                        )
                        .unwrap();
                    }
                    acc
                })
                .collect::<Vec<_>>()
        };
        let mut a = make_sketch_shards(&mut rng);
        reduce_shards_in_place(&mut a, 1).unwrap();
        for parallelism in [2usize, 8] {
            let mut rng = crate::util::Rng::new(9);
            let mut b = make_sketch_shards(&mut rng);
            reduce_shards_in_place(&mut b, parallelism).unwrap();
            assert_eq!(a[0].absorbed(), 6);
            assert_eq!(b[0].absorbed(), 6);
            let (ta, tb) = (a[0].as_sketch().unwrap(), b[0].as_sketch().unwrap());
            for (x, y) in ta.table().iter().zip(tb.table()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        // tail shards keep their allocations (and contents) for reuse
        assert_eq!(a[1].absorbed(), 2);
        assert!(a[1].as_sketch().unwrap().table().iter().any(|&x| x != 0.0));

        // Dense path, sized past the parallel-reduce gate so the
        // striped code actually runs.
        let dim = PARALLEL_REDUCE_MIN_CELLS + 1000;
        let spec = UploadSpec::Dense { dim };
        let make_dense_shards = |rng: &mut crate::util::Rng| {
            (0..3)
                .map(|_| {
                    let mut acc = RoundAccum::new(&spec).unwrap();
                    let g: Vec<f32> = (0..dim).map(|_| rng.next_gaussian() as f32).collect();
                    acc.absorb(ClientUpload::Dense(g), 0.5).unwrap();
                    acc
                })
                .collect::<Vec<_>>()
        };
        let mut rng = crate::util::Rng::new(10);
        let mut a = make_dense_shards(&mut rng);
        reduce_shards_in_place(&mut a, 1).unwrap();
        let mut rng = crate::util::Rng::new(10);
        let mut b = make_dense_shards(&mut rng);
        reduce_shards_in_place(&mut b, 8).unwrap();
        for (x, y) in a[0].as_dense().unwrap().iter().zip(b[0].as_dense().unwrap()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn adaptive_shard_controller_sizes_from_stall_rate() {
        let spec = sketch_spec();
        let slots = 40usize;
        let mut pl = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: 1,
            adaptive_shards: true,
            ..Default::default()
        });
        // No stall history → the default layout.
        let r = pl.begin(&spec, vec![1.0; slots]).unwrap();
        assert_eq!(r.absorb_stats().chosen_shards as usize, shard_count(slots));
        pl.abort(r);
        // Hot stall rate (30/40 > 0.25) doubles the layout one step per
        // closed round, clamping at min(slots, ADAPTIVE_MAX_SHARDS).
        let hot = AbsorbStats { lock_stalls: 30, ..Default::default() };
        pl.observe_absorb(hot, slots);
        let r = pl.begin(&spec, vec![1.0; slots]).unwrap();
        assert_eq!(r.absorb_stats().chosen_shards as usize, 2 * shard_count(slots));
        pl.abort(r);
        for _ in 0..4 {
            pl.observe_absorb(hot, slots);
        }
        let r = pl.begin(&spec, vec![1.0; slots]).unwrap();
        assert_eq!(
            r.absorb_stats().chosen_shards as usize,
            slots.min(ADAPTIVE_MAX_SHARDS),
            "boost saturates at the ceiling"
        );
        // A boosted round still completes and reduces normally.
        for slot in 0..slots {
            let g: Vec<f32> = (0..200).map(|i| (slot * 200 + i) as f32 * 0.01).collect();
            r.offer(
                slot,
                ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap()),
            )
            .unwrap();
        }
        let merged = pl.finish(r).unwrap();
        assert_eq!(merged.absorbed(), slots);
        pl.recycle(merged);
        // Cool stall rate decays the boost back to the default layout.
        for _ in 0..4 {
            pl.observe_absorb(AbsorbStats::default(), slots);
        }
        let r = pl.begin(&spec, vec![1.0; slots]).unwrap();
        assert_eq!(r.absorb_stats().chosen_shards as usize, shard_count(slots));
        pl.abort(r);
        // A pinned layout keeps the controller inert however hot the
        // counters run.
        let mut pinned = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: 1,
            shard_override: 4,
            adaptive_shards: true,
            ..Default::default()
        });
        for _ in 0..3 {
            pinned.observe_absorb(AbsorbStats { lock_stalls: 1000, ..Default::default() }, slots);
        }
        let r = pinned.begin(&spec, vec![1.0; slots]).unwrap();
        assert_eq!(r.absorb_stats().chosen_shards, 4);
        pinned.abort(r);
    }

    #[test]
    fn dense_accumulator_folds_sparse_and_dense() {
        let spec = UploadSpec::Dense { dim: 6 };
        let uploads = vec![
            ClientUpload::Dense(vec![2.0, 0.0, 0.0, 0.0, 0.0, 2.0]),
            ClientUpload::Sparse(SparseVec::from_pairs(6, vec![(1, 4.0), (5, -2.0)])),
        ];
        let acc = accumulate_uploads(&spec, uploads, &[0.5, 0.5]).unwrap();
        let dense = acc.into_dense().unwrap();
        assert_eq!(dense, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn round_in_flight_is_arrival_order_invariant() {
        // 20 slots > MAX_SHARDS=16, so shards own multiple slots and
        // the in-shard parking buffer actually engages. Offering in
        // reverse (every upload early except the last-discovered ones)
        // must produce bits identical to strictly sequential absorb —
        // for the frame path and the in-memory path alike.
        let mut rng = crate::util::Rng::new(31);
        let slots = 20usize;
        let uploads: Vec<ClientUpload> = (0..slots)
            .map(|_| {
                let g: Vec<f32> = (0..200).map(|_| rng.next_gaussian() as f32).collect();
                ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap())
            })
            .collect();
        let frames: Vec<Vec<u8>> = uploads.iter().map(|u| encode_upload(u, &F32LE)).collect();
        let weights: Vec<f32> = (0..slots).map(|i| 0.1 + 0.01 * i as f32).collect();

        let mut pl = pipeline();
        let seq = pl.begin(&sketch_spec(), weights.clone()).unwrap();
        for (slot, f) in frames.iter().enumerate() {
            seq.offer_frame(slot, f.clone()).unwrap();
            assert_eq!(seq.buffered(), 0, "in-order offers never park");
        }
        let merged_seq = pl.finish(seq).unwrap();
        assert_eq!(merged_seq.absorbed(), slots);

        let rev = pl.begin(&sketch_spec(), weights.clone()).unwrap();
        for (slot, f) in frames.iter().enumerate().rev() {
            rev.offer_frame(slot, f.clone()).unwrap();
        }
        assert!(rev.is_complete());
        let merged_rev = pl.finish(rev).unwrap();
        for (a, b) in merged_seq
            .as_sketch()
            .unwrap()
            .table()
            .iter()
            .zip(merged_rev.as_sketch().unwrap().table())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // In-memory uploads through the same scrambled order match too.
        let mem = pl.begin(&sketch_spec(), weights).unwrap();
        for (slot, u) in uploads.iter().enumerate().rev() {
            mem.offer(slot, u.clone()).unwrap();
        }
        let merged_mem = pl.finish(mem).unwrap();
        for (a, b) in merged_seq
            .as_sketch()
            .unwrap()
            .table()
            .iter()
            .zip(merged_mem.as_sketch().unwrap().table())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Tail shards went back to the pool each time, plus nothing
        // leaked: pool holds exactly the tail shards of the last round.
        assert_eq!(pl.pooled(), shard_count(slots) - 1);
    }

    #[test]
    fn round_in_flight_matches_hand_sharded_absorb() {
        // Reference: the fixed layout, run by hand — shard s absorbs
        // slots s, s+S, ... in order, shards reduce in shard order.
        let mut rng = crate::util::Rng::new(77);
        let slots = 19usize;
        let grads: Vec<Vec<f32>> = (0..slots)
            .map(|_| (0..200).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let weights: Vec<f32> = (0..slots).map(|i| 1.0 / (1.0 + i as f32)).collect();
        let nshards = shard_count(slots);
        let mut shards: Vec<RoundAccum> =
            (0..nshards).map(|_| RoundAccum::new(&sketch_spec()).unwrap()).collect();
        for slot in 0..slots {
            let u = ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &grads[slot]).unwrap());
            shards[shard_of(slot, nshards)].absorb(u, weights[slot]).unwrap();
        }
        reduce_shards_in_place(&mut shards, 1).unwrap();

        let mut pl = pipeline();
        let inflight = pl.begin(&sketch_spec(), weights).unwrap();
        // A scrambled-but-fixed arrival order.
        let mut order: Vec<usize> = (0..slots).collect();
        order.reverse();
        order.swap(0, 7);
        order.swap(3, 11);
        for &slot in &order {
            let u = ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &grads[slot]).unwrap());
            inflight.offer_frame(slot, encode_upload(&u, &F32LE)).unwrap();
        }
        let merged = pl.finish(inflight).unwrap();
        let (by_hand, streamed) = (shards[0].as_sketch().unwrap(), merged.as_sketch().unwrap());
        for (a, b) in by_hand.table().iter().zip(streamed.table()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn round_in_flight_rejects_bad_slots_and_incomplete_rounds() {
        let spec = UploadSpec::Dense { dim: 8 };
        let frame = |v: f32| encode_upload(&ClientUpload::Dense(vec![v; 8]), &F32LE);
        let mut pl = pipeline();
        let r = pl.begin(&spec, vec![1.0; 3]).unwrap();
        assert!(r.offer_frame(3, frame(1.0)).unwrap_err().to_string().contains("out of range"));
        r.offer_frame(1, frame(2.0)).unwrap();
        assert!(r.offer_frame(1, frame(2.0)).unwrap_err().to_string().contains("duplicate"));
        assert!(r
            .offer(1, ClientUpload::Dense(vec![2.0; 8]))
            .unwrap_err()
            .to_string()
            .contains("duplicate"));
        assert_eq!(r.absorbed(), 1);
        // Incomplete finish fails loudly instead of merging a partial
        // sum — and every shard still returns to the pool.
        let err = pl.finish(r).unwrap_err().to_string();
        assert!(err.contains("absorbed 1 of 3"), "{err}");
        assert_eq!(pl.pooled(), shard_count(3));
        // A malformed frame fails the offer and counts nothing.
        let r = pl.begin(&spec, vec![1.0; 2]).unwrap();
        let mut bad = frame(1.0);
        bad[0] = b'X';
        assert!(r.offer_frame(0, bad).is_err());
        assert_eq!(r.absorbed(), 0);
        pl.abort(r);
        // All three accumulators from the first round are pooled again
        // (one sat out the 2-slot round, two came back via abort).
        assert_eq!(pl.pooled(), shard_count(3));
        // Empty rounds are rejected up front.
        assert!(pl.begin(&spec, vec![]).is_err());
    }

    #[test]
    fn finalize_partial_matches_hand_renormalized_merge() {
        use crate::cohort::{DropReason, QuorumPolicy, RoundMembership};
        // 20 slots over 16 shards: slots 2 and 18 share shard 2, so
        // dropping slot 2 leaves slot 18 parked until finalize drains
        // it — the path a full-cohort round never exercises.
        let mut rng = crate::util::Rng::new(41);
        let slots = 20usize;
        let uploads: Vec<ClientUpload> = (0..slots)
            .map(|_| {
                let g: Vec<f32> = (0..200).map(|_| rng.next_gaussian() as f32).collect();
                ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap())
            })
            .collect();
        let weights: Vec<f32> = (0..slots).map(|i| 0.05 + 0.01 * i as f32).collect();
        let dropped = [2usize, 5, 18];
        let arrived: Vec<usize> = (0..slots).filter(|s| !dropped.contains(s)).collect();
        let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();

        // Hand reference: absorb the arrived slots into the fixed shard
        // layout in slot order, reduce, scale by 1/Σλ over the set.
        let nshards = shard_count(slots);
        let mut shards: Vec<RoundAccum> =
            (0..nshards).map(|_| RoundAccum::new(&sketch_spec()).unwrap()).collect();
        for &slot in &arrived {
            shards[shard_of(slot, nshards)]
                .absorb(uploads[slot].clone(), weights[slot])
                .unwrap();
        }
        reduce_shards_in_place(&mut shards, 1).unwrap();
        let lam_sum: f64 = arrived.iter().map(|&s| weights[s] as f64).sum();
        shards[0].scale((1.0 / lam_sum) as f32);

        // Streamed, two opposite arrival orders: identical bits.
        for reverse in [false, true] {
            let mut pl = pipeline();
            let mut m = RoundMembership::new(slots, policy.clone()).unwrap();
            let r = pl.begin(&sketch_spec(), weights.clone()).unwrap();
            let mut order = arrived.clone();
            if reverse {
                order.reverse();
            }
            for &slot in &order {
                r.offer(slot, uploads[slot].clone()).unwrap();
                m.record_arrival(slot);
            }
            for &slot in &dropped {
                m.record_drop(slot, DropReason::Faulted);
            }
            assert!(m.is_settled() && m.quorum_met() && !m.is_full());
            let merged = pl.finalize_partial(r, &m).unwrap();
            assert_eq!(merged.absorbed(), arrived.len());
            let (by_hand, streamed) =
                (shards[0].as_sketch().unwrap(), merged.as_sketch().unwrap());
            for (a, b) in by_hand.table().iter().zip(streamed.table()) {
                assert_eq!(a.to_bits(), b.to_bits(), "reverse={reverse}");
            }
            assert_eq!(pl.pooled(), nshards - 1, "tail shards return to the pool");
        }
    }

    #[test]
    fn finalize_partial_full_membership_defers_to_finish() {
        use crate::cohort::{QuorumPolicy, RoundMembership};
        let spec = UploadSpec::Dense { dim: 8 };
        let upload = |v: f32| ClientUpload::Dense(vec![v; 8]);
        let run = |partial: bool| {
            let mut pl = pipeline();
            let r = pl.begin(&spec, vec![0.3, 0.7]).unwrap();
            r.offer(0, upload(1.0)).unwrap();
            r.offer(1, upload(2.0)).unwrap();
            if partial {
                let mut m =
                    RoundMembership::new(2, QuorumPolicy::new(0.5, 0, 0).unwrap()).unwrap();
                m.record_arrival(0);
                m.record_arrival(1);
                pl.finalize_partial(r, &m).unwrap()
            } else {
                pl.finish(r).unwrap()
            }
        };
        let (full, via_partial) = (run(false), run(true));
        // No renormalization on a full cohort — finish() verbatim, even
        // though Σλ = 1.0 only approximately in floating point.
        for (a, b) in full.as_dense().unwrap().iter().zip(via_partial.as_dense().unwrap()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn finalize_partial_rejects_unmet_quorum_and_membership_mismatch() {
        use crate::cohort::{DropReason, QuorumPolicy, RoundMembership};
        let spec = UploadSpec::Dense { dim: 8 };
        let mut pl = pipeline();
        // Quorum not met: 1 of 3 arrived under a 0.9 quorum.
        let r = pl.begin(&spec, vec![1.0; 3]).unwrap();
        r.offer(0, ClientUpload::Dense(vec![1.0; 8])).unwrap();
        let mut m = RoundMembership::new(3, QuorumPolicy::new(0.9, 0, 0).unwrap()).unwrap();
        m.record_arrival(0);
        m.record_drop(1, DropReason::Faulted);
        m.record_drop(2, DropReason::Deadline);
        let err = pl.finalize_partial(r, &m).unwrap_err().to_string();
        assert!(err.contains("quorum not met"), "{err}");
        assert_eq!(pl.pooled(), shard_count(3), "shards still return to the pool");
        // Membership that disagrees with the offered slots is a driver
        // bug and fails loudly.
        let r = pl.begin(&spec, vec![1.0; 3]).unwrap();
        r.offer(0, ClientUpload::Dense(vec![1.0; 8])).unwrap();
        let mut m = RoundMembership::new(3, QuorumPolicy::new(0.3, 0, 0).unwrap()).unwrap();
        m.record_arrival(1); // claims slot 1 arrived; only slot 0 was offered
        m.record_drop(0, DropReason::Faulted);
        m.record_drop(2, DropReason::Faulted);
        let err = pl.finalize_partial(r, &m).unwrap_err().to_string();
        assert!(err.contains("membership records"), "{err}");
        // Slot-count mismatch.
        let r = pl.begin(&spec, vec![1.0; 3]).unwrap();
        let m = RoundMembership::new(2, QuorumPolicy::strict()).unwrap();
        assert!(pl.finalize_partial(r, &m).is_err());
    }

    #[test]
    fn corrupt_parked_frame_fails_its_own_offer() {
        // Slot 16 shares shard 0 with slot 0 (17 slots → 16 shards), so
        // an early offer of slot 16 parks. A corrupt frame must fail
        // slot 16's own offer — not slot 0's later arrival, which
        // would blame (and burn) the wrong peer in a quorum round.
        // Validation runs before any lock, so the rejection never
        // touches round state at all.
        let spec = UploadSpec::Dense { dim: 8 };
        let good = |v: f32| encode_upload(&ClientUpload::Dense(vec![v; 8]), &F32LE);
        let mut pl = pipeline();
        let r = pl.begin(&spec, vec![1.0; 17]).unwrap();
        let mut bad = good(1.0);
        bad[0] = b'X';
        let err = r.offer_frame(16, bad).unwrap_err().to_string();
        assert!(err.contains("validating upload frame for slot 16"), "{err}");
        assert_eq!(r.buffered(), 0, "a rejected frame is not parked");
        // Wrong-shape frames are caught at park time too.
        let wrong_dim = encode_upload(&ClientUpload::Dense(vec![0.0; 4]), &F32LE);
        assert!(r.offer_frame(16, wrong_dim).is_err());
        // The slot is not burned: a healthy re-offer parks…
        r.offer_frame(16, good(2.0)).unwrap();
        assert_eq!(r.buffered(), 1);
        // …and the predecessor's arrival drains it cleanly.
        r.offer_frame(0, good(3.0)).unwrap();
        assert_eq!(r.absorbed(), 2);
        assert_eq!(r.buffered(), 0);
        pl.abort(r);
    }

    #[test]
    fn failed_absorb_unmarks_the_slot_for_retry() {
        let spec = UploadSpec::Dense { dim: 8 };
        let good = |v: f32| encode_upload(&ClientUpload::Dense(vec![v; 8]), &F32LE);
        let mut pl = pipeline();
        let r = pl.begin(&spec, vec![0.5; 2]).unwrap();
        let mut bad = good(1.0);
        bad[0] = b'X';
        assert!(r.offer_frame(0, bad).is_err());
        assert_eq!(r.absorbed(), 0);
        // The faulted slot may be offered again — the transport's
        // retry/reassignment path re-delivers it from another worker.
        r.offer_frame(0, good(1.0)).unwrap();
        r.offer(1, ClientUpload::Dense(vec![2.0; 8])).unwrap();
        assert!(r.is_complete());
        let merged = pl.finish(r).unwrap();
        assert_eq!(merged.as_dense().unwrap()[0], 1.5);
    }

    #[test]
    fn shard_layout_is_parallelism_invariant() {
        assert_eq!(shard_count(1), 1);
        assert_eq!(shard_count(7), 7);
        assert_eq!(shard_count(MAX_SHARDS), MAX_SHARDS);
        assert_eq!(shard_count(100), MAX_SHARDS);
        assert_eq!(shard_count(0), 1);
        assert_eq!(shard_of(0, 5), 0);
        assert_eq!(shard_of(12, 5), 2);
        assert_eq!(shard_of(12, 16), 12);
        assert!(resolve_parallelism(0) >= 1);
        assert_eq!(resolve_parallelism(3), 3);
    }

    #[test]
    fn kind_mismatches_are_rejected() {
        let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
        assert!(acc.absorb(ClientUpload::Dense(vec![0.0; 200]), 1.0).is_err());
        let mut acc = RoundAccum::new(&UploadSpec::Dense { dim: 10 }).unwrap();
        assert!(acc
            .absorb(
                ClientUpload::Sketch(CountSketch::zeros(3, 128, 10, 1).unwrap()),
                1.0
            )
            .is_err());
        assert!(acc.absorb(ClientUpload::Dense(vec![0.0; 4]), 1.0).is_err());
        // wrong-geometry sketch upload
        let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
        assert!(acc
            .absorb(
                ClientUpload::Sketch(CountSketch::zeros(3, 128, 200, 999).unwrap()),
                1.0
            )
            .is_err());
    }

    /// Simulate one relay: fold `chain_slots`' uploads (global λ, local
    /// slot order = ascending global slot order) through a 1-shard
    /// pipeline and encode the merged partial sum as a lossless frame.
    fn relay_merge(
        spec: &UploadSpec,
        frames: &[Vec<u8>],
        weights: &[f32],
        chain_slots: &[usize],
        arrived: &[usize],
    ) -> Option<Vec<u8>> {
        let mut pl = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: 1,
            shard_override: 1,
            ..Default::default()
        });
        let lams: Vec<f32> = chain_slots.iter().map(|&s| weights[s]).collect();
        let r = pl.begin(spec, lams).unwrap();
        for (local, &slot) in chain_slots.iter().enumerate() {
            if arrived.contains(&slot) {
                r.offer_frame_bytes(local, &frames[slot]).unwrap();
            }
        }
        let merged = pl.finalize_subtree(r).unwrap()?;
        Some(match spec {
            UploadSpec::Sketch { .. } => {
                crate::wire::encode_sketch_frame(merged.as_sketch().unwrap(), &F32LE)
            }
            UploadSpec::Dense { .. } => {
                crate::wire::encode_dense_frame(merged.as_dense().unwrap(), &F32LE)
            }
        })
    }

    #[test]
    fn chain_frames_reassociate_to_flat_bits() {
        // The tree-determinism contract at the unit level: R relays,
        // each owning one shard chain of a shard_override=R layout,
        // merge their chains through 1-shard pipelines; the root
        // absorbs the merged frames with weight 1.0. Bits must equal a
        // flat per-slot round over the same layout — for sketch and
        // dense specs, full and partial (quorum) membership.
        use crate::cohort::{DropReason, QuorumPolicy, RoundMembership};
        let mut rng = crate::util::Rng::new(53);
        let slots = 9usize;
        let nrelays = 3usize;
        let weights: Vec<f32> = (0..slots).map(|i| 0.1 + 0.01 * i as f32).collect();
        for spec in [sketch_spec(), UploadSpec::Dense { dim: 200 }] {
            let uploads: Vec<ClientUpload> = (0..slots)
                .map(|_| {
                    let g: Vec<f32> = (0..200).map(|_| rng.next_gaussian() as f32).collect();
                    match spec {
                        UploadSpec::Sketch { .. } => {
                            ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap())
                        }
                        UploadSpec::Dense { .. } => ClientUpload::Dense(g),
                    }
                })
                .collect();
            let frames: Vec<Vec<u8>> =
                uploads.iter().map(|u| encode_upload(u, &F32LE)).collect();
            let opts = PipelineOptions {
                reduce_parallelism: 1,
                shard_override: nrelays,
                ..Default::default()
            };
            for dropped in [vec![], vec![4usize]] {
                let arrived: Vec<usize> =
                    (0..slots).filter(|s| !dropped.contains(s)).collect();
                let policy = QuorumPolicy::new(0.5, 0, 0).unwrap();
                // Flat reference over the same fixed layout.
                let mut flat = RoundPipeline::new(opts.clone());
                let r = flat.begin(&spec, weights.clone()).unwrap();
                let mut m = RoundMembership::new(slots, policy.clone()).unwrap();
                for &slot in &arrived {
                    r.offer_frame_bytes(slot, &frames[slot]).unwrap();
                    m.record_arrival(slot);
                }
                for &slot in &dropped {
                    m.record_drop(slot, DropReason::Disconnected);
                }
                let flat_merged = if dropped.is_empty() {
                    flat.finish(r).unwrap()
                } else {
                    flat.finalize_partial(r, &m).unwrap()
                };
                // Tree: one merged frame per chain, absorbed at weight
                // 1.0 into the same layout.
                let mut root = RoundPipeline::new(opts.clone());
                let r = root.begin(&spec, weights.clone()).unwrap();
                for chain in 0..nrelays {
                    let chain_slots: Vec<usize> =
                        (chain..slots).step_by(nrelays).collect();
                    let chain_arrived: Vec<usize> = chain_slots
                        .iter()
                        .copied()
                        .filter(|s| arrived.contains(s))
                        .collect();
                    if let Some(frame) =
                        relay_merge(&spec, &frames, &weights, &chain_slots, &chain_arrived)
                    {
                        r.offer_chain_frame(chain, &chain_arrived, &frame).unwrap();
                    }
                }
                assert_eq!(r.absorbed(), arrived.len());
                let tree_merged = if dropped.is_empty() {
                    root.finish(r).unwrap()
                } else {
                    root.finalize_partial(r, &m).unwrap()
                };
                let (a, b) = match spec {
                    UploadSpec::Sketch { .. } => (
                        flat_merged.as_sketch().unwrap().table().to_vec(),
                        tree_merged.as_sketch().unwrap().table().to_vec(),
                    ),
                    UploadSpec::Dense { .. } => (
                        flat_merged.as_dense().unwrap().to_vec(),
                        tree_merged.as_dense().unwrap().to_vec(),
                    ),
                };
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "spec {spec:?} dropped {dropped:?}");
                }
            }
        }
    }

    #[test]
    fn tiered_reduce_rebuilds_the_tree_association() {
        // f32 addition is not associative; pick magnitudes where the
        // flat fold ((s0+s1)+s2)+s3 and the tree fold (s0+s2)+(s1+s3)
        // provably differ, then check reduce_shards_tree reproduces the
        // tree association exactly (and that the flat fold does not).
        let spec = UploadSpec::Dense { dim: 2 };
        let vals = [1.0e8f32, 1.0, -1.0e8, 1.0];
        let make = |v: f32| {
            let mut a = RoundAccum::new(&spec).unwrap();
            a.absorb(ClientUpload::Dense(vec![v; 2]), 1.0).unwrap();
            a
        };
        let accs: Vec<RoundAccum> = vals.iter().map(|&v| make(v)).collect();
        let mut spares = Vec::new();
        let merged = reduce_shards_tree(accs, &[2, 2], 1, &mut spares).unwrap();
        let tree = ((vals[0] + vals[2]) + (vals[1] + vals[3])) as f32;
        let flat = (((vals[0] + vals[1]) + vals[2]) + vals[3]) as f32;
        assert_eq!(merged.as_dense().unwrap()[0].to_bits(), tree.to_bits());
        assert_ne!(tree.to_bits(), flat.to_bits(), "magnitudes failed to expose reassociation");
        assert_eq!(spares.len(), 3, "every drained shard returns for reuse");
        assert_eq!(merged.absorbed, 4);
        // A single tier is the flat fold verbatim.
        let accs: Vec<RoundAccum> = vals.iter().map(|&v| make(v)).collect();
        let merged = reduce_shards_tree(accs, &[4], 1, &mut spares).unwrap();
        assert_eq!(merged.as_dense().unwrap()[0].to_bits(), flat.to_bits());
        // Layout violations are loud.
        let accs: Vec<RoundAccum> = vals.iter().map(|&v| make(v)).collect();
        assert!(reduce_shards_tree(accs, &[3, 2], 1, &mut spares).is_err());
        let accs: Vec<RoundAccum> = vals.iter().map(|&v| make(v)).collect();
        assert!(reduce_shards_tree(accs, &[2, 0], 1, &mut spares).is_err());
    }

    #[test]
    fn tier_layouts_pin_the_pipeline_shape() {
        let spec = UploadSpec::Dense { dim: 4 };
        let frame = |v: f32| crate::wire::encode_dense_frame(&vec![v; 4], &F32LE);
        let tiered = PipelineOptions {
            reduce_parallelism: 1,
            shard_override: 0,
            reduce_tiers: vec![2, 2],
            ..Default::default()
        };
        // Fewer slots than leaves cannot fill the layout.
        let mut pl = RoundPipeline::new(tiered.clone());
        assert!(pl.begin(&spec, vec![1.0; 3]).is_err());
        // shard_override must agree with the tier product.
        let mut pl = RoundPipeline::new(PipelineOptions {
            shard_override: 3,
            ..tiered.clone()
        });
        assert!(pl.begin(&spec, vec![1.0; 8]).is_err());
        // The tiered pipeline merges per-slot uploads with the tree
        // association: slot → shard is slot % 4, groups are shards
        // {0,2} and {1,3}.
        let vals = [1.0e8f32, 1.0, -1.0e8, 1.0];
        let mut pl = RoundPipeline::new(tiered);
        let r = pl.begin(&spec, vec![1.0; 4]).unwrap();
        for (slot, &v) in vals.iter().enumerate() {
            r.offer_frame_bytes(slot, &frame(v)).unwrap();
        }
        let merged = pl.finish(r).unwrap();
        let tree = (vals[0] + vals[2]) + (vals[1] + vals[3]);
        assert_eq!(merged.as_dense().unwrap()[0].to_bits(), tree.to_bits());
    }

    #[test]
    fn offer_chain_frame_validates_and_releases_on_failure() {
        let spec = UploadSpec::Dense { dim: 8 };
        let dense_frame =
            |v: f32| crate::wire::encode_dense_frame(&vec![v; 8], &F32LE);
        let opts =
            PipelineOptions { reduce_parallelism: 1, shard_override: 2, ..Default::default() };
        let mut pl = RoundPipeline::new(opts);
        let r = pl.begin(&spec, vec![1.0; 6]).unwrap();
        // Chain / slot-list structural violations.
        assert!(r.offer_chain_frame(2, &[0], &dense_frame(1.0)).is_err(), "chain out of range");
        assert!(r.offer_chain_frame(0, &[], &dense_frame(1.0)).is_err(), "empty arrival list");
        assert!(r.offer_chain_frame(0, &[1], &dense_frame(1.0)).is_err(), "slot 1 is chain 1's");
        assert!(r.offer_chain_frame(0, &[4, 2], &dense_frame(1.0)).is_err(), "out of order");
        assert!(r.offer_chain_frame(0, &[0, 8], &dense_frame(1.0)).is_err(), "slot range");
        // Frame-level violations never claim a slot.
        let mut bad = dense_frame(1.0);
        bad[0] = b'X';
        assert!(r.offer_chain_frame(0, &[0, 2], &bad).is_err(), "corrupt frame");
        let lossy = crate::wire::encode_dense_frame(&vec![1.0; 8], &crate::wire::F16LE);
        let err = r.offer_chain_frame(0, &[0, 2], &lossy).unwrap_err().to_string();
        assert!(err.contains("f32le"), "{err}");
        let sparse = encode_upload(
            &ClientUpload::Sparse(SparseVec::from_pairs(8, vec![(1, 2.0)])),
            &F32LE,
        );
        assert!(r.offer_chain_frame(0, &[0, 2], &sparse).is_err(), "sparse merged frame");
        assert_eq!(r.absorbed(), 0);
        // A healthy chain frame lands…
        r.offer_chain_frame(0, &[0, 2], &dense_frame(2.0)).unwrap();
        assert_eq!(r.absorbed(), 2);
        // …and slot 4 (released by every failure above) is still
        // deliverable — but not via a second merged frame for the same
        // chain, whose shard is no longer untouched.
        let err = r.offer_chain_frame(0, &[4], &dense_frame(1.0)).unwrap_err().to_string();
        assert!(err.contains("whole chain"), "{err}");
        r.offer_chain_frame(1, &[1, 3], &dense_frame(3.0)).unwrap();
        let err = r.offer_chain_frame(1, &[5], &dense_frame(9.9)).unwrap_err().to_string();
        assert!(err.contains("whole chain"), "{err}");
        pl.abort(r);
        // Per-slot uploads poison a chain for merged delivery.
        let r = pl.begin(&spec, vec![1.0; 6]).unwrap();
        r.offer_frame(0, dense_frame(1.0)).unwrap();
        let err = r.offer_chain_frame(0, &[2, 4], &dense_frame(1.0)).unwrap_err().to_string();
        assert!(err.contains("per-slot uploads"), "{err}");
        // The failure released slots 2 and 4 — per-slot delivery still
        // works, so the round can complete.
        r.offer_frame(2, dense_frame(1.0)).unwrap();
        r.offer_frame(4, dense_frame(1.0)).unwrap();
        // Duplicate slot claimed by two tiers: slot 3 already arrived
        // per-slot (parked early behind slot 1), so a chain-1 merged
        // frame covering it is a loud duplicate that releases its fresh
        // claim on slot 1.
        r.offer_frame(3, dense_frame(1.0)).unwrap();
        assert_eq!(r.buffered(), 1);
        let err =
            r.offer_chain_frame(1, &[1, 3, 5], &dense_frame(2.0)).unwrap_err().to_string();
        assert!(err.contains("already delivered"), "{err}");
        // Slot 1's claim was released: its arrival absorbs and drains
        // parked slot 3, and the round still completes.
        r.offer_frame(1, dense_frame(1.0)).unwrap();
        r.offer_frame(5, dense_frame(1.0)).unwrap();
        assert!(r.is_complete());
        let merged = pl.finish(r).unwrap();
        assert_eq!(merged.as_dense().unwrap()[0], 6.0);
    }

    #[test]
    fn finalize_subtree_handles_empty_and_parked_rounds() {
        let spec = UploadSpec::Dense { dim: 8 };
        let frame = |v: f32| crate::wire::encode_dense_frame(&vec![v; 8], &F32LE);
        let mut pl = RoundPipeline::new(PipelineOptions {
            reduce_parallelism: 1,
            shard_override: 1,
            ..Default::default()
        });
        // Zero-participant subtree: nothing arrived → Ok(None), shard
        // returns to the pool.
        let r = pl.begin(&spec, vec![1.0; 3]).unwrap();
        assert!(pl.finalize_subtree(r).unwrap().is_none());
        assert_eq!(pl.pooled(), 1);
        // A parked arrival whose predecessor dropped still merges: the
        // drain absorbs it in slot order before reducing.
        let r = pl.begin(&spec, vec![0.5, 0.25, 2.0]).unwrap();
        r.offer_frame(2, frame(1.0)).unwrap();
        assert_eq!(r.buffered(), 1, "slot 2 parks behind the dropped slots");
        let merged = pl.finalize_subtree(r).unwrap().expect("one slot arrived");
        assert_eq!(merged.absorbed(), 1);
        assert_eq!(merged.as_dense().unwrap()[0], 2.0, "λ₂ applied");
        pl.recycle(merged);
    }
}
