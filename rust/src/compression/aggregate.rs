//! Incremental, shardable upload aggregation.
//!
//! Every strategy's fan-in is a weighted sum `Σ_i λ_i · upload_i`
//! (see `compression` module docs), so the merge machinery lives here
//! once, strategy-agnostic: a [`RoundAccum`] absorbs uploads as they
//! arrive — no `Vec<ClientUpload>` of the whole cohort is ever
//! buffered — and accumulators produced by different workers reduce
//! with [`reduce_shards`] in a fixed order.
//!
//! Determinism contract: for a fixed *shard layout* (how slots are
//! assigned to shards, fixed by the engine independently of thread
//! count), the merged result is bitwise identical no matter how many
//! workers produced the shards, because (a) each shard absorbs its
//! slots in increasing slot order, and (b) shards are reduced strictly
//! in shard order. Floating-point addition order is therefore a pure
//! function of the layout, never of scheduling.

use anyhow::{bail, Result};

use crate::compression::{ClientUpload, RoundUpdate, ServerAggregator, UploadSpec};
use crate::sketch::CountSketch;

enum Acc {
    Sketch(CountSketch),
    Dense(Vec<f32>),
}

/// A partial weighted sum of uploads (one worker's scratch, or the
/// whole round's merged result).
pub struct RoundAccum {
    acc: Acc,
    absorbed: usize,
}

impl RoundAccum {
    pub fn new(spec: &UploadSpec) -> Result<RoundAccum> {
        let acc = match spec {
            UploadSpec::Sketch { rows, cols, dim, seed } => {
                Acc::Sketch(CountSketch::zeros(*rows, *cols, *dim, *seed)?)
            }
            UploadSpec::Dense { dim } => Acc::Dense(vec![0f32; *dim]),
        };
        Ok(RoundAccum { acc, absorbed: 0 })
    }

    /// Number of uploads absorbed (across merges).
    pub fn absorbed(&self) -> usize {
        self.absorbed
    }

    /// `self += weight * upload`. Consumes the upload — nothing is
    /// buffered.
    pub fn absorb(&mut self, upload: ClientUpload, weight: f32) -> Result<()> {
        match (&mut self.acc, upload) {
            (Acc::Sketch(acc), ClientUpload::Sketch(s)) => {
                if s.rows() != acc.rows()
                    || s.cols() != acc.cols()
                    || s.seed() != acc.seed()
                    || s.dim() != acc.dim()
                {
                    bail!(
                        "upload sketch {}x{} (seed {}, dim {}) incompatible with \
                         aggregator {}x{} (seed {}, dim {})",
                        s.rows(), s.cols(), s.seed(), s.dim(),
                        acc.rows(), acc.cols(), acc.seed(), acc.dim()
                    );
                }
                acc.add_scaled(&s, weight);
            }
            (Acc::Sketch(_), _) => bail!("aggregator expects sketch uploads"),
            (Acc::Dense(acc), ClientUpload::Dense(g)) => {
                if g.len() != acc.len() {
                    bail!("dense upload dim {} != aggregator dim {}", g.len(), acc.len());
                }
                for (a, &x) in acc.iter_mut().zip(&g) {
                    *a += weight * x;
                }
            }
            (Acc::Dense(acc), ClientUpload::Sparse(sv)) => {
                if sv.dim != acc.len() {
                    bail!("sparse upload dim {} != aggregator dim {}", sv.dim, acc.len());
                }
                sv.add_into(acc, weight);
            }
            (Acc::Dense(_), ClientUpload::Sketch(_)) => {
                bail!("aggregator expects dense/sparse uploads, got a sketch")
            }
        }
        self.absorbed += 1;
        Ok(())
    }

    /// The merged sketch (fetchsgd). Errors for dense aggregators.
    pub fn into_sketch(self) -> Result<CountSketch> {
        match self.acc {
            Acc::Sketch(s) => Ok(s),
            Acc::Dense(_) => bail!("round accumulator holds a dense sum, not a sketch"),
        }
    }

    /// The merged dense vector (all baselines). Errors for sketch
    /// aggregators.
    pub fn into_dense(self) -> Result<Vec<f32>> {
        match self.acc {
            Acc::Dense(v) => Ok(v),
            Acc::Sketch(_) => bail!("round accumulator holds a sketch, not a dense sum"),
        }
    }
}

/// Fan-in: reduce per-worker shard accumulators **in slice order** into
/// one merged accumulator. Sketch shards reduce through
/// [`CountSketch::merge_shards`]; dense shards fold elementwise.
pub fn reduce_shards(shards: Vec<RoundAccum>) -> Result<RoundAccum> {
    let mut iter = shards.into_iter();
    let Some(first) = iter.next() else {
        bail!("reduce_shards: no shards");
    };
    let mut absorbed = first.absorbed;
    match first.acc {
        Acc::Sketch(mut base) => {
            let mut rest = Vec::new();
            for sh in iter {
                absorbed += sh.absorbed;
                match sh.acc {
                    Acc::Sketch(s) => rest.push(s),
                    Acc::Dense(_) => bail!("mixed shard kinds in reduce_shards"),
                }
            }
            base.merge_shards(&rest);
            Ok(RoundAccum { acc: Acc::Sketch(base), absorbed })
        }
        Acc::Dense(mut base) => {
            for sh in iter {
                absorbed += sh.absorbed;
                match sh.acc {
                    Acc::Dense(v) => {
                        if v.len() != base.len() {
                            bail!("shard dim mismatch in reduce_shards");
                        }
                        for (a, &b) in base.iter_mut().zip(&v) {
                            *a += b;
                        }
                    }
                    Acc::Sketch(_) => bail!("mixed shard kinds in reduce_shards"),
                }
            }
            Ok(RoundAccum { acc: Acc::Dense(base), absorbed })
        }
    }
}

/// Sequential convenience: absorb `uploads[i]` with `weights[i]`, in
/// order, into a fresh accumulator. Used by strategy unit tests and the
/// server-cost benches; the trainer goes through the round engine
/// instead.
pub fn accumulate_uploads(
    spec: &UploadSpec,
    uploads: Vec<ClientUpload>,
    weights: &[f32],
) -> Result<RoundAccum> {
    if uploads.len() != weights.len() {
        bail!("{} uploads but {} weights", uploads.len(), weights.len());
    }
    let mut acc = RoundAccum::new(spec)?;
    for (u, &lam) in uploads.into_iter().zip(weights) {
        acc.absorb(u, lam)?;
    }
    Ok(acc)
}

/// Sequential convenience driving one full server round —
/// `begin_round → absorb each upload in order → finish` — exactly the
/// pipeline the round engine runs in sharded form. Used by strategy
/// unit tests and the server-cost benches so the contract lives in one
/// place.
pub fn run_server_round(
    agg: &mut dyn ServerAggregator,
    client_sizes: &[f32],
    uploads: Vec<ClientUpload>,
    w: &mut [f32],
    lr: f32,
) -> Result<RoundUpdate> {
    let weights = agg.begin_round(client_sizes);
    let merged = accumulate_uploads(&agg.upload_spec(), uploads, &weights)?;
    agg.finish(merged, w, lr)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::topk::SparseVec;

    fn sketch_spec() -> UploadSpec {
        UploadSpec::Sketch { rows: 3, cols: 128, dim: 200, seed: 11 }
    }

    #[test]
    fn sketch_absorb_matches_direct_weighted_merge() {
        let mut rng = crate::util::Rng::new(5);
        let grads: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..200).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let uploads: Vec<ClientUpload> = grads
            .iter()
            .map(|g| ClientUpload::Sketch(CountSketch::encode(3, 128, 11, g).unwrap()))
            .collect();
        let acc = accumulate_uploads(&sketch_spec(), uploads, &[0.25; 4]).unwrap();
        assert_eq!(acc.absorbed(), 4);
        let merged = acc.into_sketch().unwrap();

        let mut direct = CountSketch::zeros(3, 128, 200, 11).unwrap();
        for g in &grads {
            direct.add_scaled(&CountSketch::encode(3, 128, 11, g).unwrap(), 0.25);
        }
        for (a, b) in merged.table().iter().zip(direct.table()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sharded_reduce_is_bitwise_stable_across_layout_reuse() {
        // Same shard layout, different "thread counts" is a no-op at
        // this layer: reducing the same shard list twice is identical.
        let mut rng = crate::util::Rng::new(9);
        let make_shards = |rng: &mut crate::util::Rng| {
            (0..3)
                .map(|_| {
                    let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
                    for _ in 0..2 {
                        let g: Vec<f32> =
                            (0..200).map(|_| rng.next_gaussian() as f32).collect();
                        acc.absorb(
                            ClientUpload::Sketch(CountSketch::encode(3, 128, 11, &g).unwrap()),
                            0.5,
                        )
                        .unwrap();
                    }
                    acc
                })
                .collect::<Vec<_>>()
        };
        let a = reduce_shards(make_shards(&mut rng)).unwrap();
        let mut rng = crate::util::Rng::new(9);
        let b = reduce_shards(make_shards(&mut rng)).unwrap();
        assert_eq!(a.absorbed(), 6);
        let (ta, tb) = (a.into_sketch().unwrap(), b.into_sketch().unwrap());
        for (x, y) in ta.table().iter().zip(tb.table()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn dense_accumulator_folds_sparse_and_dense() {
        let spec = UploadSpec::Dense { dim: 6 };
        let uploads = vec![
            ClientUpload::Dense(vec![2.0, 0.0, 0.0, 0.0, 0.0, 2.0]),
            ClientUpload::Sparse(SparseVec::from_pairs(6, vec![(1, 4.0), (5, -2.0)])),
        ];
        let acc = accumulate_uploads(&spec, uploads, &[0.5, 0.5]).unwrap();
        let dense = acc.into_dense().unwrap();
        assert_eq!(dense, vec![1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn kind_mismatches_are_rejected() {
        let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
        assert!(acc.absorb(ClientUpload::Dense(vec![0.0; 200]), 1.0).is_err());
        let mut acc = RoundAccum::new(&UploadSpec::Dense { dim: 10 }).unwrap();
        assert!(acc
            .absorb(
                ClientUpload::Sketch(CountSketch::zeros(3, 128, 10, 1).unwrap()),
                1.0
            )
            .is_err());
        assert!(acc.absorb(ClientUpload::Dense(vec![0.0; 4]), 1.0).is_err());
        // wrong-geometry sketch upload
        let mut acc = RoundAccum::new(&sketch_spec()).unwrap();
        assert!(acc
            .absorb(
                ClientUpload::Sketch(CountSketch::zeros(3, 128, 200, 999).unwrap()),
                1.0
            )
            .is_err());
    }
}
