//! FedAvg (McMahan et al. 2016) — the local-steps baseline.
//!
//! Each client runs `K` local SGD steps (the `fedavg_k{K}` artifact: a
//! `lax.scan` over pre-batched local data, entirely inside one HLO
//! execution) and uploads the dense model delta; the server averages
//! deltas weighted by local dataset size (paper §2.1) and applies them,
//! optionally through a global momentum buffer (§5's ρ_g sweep).
//!
//! Communication: dense in both directions. FedAvg's compression in the
//! paper comes from running fewer global epochs — the experiment driver
//! sweeps `rounds` accordingly and rescales the lr schedule in the
//! iteration dimension (§5).

use anyhow::Result;

use crate::compression::{ClientResult, ClientUpload, RoundUpdate, Strategy};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_fedavg, Batch};
use crate::runtime::Tensor;

pub struct FedAvg {
    dim: usize,
    local_steps: usize,
    rho_g: f32,
    momentum: Vec<f32>,
    /// per-upload weights (client dataset sizes), set by the trainer
    /// before server_round via `set_round_weights`.
    round_weights: Vec<f32>,
}

impl FedAvg {
    pub fn new(dim: usize, local_steps: usize, rho_g: f32) -> Self {
        FedAvg { dim, local_steps, rho_g, momentum: vec![0f32; dim], round_weights: Vec::new() }
    }

    /// Weight this round's uploads by local dataset size (FedAvg's
    /// weighted average). Must align with the upload order.
    pub fn set_round_weights(&mut self, weights: Vec<f32>) {
        self.round_weights = weights;
    }
}

impl Strategy for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn wants_stacked_batches(&self) -> Option<usize> {
        Some(self.local_steps)
    }

    fn begin_round(&mut self, client_sizes: &[f32]) {
        self.set_round_weights(client_sizes.to_vec());
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        _batch: &Batch,
        _client: usize,
        stacked: Option<(Tensor, Tensor, Tensor)>,
        lr: f32,
    ) -> Result<ClientResult> {
        let (xs, ys, masks) = stacked.expect("fedavg requires stacked local batches");
        let exe = artifacts.executable(&TaskArtifacts::fedavg_kind(self.local_steps))?;
        let (loss, delta) = run_fedavg(&exe, w, xs, ys, masks, lr)?;
        Ok(ClientResult { loss, upload: ClientUpload::Dense(delta) })
    }

    fn server_round(
        &mut self,
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
        _lr: f32,
    ) -> Result<RoundUpdate> {
        let n = uploads.len();
        let weights: Vec<f32> = if self.round_weights.len() == n {
            let total: f32 = self.round_weights.iter().sum();
            self.round_weights.iter().map(|&x| x / total.max(1e-9)).collect()
        } else {
            vec![1.0 / n.max(1) as f32; n]
        };
        let mut mean = vec![0f32; self.dim];
        for (u, wt) in uploads.into_iter().zip(weights) {
            match u {
                ClientUpload::Dense(delta) => {
                    for (m, &d) in mean.iter_mut().zip(&delta) {
                        *m += wt * d;
                    }
                }
                _ => anyhow::bail!("fedavg expects dense delta uploads"),
            }
        }
        self.round_weights.clear();
        if self.rho_g > 0.0 {
            for (m, &d) in self.momentum.iter_mut().zip(&mean) {
                *m = self.rho_g * *m + d;
            }
            for (wi, &m) in w.iter_mut().zip(&self.momentum) {
                *wi -= m;
            }
        } else {
            for (wi, &d) in w.iter_mut().zip(&mean) {
                *wi -= d;
            }
        }
        Ok(RoundUpdate::Dense)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_average_of_deltas() {
        let mut s = FedAvg::new(2, 2, 0.0);
        let mut w = vec![0f32; 2];
        s.set_round_weights(vec![3.0, 1.0]);
        let u = vec![
            ClientUpload::Dense(vec![4.0, 0.0]),
            ClientUpload::Dense(vec![0.0, 4.0]),
        ];
        s.server_round(u, &mut w, 1.0).unwrap();
        assert_eq!(w, vec![-3.0, -1.0]);
    }

    #[test]
    fn unweighted_fallback() {
        let mut s = FedAvg::new(1, 2, 0.0);
        let mut w = vec![0f32];
        let u = vec![ClientUpload::Dense(vec![2.0]), ClientUpload::Dense(vec![4.0])];
        s.server_round(u, &mut w, 1.0).unwrap();
        assert_eq!(w, vec![-3.0]);
    }

    #[test]
    fn wants_stacked() {
        let s = FedAvg::new(1, 5, 0.0);
        assert_eq!(s.wants_stacked_batches(), Some(5));
    }
}
