//! FedAvg (McMahan et al. 2016) — the local-steps baseline.
//!
//! Each client runs `K` local SGD steps (the `fedavg_k{K}` artifact: a
//! `lax.scan` over pre-batched local data, entirely inside one HLO
//! execution) and uploads the dense model delta; the server averages
//! deltas weighted by local dataset size (paper §2.1) and applies them,
//! optionally through a global momentum buffer (§5's ρ_g sweep). The
//! dataset-size weighting is exactly the per-slot weight vector
//! [`FedAvgServer::begin_round`] hands the round engine.
//!
//! Communication: dense in both directions. FedAvg's compression in the
//! paper comes from running fewer global epochs — the experiment driver
//! sweeps `rounds` accordingly and rescales the lr schedule in the
//! iteration dimension (§5).

use anyhow::Result;

use crate::compression::aggregate::RoundAccum;
use crate::compression::{
    ClientCompute, ClientResult, ClientUpload, RoundUpdate, ServerAggregator, UploadSpec,
};
use crate::runtime::artifact::TaskArtifacts;
use crate::runtime::exec::{run_fedavg, Batch};
use crate::runtime::Tensor;

/// Client half: K local SGD steps inside one HLO execution.
pub struct FedAvgClient {
    local_steps: usize,
}

impl FedAvgClient {
    pub fn new(local_steps: usize) -> Self {
        FedAvgClient { local_steps }
    }
}

impl ClientCompute for FedAvgClient {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn wants_stacked_batches(&self) -> Option<usize> {
        Some(self.local_steps)
    }

    fn client_round(
        &self,
        artifacts: &TaskArtifacts,
        w: &[f32],
        _batch: &Batch,
        _client: usize,
        stacked: Option<(Tensor, Tensor, Tensor)>,
        lr: f32,
    ) -> Result<ClientResult> {
        let (xs, ys, masks) = stacked.expect("fedavg requires stacked local batches");
        let exe = artifacts.executable(&TaskArtifacts::fedavg_kind(self.local_steps))?;
        let (loss, delta) = run_fedavg(&exe, w, xs, ys, masks, lr)?;
        Ok(ClientResult { loss, upload: ClientUpload::Dense(delta) })
    }
}

/// Server half: dataset-size-weighted delta average + optional global
/// momentum.
pub struct FedAvgServer {
    dim: usize,
    rho_g: f32,
    momentum: Vec<f32>,
}

impl FedAvgServer {
    pub fn new(dim: usize, rho_g: f32) -> Self {
        FedAvgServer { dim, rho_g, momentum: vec![0f32; dim] }
    }
}

impl ServerAggregator for FedAvgServer {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn begin_round(&mut self, client_sizes: &[f32]) -> Vec<f32> {
        // FedAvg's weighted average: λ_i = n_i / Σ n_j.
        let total: f32 = client_sizes.iter().sum();
        if total > 0.0 {
            client_sizes.iter().map(|&x| x / total).collect()
        } else {
            let n = client_sizes.len().max(1) as f32;
            vec![1.0 / n; client_sizes.len()]
        }
    }

    fn upload_spec(&self) -> UploadSpec {
        UploadSpec::Dense { dim: self.dim }
    }

    fn finish(&mut self, merged: &RoundAccum, _lr: f32) -> Result<RoundUpdate> {
        let mean = merged.as_dense()?;
        let step = if self.rho_g > 0.0 {
            for (m, &d) in self.momentum.iter_mut().zip(mean) {
                *m = self.rho_g * *m + d;
            }
            self.momentum.clone()
        } else {
            mean.to_vec()
        };
        Ok(RoundUpdate::Dense(step))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::aggregate::run_server_round;

    fn server_round_weighted(
        s: &mut FedAvgServer,
        sizes: &[f32],
        uploads: Vec<ClientUpload>,
        w: &mut [f32],
    ) -> RoundUpdate {
        run_server_round(s, sizes, uploads, w, 1.0).unwrap()
    }

    #[test]
    fn weighted_average_of_deltas() {
        let mut s = FedAvgServer::new(2, 0.0);
        let mut w = vec![0f32; 2];
        let u = vec![
            ClientUpload::Dense(vec![4.0, 0.0]),
            ClientUpload::Dense(vec![0.0, 4.0]),
        ];
        server_round_weighted(&mut s, &[3.0, 1.0], u, &mut w);
        assert_eq!(w, vec![-3.0, -1.0]);
    }

    #[test]
    fn uniform_fallback_when_sizes_are_zero() {
        let mut s = FedAvgServer::new(1, 0.0);
        let mut w = vec![0f32];
        let u = vec![ClientUpload::Dense(vec![2.0]), ClientUpload::Dense(vec![4.0])];
        server_round_weighted(&mut s, &[0.0, 0.0], u, &mut w);
        assert_eq!(w, vec![-3.0]);
    }

    #[test]
    fn wants_stacked() {
        let c = FedAvgClient::new(5);
        assert_eq!(c.wants_stacked_batches(), Some(5));
    }
}
