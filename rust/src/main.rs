//! `fetchsgd` — CLI launcher for the FetchSGD federated-learning stack.
//!
//! Subcommands:
//!   train       run one training config (JSON file + key=value overrides)
//!   serve       run the server half of a training config over a real
//!               transport (TCP/UDS), waiting for `join` workers
//!   join        connect to a `serve` instance and compute client
//!               uploads for it
//!   relay       mid-tier aggregator: join an upstream `serve` as one
//!               subtree while serving downstream `join` workers
//!   trace-summary  fold one or more JSONL trace files (`--trace` output
//!               from any tier) into a per-phase / per-tier table
//!   experiment  regenerate a paper table/figure (fig3|fig4|fig5|fig10|
//!               table1|ablation)
//!   inspect     print manifest / artifact info
//!   selfcheck   load the smoke artifacts and verify the cross-language
//!               sketch equality end-to-end
//!
//! Hand-rolled arg parsing (clap is unavailable offline): positional
//! subcommand followed by `--flag value` pairs and bare `key=value`
//! overrides.

use anyhow::{bail, Context, Result};
use std::path::PathBuf;

use fetchsgd::config::TrainConfig;
use fetchsgd::coordinator::Trainer;
use fetchsgd::experiments::runner::ExperimentScale;
use fetchsgd::experiments::{ablations, assumption, fig10, fig3, fig4, fig5, table1};
use fetchsgd::runtime::artifact::Manifest;

const USAGE: &str = "\
fetchsgd — communication-efficient federated learning with sketching

USAGE:
  fetchsgd train --config CFG.json [key=value ...]
            (observability, train/serve/relay alike:
             --trace PATH | trace_path=PATH  write phase spans, slot
                                  timelines, and latency histograms as
                                  JSONL; off by default and free when
                                  off. Fold with `fetchsgd
                                  trace-summary`.)
            (quorum knobs, train and serve alike:
             quorum_fraction=F    close a round once F of the cohort
                                  arrived, in (0,1]; default 1.0 = all
             round_deadline_ms=T  drop stragglers T ms into a round
                                  once quorum is met; 0 = wait forever
             max_slot_retries=N   re-offer a faulted slot N times
                                  before dropping it; default 0)
  fetchsgd serve --listen tcp:HOST:PORT|uds:/path.sock [--workers N]
            [--config CFG.json] [key=value ...]
            (serve knobs: serve_read_timeout_s=S serve_accept_timeout_s=S
             serve_max_msg=BYTES reduce_parallelism=N
             absorb knobs, train and serve alike:
             adaptive_shards=true  re-size the absorb shard count from
                                   observed lock contention; conflicts
                                   with shards= / shard_tiers= /
                                   relay_children= (those pin the fold
                                   layout); default false
             pin_shards=true      pin absorb/reduce workers to cores
                                   (placement hint, bitwise-neutral);
                                   needs parallelism or
                                   reduce_parallelism != 1)
  fetchsgd join --connect tcp:HOST:PORT|uds:/path.sock
            [--config CFG.json] [key=value ...]
            (reconnect knobs, join and relay alike:
             reconnect_attempts=N   re-dial a lost connection up to N
                                    *consecutive* times; a completed
                                    round resets the streak; default 0
             reconnect_backoff_ms=T first re-dial delay; the n-th
                                    consecutive failure waits T*2^(n-1)
                                    ms, hard-capped at 10 s)
  fetchsgd relay --connect tcp:HOST:PORT|uds:/path.sock
            --listen tcp:HOST:PORT|uds:/path.sock [--workers N]
            [--config CFG.json] [key=value ...]
            (upstream must run with relay_children=R; a relay with its
             own relay_children=K serves K downstream relays instead of
             workers, so trees nest to any depth; see shards=R, or
             shard_tiers=RxKx... for a depth>2 tree, to make a flat
             server bitwise-match the tree)
  fetchsgd trace-summary FILE [FILE ...]
            (merge trace files from any set of tiers — e.g. the root's
             and every relay's — into one per-tier round timeline)
  fetchsgd experiment <fig3|fig4|fig5|fig10|table1|ablation>
            [--dataset cifar10|cifar100] [--scale smoke|small|full]
            [--which ABLATION] [--curves] [--seeds N]
            [--artifacts DIR] [--out DIR]
  fetchsgd inspect [--artifacts DIR]
  fetchsgd selfcheck [--artifacts DIR]
";

struct Args {
    flags: Vec<(String, String)>,
    overrides: Vec<String>,
    bools: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut flags = Vec::new();
        let mut overrides = Vec::new();
        let mut bools = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") && !argv[i + 1].contains('=')
                {
                    flags.push((name.to_string(), argv[i + 1].clone()));
                    i += 2;
                    continue;
                }
                bools.push(name.to_string());
                i += 1;
            } else if a.contains('=') {
                overrides.push(a.clone());
                i += 1;
            } else {
                eprintln!("warning: ignoring stray argument '{a}'");
                i += 1;
            }
        }
        Args { flags, overrides, bools }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.iter().rev().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    // Parsed before Args::parse: its operands are positional file
    // paths, which the flag grammar would warn about and drop.
    if cmd == "trace-summary" {
        return cmd_trace_summary(&argv[1..]);
    }
    let args = Args::parse(&argv[1..]);
    let artifacts_dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let out_dir = PathBuf::from(args.get("out").unwrap_or("results"));

    match cmd.as_str() {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "join" => cmd_join(&args),
        "relay" => cmd_relay(&args),
        "experiment" => cmd_experiment(&args, artifacts_dir, out_dir),
        "inspect" => cmd_inspect(&artifacts_dir),
        "selfcheck" => cmd_selfcheck(&artifacts_dir),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path), &args.overrides)?,
        None => {
            let mut cfg = TrainConfig::default_smoke();
            cfg.apply_overrides(&args.overrides)?;
            cfg
        }
    };
    if args.has("verbose") {
        cfg.verbose = true;
    }
    if let Some(p) = args.get("trace") {
        cfg.trace_path = Some(PathBuf::from(p));
    }
    eprintln!(
        "[train] task={} strategy={} rounds={} W={}",
        cfg.task,
        cfg.strategy.name(),
        cfg.rounds,
        cfg.clients_per_round
    );
    let mut trainer = Trainer::new(cfg)?;
    let s = trainer.run()?;
    println!(
        "task={} strategy={} rounds={} final_loss={:.4} eval_loss={:.4} acc={:.4} ppl={:.2}",
        s.task, s.strategy, s.rounds, s.final_loss, s.eval_loss, s.accuracy, s.perplexity
    );
    println!(
        "compression: up {:.1}x down {:.1}x overall {:.1}x (stale-download bytes: {})",
        s.ratios.upload, s.ratios.download, s.ratios.overall, s.download_bytes_stale
    );
    if s.wire_upload_bytes > 0 {
        println!(
            "wire (measured frames): up {} B vs idealized {} B; down {} B vs idealized {} B",
            s.wire_upload_bytes, s.upload_bytes, s.wire_download_bytes, s.download_bytes
        );
    }
    Ok(())
}

/// Shared config loading for `serve` / `join`: config file + overrides,
/// with `--listen` / `--connect` setting the transport endpoint and
/// `--workers` the pool size.
fn transport_cfg(args: &Args, endpoint_flag: &str) -> Result<TrainConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => TrainConfig::load(std::path::Path::new(path), &args.overrides)?,
        None => {
            let mut cfg = TrainConfig::default_smoke();
            cfg.apply_overrides(&args.overrides)?;
            cfg
        }
    };
    if let Some(ep) = args.get(endpoint_flag) {
        cfg.transport = Some(ep.to_string());
    }
    if let Some(n) = args.get("workers") {
        cfg.transport_workers = n.parse().context("--workers")?;
    }
    if args.has("verbose") {
        cfg.verbose = true;
    }
    if let Some(p) = args.get("trace") {
        cfg.trace_path = Some(PathBuf::from(p));
    }
    if cfg.transport.is_none() {
        bail!("no transport endpoint: pass --{endpoint_flag} or set transport= in the config");
    }
    Ok(cfg)
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = transport_cfg(args, "listen")?;
    let s = fetchsgd::transport::serve_training(&cfg)?;
    println!(
        "task={} strategy={} rounds={} final_loss={:.4}",
        s.task, s.strategy, s.rounds, s.final_loss
    );
    println!(
        "bytes: idealized up {} down {}; measured frames up {} down {}; on-the-wire total {}",
        s.upload_bytes,
        s.download_bytes,
        s.wire_upload_bytes,
        s.wire_download_bytes,
        s.transport_bytes
    );
    Ok(())
}

fn cmd_join(args: &Args) -> Result<()> {
    let cfg = transport_cfg(args, "connect")?;
    let s = fetchsgd::transport::join_training(&cfg)?;
    println!(
        "joined: rounds={} uploads={} sent={} B received={} B",
        s.rounds, s.uploads, s.bytes_sent, s.bytes_received
    );
    Ok(())
}

fn cmd_relay(args: &Args) -> Result<()> {
    // Upstream is the ordinary transport endpoint (--connect, like
    // `join`); the downstream listener comes from --listen or the
    // relay_listen config knob.
    let mut cfg = transport_cfg(args, "connect")?;
    if let Some(ep) = args.get("listen") {
        cfg.relay_listen = Some(ep.to_string());
    }
    if cfg.relay_listen.is_none() {
        bail!("no downstream endpoint: pass --listen or set relay_listen= in the config");
    }
    let s = fetchsgd::relay::relay_training(&cfg)?;
    println!(
        "relayed: rounds={} merged_uploads={} reconnects={} upstream {} B downstream {} B",
        s.rounds, s.merged_uploads, s.reconnects, s.upstream_bytes, s.downstream_bytes
    );
    Ok(())
}

/// `fetchsgd trace-summary FILE [FILE ...]` — fold trace files from any
/// set of tiers into one per-phase / per-tier breakdown.
fn cmd_trace_summary(argv: &[String]) -> Result<()> {
    let files: Vec<&String> = argv.iter().filter(|a| !a.starts_with("--")).collect();
    if files.is_empty() {
        bail!("trace-summary needs at least one trace file\n{USAGE}");
    }
    let report = fetchsgd::trace::summary::fold_files(&files)?;
    print!("{}", fetchsgd::trace::summary::render(&report));
    Ok(())
}

fn cmd_experiment(args: &Args, artifacts_dir: PathBuf, out_dir: PathBuf) -> Result<()> {
    // `fetchsgd experiment fig3 ...`: the experiment id is the first
    // positional token after the subcommand.
    let argv: Vec<String> = std::env::args().skip(2).collect();
    let id = argv
        .first()
        .filter(|a| !a.starts_with("--") && !a.contains('='))
        .cloned()
        .context("missing experiment id (fig3|fig4|fig5|fig10|table1|ablation|assumption2)")?;
    let scale = ExperimentScale::parse(args.get("scale").unwrap_or("small"))?;
    match id.as_str() {
        "fig3" => {
            let dataset = args.get("dataset").unwrap_or("cifar10").to_string();
            if dataset != "cifar10" && dataset != "cifar100" {
                bail!("--dataset must be cifar10|cifar100");
            }
            fig3::run(fig3::Fig3Params { dataset, scale, artifacts_dir, out_dir })?;
        }
        "fig4" => {
            fig4::run(fig4::Fig4Params { scale, artifacts_dir, out_dir })?;
        }
        "fig5" => {
            fig5::run(fig5::Fig5Params {
                scale,
                artifacts_dir,
                out_dir,
                curves: args.has("curves"),
            })?;
        }
        "fig10" => {
            fig10::run(fig10::Fig10Params { scale, artifacts_dir, out_dir })?;
        }
        "table1" => {
            let seeds = args.get("seeds").map(|s| s.parse()).transpose()?.unwrap_or(3);
            table1::run(table1::Table1Params { scale, artifacts_dir, out_dir, seeds })?;
        }
        "ablation" => {
            let which = args.get("which").unwrap_or("zero_vs_subtract").to_string();
            ablations::run(ablations::AblationParams { which, scale, artifacts_dir, out_dir })?;
        }
        "assumption2" => {
            let task = args.get("task").unwrap_or("cifar10").to_string();
            assumption::run(assumption::AssumptionParams { scale, artifacts_dir, out_dir, task })?;
        }
        other => bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

fn cmd_inspect(artifacts_dir: &PathBuf) -> Result<()> {
    let manifest = Manifest::load(artifacts_dir)?;
    println!("artifacts: {}", artifacts_dir.display());
    for t in &manifest.tasks {
        println!(
            "task {:<16} model {:<18} d={:<8} batch={:<4} sketch rows={} cols={:?}",
            t.name, t.model, t.dim, t.batch, t.sketch.rows, t.sketch.cols_options
        );
        let mut kinds: Vec<&String> = t.artifacts.keys().collect();
        kinds.sort();
        for k in kinds {
            println!("    {k}");
        }
    }
    Ok(())
}

/// End-to-end cross-language check: run the smoke task's client_step
/// (gradient sketched by the Pallas kernel inside the HLO graph) and the
/// client_grad artifact, sketch the gradient with the Rust CountSketch,
/// and require close agreement.
fn cmd_selfcheck(artifacts_dir: &PathBuf) -> Result<()> {
    use fetchsgd::runtime::exec::{run_client_grad, run_client_step};
    use fetchsgd::runtime::{Runtime, TaskArtifacts};
    use fetchsgd::sketch::CountSketch;

    let runtime = std::sync::Arc::new(Runtime::cpu()?);
    println!("platform: {}", runtime.platform());
    let manifest = Manifest::load(artifacts_dir)?;
    let task = manifest
        .tasks
        .iter()
        .find(|t| t.name == "smoke")
        .context("smoke task missing — run `make artifacts`")?
        .name
        .clone();
    let arts = TaskArtifacts::new(runtime, &manifest, &task)?;
    let cols = arts.manifest.sketch.cols_options[0];
    let (rows, seed) = (arts.manifest.sketch.rows, arts.manifest.sketch.seed);
    let w = arts.init_weights()?;

    let ds = fetchsgd::model::build_dataset(&arts.manifest, &fetchsgd::model::DataScale::smoke())?;
    let batch = ds.client_batch(0, 7);

    let step_exe = arts.executable(&TaskArtifacts::client_step_kind(cols))?;
    let (loss1, sketch_jax) = run_client_step(&step_exe, &w, &batch, rows, cols, seed)?;
    let grad_exe = arts.executable("client_grad")?;
    let (loss2, grad) = run_client_grad(&grad_exe, &w, &batch)?;
    let sketch_rust = CountSketch::encode(rows, cols, seed, &grad)?;

    anyhow::ensure!((loss1 - loss2).abs() < 1e-5, "losses disagree: {loss1} vs {loss2}");
    let mut max_err = 0f32;
    for (a, b) in sketch_jax.table().iter().zip(sketch_rust.table()) {
        max_err = max_err.max((a - b).abs());
    }
    let scale: f32 = grad.iter().map(|g| g.abs()).fold(0.0, f32::max).max(1e-9);
    println!(
        "loss={loss1:.5}  sketch max_abs_err={max_err:.3e} (grad max {scale:.3e}, {} cells)",
        sketch_jax.cells()
    );
    anyhow::ensure!(
        max_err <= 1e-4 * scale.max(1.0),
        "Pallas and Rust sketches disagree (max err {max_err})"
    );
    println!("selfcheck OK: Pallas-in-HLO sketch == Rust sketch");
    Ok(())
}
