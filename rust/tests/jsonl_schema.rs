//! JSONL schema round-trip: every record type the metrics logger emits
//! and every event type the trace sink emits must parse back through
//! the hand-rolled JSON layer with the documented keys — including the
//! keys that are *omitted* when zero/absent, so consumers can rely on
//! "key present ⇔ value measured". The schema itself is documented in
//! `docs/OBSERVABILITY.md`; this test is its executable form.

use fetchsgd::metrics::{EvalRecord, MetricsLogger, RoundRecord, SummaryRecord};
use fetchsgd::serialize::json::{parse, Value};
use fetchsgd::trace::summary::{fold_text, TraceReport};
use fetchsgd::trace::{Histogram, Phase, SlotEvent, TraceSink};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("fsgd_schema_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn keys(v: &Value) -> Vec<String> {
    v.as_object().unwrap().iter().map(|(k, _)| k.clone()).collect()
}

/// A round record with every optional field zeroed — the "quiet" shape
/// an in-process, untraced, estimate-only run logs.
fn minimal_round(round: usize) -> RoundRecord {
    RoundRecord {
        round,
        loss: 1.5,
        lr: 0.1,
        upload_bytes: 64,
        download_bytes: 32,
        wire_upload_bytes: 0,
        wire_download_bytes: 0,
        transport_bytes: 0,
        absorb_stalls: 0,
        parked_bytes: 0,
        chosen_shards: 0,
        participants: 2,
        dropped_slots: 0,
        retried_slots: 0,
        update_nnz: 7,
        round_ms: 3.25,
        compute_ms: 0.0,
        absorb_ms: 0.0,
        reduce_ms: 0.0,
        tier: None,
    }
}

/// A round record with every optional field populated — the shape a
/// traced, wire-mode tree root logs.
fn maximal_round(round: usize) -> RoundRecord {
    RoundRecord {
        wire_upload_bytes: 96,
        wire_download_bytes: 48,
        transport_bytes: 180,
        absorb_stalls: 3,
        parked_bytes: 512,
        chosen_shards: 4,
        dropped_slots: 1,
        retried_slots: 2,
        compute_ms: 2.0,
        absorb_ms: 0.5,
        reduce_ms: 0.25,
        tier: Some("root"),
        ..minimal_round(round)
    }
}

#[test]
fn round_record_round_trips_and_omits_unmeasured_keys() {
    let dir = tmpdir("round");
    let p = dir.join("run.jsonl");
    {
        let mut m = MetricsLogger::new(Some(&p)).unwrap();
        m.log_round(minimal_round(0));
        m.log_round(maximal_round(1));
        m.flush().unwrap();
    }
    let text = std::fs::read_to_string(&p).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2);

    // Minimal shape: only the always-present keys, in schema order.
    let v = parse(lines[0]).unwrap();
    assert_eq!(v.req_str("type").unwrap(), "round");
    assert_eq!(
        keys(&v),
        [
            "type",
            "round",
            "loss",
            "lr",
            "upload_bytes",
            "download_bytes",
            "participants",
            "dropped_slots",
            "retried_slots",
            "update_nnz",
            "round_ms",
        ],
        "a quiet round must omit every unmeasured/zero optional key"
    );
    assert_eq!(v.req_u64("round").unwrap(), 0);
    assert!((v.req_f64("round_ms").unwrap() - 3.25).abs() < 1e-9);

    // Maximal shape: every optional key present and correct.
    let v = parse(lines[1]).unwrap();
    for key in [
        "wire_upload_bytes",
        "wire_download_bytes",
        "transport_bytes",
        "absorb_stalls",
        "parked_bytes",
        "chosen_shards",
        "compute_ms",
        "absorb_ms",
        "reduce_ms",
    ] {
        assert!(v.get(key).is_some(), "traced wire-mode round must carry {key}");
    }
    assert_eq!(v.req_str("tier").unwrap(), "root");
    assert!((v.req_f64("absorb_ms").unwrap() - 0.5).abs() < 1e-9);
    assert_eq!(v.req_u64("transport_bytes").unwrap(), 180);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_record_round_trips() {
    let dir = tmpdir("eval");
    let p = dir.join("run.jsonl");
    {
        let mut m = MetricsLogger::new(Some(&p)).unwrap();
        m.log_eval(EvalRecord { round: 4, eval_loss: 1.75, accuracy: 0.5, perplexity: 5.75 });
        m.flush().unwrap();
    }
    let text = std::fs::read_to_string(&p).unwrap();
    let v = parse(text.lines().next().unwrap()).unwrap();
    assert_eq!(v.req_str("type").unwrap(), "eval");
    assert_eq!(keys(&v), ["type", "round", "eval_loss", "accuracy", "perplexity"]);
    assert_eq!(v.req_u64("round").unwrap(), 4);
    assert!((v.req_f64("accuracy").unwrap() - 0.5).abs() < 1e-9);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn summary_record_round_trips_and_omits_unmeasured_keys() {
    let dir = tmpdir("summary");
    let p = dir.join("run.jsonl");
    {
        let mut m = MetricsLogger::new(Some(&p)).unwrap();
        // Untraced run: wall clock only.
        m.log_summary(&SummaryRecord {
            strategy: "fetchsgd".into(),
            task: "smoke".into(),
            rounds: 2,
            final_loss: 1.0,
            upload_bytes: 10,
            download_bytes: 20,
            round_ms: 7.5,
            ..SummaryRecord::default()
        });
        // Traced run: full phase + arrival breakdown.
        m.log_summary(&SummaryRecord {
            strategy: "fetchsgd".into(),
            task: "smoke".into(),
            rounds: 2,
            final_loss: 1.0,
            upload_bytes: 10,
            download_bytes: 20,
            dropped_slots: 1,
            retried_slots: 2,
            round_ms: 7.5,
            compute_ms: 4.0,
            absorb_ms: 1.5,
            reduce_ms: 0.5,
            arrival_p50_ms: 0.8,
            arrival_p90_ms: 1.6,
            arrival_p99_ms: 2.4,
        });
        m.flush().unwrap();
    }
    let text = std::fs::read_to_string(&p).unwrap();
    let lines: Vec<&str> = text.lines().collect();

    let v = parse(lines[0]).unwrap();
    assert_eq!(v.req_str("type").unwrap(), "summary");
    assert_eq!(
        keys(&v),
        [
            "type",
            "strategy",
            "task",
            "rounds",
            "final_loss",
            "upload_bytes",
            "download_bytes",
            "dropped_slots",
            "retried_slots",
            "round_ms",
        ],
        "an untraced summary must omit the phase and arrival keys"
    );

    let v = parse(lines[1]).unwrap();
    for key in [
        "compute_ms",
        "absorb_ms",
        "reduce_ms",
        "arrival_p50_ms",
        "arrival_p90_ms",
        "arrival_p99_ms",
    ] {
        assert!(v.get(key).is_some(), "traced summary must carry {key}");
    }
    assert!((v.req_f64("arrival_p99_ms").unwrap() - 2.4).abs() < 1e-9);
    std::fs::remove_dir_all(&dir).ok();
}

/// Every trace event type, written by the sink and read back both as
/// raw JSON (key-level schema) and through the summary folder (the
/// consumer every trace file must satisfy).
#[test]
fn trace_events_round_trip_through_sink_and_summary_folder() {
    let dir = tmpdir("trace");
    let p = dir.join("t.jsonl");
    {
        let sink = TraceSink::create(&p, "root", "tcp:127.0.0.1:9999").unwrap();
        let t0 = sink.now_us();
        for phase in Phase::ALL {
            sink.span(5, phase, t0, t0 + 100);
        }
        // Slot timeline: every event variant, with and without a peer.
        for ev in [
            SlotEvent::Offered,
            SlotEvent::Validated,
            SlotEvent::Absorbed,
            SlotEvent::Parked,
            SlotEvent::Folded,
            SlotEvent::Retried,
            SlotEvent::Reassigned,
        ] {
            sink.slot_event(5, 3, ev, Some(1));
            sink.slot_event(5, 4, ev, None);
        }
        sink.slot_dropped(5, 9, "deadline");
        sink.conn(5, 2, 100, 200, 300);
        let mut h = Histogram::new();
        h.record(50);
        h.record(5_000);
        sink.histogram(Some(5), "slot_arrival_us", &h);
        sink.flush().unwrap();
    }
    let text = std::fs::read_to_string(&p).unwrap();

    // Key-level schema: every line parses and carries its documented
    // keys; `peer` and `reason` are omitted when not applicable.
    for line in text.lines() {
        let v = parse(line).unwrap();
        match v.req_str("type").unwrap() {
            "trace_meta" => {
                assert_eq!(keys(&v), ["type", "v", "tier", "source", "epoch_unix_ms"]);
                assert_eq!(v.req_u64("v").unwrap(), fetchsgd::trace::TRACE_VERSION);
                assert_eq!(v.req_str("tier").unwrap(), "root");
            }
            "span" => {
                assert_eq!(keys(&v), ["type", "tier", "round", "phase", "start_us", "dur_us"]);
                assert_eq!(v.req_u64("dur_us").unwrap(), 100);
            }
            "slot" => {
                let base = ["type", "tier", "round", "slot", "event", "t_us"];
                let got = keys(&v);
                if v.req_str("event").unwrap() == "dropped" {
                    assert_eq!(got, [&base[..], &["reason"]].concat());
                    assert_eq!(v.req_str("reason").unwrap(), "deadline");
                } else if v.req_u64("slot").unwrap() == 3 {
                    assert_eq!(got, [&base[..], &["peer"]].concat());
                    assert_eq!(v.req_u64("peer").unwrap(), 1);
                } else {
                    assert_eq!(got, base, "peerless slot events must omit the peer key");
                }
            }
            "conn" => {
                assert_eq!(
                    keys(&v),
                    ["type", "tier", "round", "peer", "stall_us", "read_us", "write_us"]
                );
                assert_eq!(v.req_u64("write_us").unwrap(), 300);
            }
            "hist" => {
                assert_eq!(
                    keys(&v),
                    [
                        "type", "tier", "round", "metric", "count", "max_us", "p50_us", "p90_us",
                        "p99_us", "buckets",
                    ]
                );
                assert_eq!(v.req_u64("count").unwrap(), 2);
                assert!(!v.req_array("buckets").unwrap().is_empty());
            }
            other => panic!("undocumented trace event type {other:?}"),
        }
    }

    // Consumer-level: the summary folder accepts every event the sink
    // can produce, with nothing skipped as unknown.
    let mut report = TraceReport::default();
    fold_text(&mut report, &text, "inline").unwrap();
    assert_eq!(report.unknown_lines, 0, "sink and folder schema drifted apart");
    assert_eq!(report.sources, vec![("root".to_string(), "tcp:127.0.0.1:9999".to_string())]);
    assert_eq!(report.rounds.len(), 1);
    let tl = &report.rounds[&5];
    assert_eq!(tl.phases.len(), Phase::ALL.len(), "all six phases fold under the root tier");
    assert_eq!(tl.events[&("root".to_string(), "offered".to_string())], 2);
    assert_eq!(tl.events[&("root".to_string(), "dropped".to_string())], 1);
    assert_eq!(report.hists[&("root".to_string(), "slot_arrival_us".to_string())].count(), 2);
    let (stall, read, write) = report.conn_totals[&("root".to_string(), 2)];
    assert_eq!((stall, read, write), (100, 200, 300));
    std::fs::remove_dir_all(&dir).ok();
}
