//! Property: membership accounting is *tree-shape invariant*.
//!
//! Since protocol v4 a root server learns most slot outcomes
//! second-hand, as `SlotReport` roll-ups from a relay subtree: a leaf
//! relay settles its workers' slots, an interior relay merges its
//! leaves (its own retries folded into each report's count), and the
//! root replays the merged reports through
//! `RoundMembership::record_report` — the transport's `roll_up` path.
//! A flat server sees the same facts first-hand, as direct
//! `record_retry` / `record_arrival` / `record_drop` events in slot
//! order.
//!
//! These properties pin the equivalence the depth-3 determinism tests
//! rest on: for random topologies (chain and leaf fan-out), random
//! per-slot outcomes, retry counts, chain arrival orders, and
//! mid-round re-offer patterns, the two recording paths settle on the
//! same membership set, the same participant accounting, and the same
//! `renormalization_scale`, bit for bit.

use fetchsgd::cohort::{DropReason, QuorumPolicy, RoundMembership, SlotOutcome};
use fetchsgd::util::proptest::{check, Gen};

/// Retry budget far above anything a case generates — the budget gates
/// *re-offers*, never the bookkeeping under test.
const MAX_RETRIES: usize = 16;

#[derive(Clone, Copy)]
struct SlotFact {
    /// Did the upload ultimately arrive (possibly after retries)?
    arrived: bool,
    /// Retries the subtree itself charged against the slot.
    retries: usize,
    /// Drop reason, meaningful only when `arrived` is false.
    reason: DropReason,
    weight: f32,
    loss: f32,
}

fn gen_fact(g: &mut Gen) -> SlotFact {
    SlotFact {
        arrived: g.usize_in(0, 4) != 0,
        retries: g.usize_in(0, 4),
        reason: match g.usize_in(0, 3) {
            0 => DropReason::Faulted,
            1 => DropReason::Disconnected,
            _ => DropReason::Deadline,
        },
        weight: 0.5 + g.f32_in(0.0, 4.0),
        loss: g.f32_in(0.0, 2.0),
    }
}

/// The order an interior relay's merged report lists chain `r`'s
/// slots: leaf by leaf (leaf `k` owns the chain-local positions
/// `≡ k (mod nleaves)`), ascending within each leaf.
fn chain_report_order(slots: usize, r: usize, nchains: usize, nleaves: usize) -> Vec<usize> {
    let chain: Vec<usize> = (0..slots).filter(|s| s % nchains == r).collect();
    let mut order = Vec::with_capacity(chain.len());
    for k in 0..nleaves {
        for (i, &s) in chain.iter().enumerate() {
            if i % nleaves == k {
                order.push(s);
            }
        }
    }
    order
}

#[test]
fn prop_tree_rollups_match_the_flat_tracker() {
    check("membership tree == flat", 300, |g| {
        let slots = g.usize_in(4, 41);
        let nchains = g.usize_in(1, 5);
        let nleaves = g.usize_in(1, 5);
        let policy = QuorumPolicy::new(g.f64_in(0.1, 1.0), 0, MAX_RETRIES).unwrap();

        let mut facts: Vec<SlotFact> = (0..slots).map(|_| gen_fact(g)).collect();
        if !facts.iter().any(|f| f.arrived) {
            // Renormalization needs at least one survivor.
            facts[0].arrived = true;
        }
        // A root-tier re-offer of a whole chain charges one extra
        // retry per slot of that chain, on top of the subtree's own
        // count.
        let reoffered: Vec<bool> = (0..nchains).map(|_| g.usize_in(0, 4) == 0).collect();
        let weights: Vec<f32> = facts.iter().map(|f| f.weight).collect();

        // Chains' merged uploads land in a random order.
        let mut chain_order: Vec<usize> = (0..nchains).collect();
        for i in (1..nchains).rev() {
            let j = g.usize_in(0, i + 1);
            chain_order.swap(i, j);
        }

        // Tree path: replay each chain's merged report through
        // `record_report`, exactly as the transport's roll-up does.
        let mut tree = RoundMembership::new(slots, policy.clone()).unwrap();
        let mut tree_losses = vec![0f32; slots];
        for &r in &chain_order {
            for s in chain_report_order(slots, r, nchains, nleaves) {
                let f = facts[s];
                if reoffered[r] {
                    tree.record_retry(s);
                }
                if f.arrived {
                    tree.record_report(
                        s,
                        if f.retries > 0 {
                            SlotOutcome::Retried(f.retries)
                        } else {
                            SlotOutcome::Arrived
                        },
                    );
                    tree_losses[s] = f.loss;
                } else {
                    for _ in 0..f.retries {
                        tree.record_retry(s);
                    }
                    tree.record_report(s, SlotOutcome::Dropped(f.reason));
                }
            }
        }

        // Flat path: the same facts as first-hand events, slot order.
        let mut flat = RoundMembership::new(slots, policy).unwrap();
        let mut flat_losses = vec![0f32; slots];
        for (s, f) in facts.iter().enumerate() {
            let extra = usize::from(reoffered[s % nchains]);
            for _ in 0..f.retries + extra {
                flat.record_retry(s);
            }
            if f.arrived {
                flat.record_arrival(s);
                flat_losses[s] = f.loss;
            } else {
                flat.record_drop(s, f.reason);
            }
        }

        assert!(tree.is_settled() && flat.is_settled());
        assert_eq!(tree.arrived_slots(), flat.arrived_slots(), "membership set diverged");
        assert_eq!(tree.quorum_met(), flat.quorum_met());
        assert_eq!(tree.summary(), flat.summary(), "participant accounting diverged");
        for s in 0..slots {
            assert_eq!(tree.outcome(s), flat.outcome(s), "slot {s} outcome diverged");
        }
        assert_eq!(
            tree.renormalization_scale(&weights).unwrap().to_bits(),
            flat.renormalization_scale(&weights).unwrap().to_bits(),
            "renormalization scale diverged"
        );
        assert_eq!(tree_losses, flat_losses);
        assert_eq!(
            tree.mean_loss_over_arrived(&tree_losses).to_bits(),
            flat.mean_loss_over_arrived(&flat_losses).to_bits(),
        );
    });
}

/// The re-offer identity the root relies on: `record_retry` followed
/// by an arrived report with `n` downstream retries is
/// indistinguishable from a single `Retried(n + 1)` report.
#[test]
fn prop_reoffer_retry_charge_equals_incremented_report() {
    check("re-offer identity", 100, |g| {
        let n = g.usize_in(0, 6);
        let policy = QuorumPolicy::new(1.0, 0, MAX_RETRIES).unwrap();
        let mut a = RoundMembership::new(1, policy.clone()).unwrap();
        a.record_retry(0);
        a.record_report(0, if n > 0 { SlotOutcome::Retried(n) } else { SlotOutcome::Arrived });
        let mut b = RoundMembership::new(1, policy).unwrap();
        b.record_report(0, SlotOutcome::Retried(n + 1));
        assert_eq!(a.outcome(0), b.outcome(0));
        assert_eq!(a.summary(), b.summary());
        assert!(a.is_full() && b.is_full());
    });
}
